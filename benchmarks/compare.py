"""Regression diff of two bench JSON artifacts (`--out` files).

CI runs each benchmark with ``--out`` and compares the fresh artifact against
the committed baseline under ``benchmarks/baselines/``: seeded metrics may
drift better, not worse. Only deterministic fields are gated — wall-clock
derived numbers (``wall_s``, the obs overhead measurements) are excluded
because shared runners make them noisy; the obs overhead has its own CI
assert with a generous bound.

Spec directions:
  higher  candidate must be >= baseline * (1 - tolerance)
  lower   candidate must be <= baseline * (1 + tolerance)
  exact   candidate must equal baseline (counts, booleans)

Dict-valued leaves (e.g. per-tier p95 maps) are compared key-by-key.

Usage:
  python benchmarks/compare.py BASELINE.json CANDIDATE.json \
      [--bench serving_schedule] [--tolerance 0.05]

``run()`` performs a self-check (identity compare passes; an injected 20%
throughput regression is caught) so the harness can gate the comparator
itself.
"""
from __future__ import annotations

import argparse
import copy
import json
import sys
from typing import Any, Dict, List, Tuple

# gated fields per bench artifact: (dotted path, direction)
SPECS: Dict[str, List[Tuple[str, str]]] = {
    "spec_decode": [
        ("acceptance_all", "exact"),
        ("tokens_per_forward_ratio", "higher"),
        ("energy_ratio_draft", "lower"),
        ("parity.ngram.tokens_equal", "exact"),
        ("parity.draft.tokens_equal", "exact"),
        ("variants.off.completed", "exact"),
        ("variants.ngram.completed", "exact"),
        ("variants.draft.completed", "exact"),
        ("variants.draft.tokens_per_forward", "higher"),
        ("variants.draft.ipw", "higher"),
        ("variants.draft.refit_depth", "exact"),
        ("variants.ngram.refit_depth", "exact"),
    ],
    "prefix_pool": [
        ("acceptance_all", "exact"),
        ("parity_ok", "exact"),
        ("hits_match_analytic", "exact"),
        ("expected_hits", "exact"),
        ("hit_rate", "higher"),
        ("steady_prefill_ratio", "higher"),
        ("prefill_bytes_ratio", "higher"),
        ("throughput_ratio", "higher"),
        ("batch.completed", "exact"),
        ("pool.completed", "exact"),
        ("pool.pool_hit_blocks", "exact"),
        ("pool.pool_evictions", "exact"),
        ("pool.prefill_bytes_moved", "lower"),
        ("pool.steady_prefill_bytes_moved", "lower"),
        ("tight.completed", "exact"),
        ("tight.pool_evictions", "higher"),
        ("tight.pool_hit_blocks", "higher"),
    ],
    "preemption": [
        ("acceptance_all", "exact"),
        ("preempt.completed", "exact"),
        ("preempt.cancelled", "exact"),
        ("preempt.preemptions", "exact"),
        ("preempt.retries_total", "exact"),
        ("preempt.chaos_applied", "exact"),
        ("preempt.leaked_blocks", "exact"),
        ("run_to_completion.completed", "exact"),
        ("run_to_completion.leaked_blocks", "exact"),
        ("run_to_completion.chaos_applied", "exact"),
        ("interactive_p95_ratio", "higher"),
        ("resume_tail_ratio", "lower"),
        ("gates", "exact"),
    ],
    "serving_schedule": [
        ("acceptance_all", "exact"),
        ("scheduler.completed", "exact"),
        ("scheduler.batches", "exact"),
        ("scheduler.caps_met_fraction", "higher"),
        ("scheduler.throughput_rps", "higher"),
        ("scheduler.ipw_seq_per_j", "higher"),
        ("scheduler.p95_latency_s", "lower"),
        ("per_call.throughput_rps", "higher"),
        ("throughput_ratio", "higher"),
        ("ipw_ratio", "higher"),
        ("obs.parity_ok", "exact"),
        ("obs.span_lifecycle_ok", "exact"),
    ],
}


def _get(d: Any, path: str) -> Any:
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            raise KeyError(path)
        d = d[part]
    return d


def _leaf_checks(path: str, base: Any, cand: Any,
                 direction: str) -> List[Tuple[str, Any, Any, str]]:
    """Expand dict-valued leaves into per-key scalar checks."""
    if isinstance(base, dict):
        out = []
        for k in sorted(base):
            if not isinstance(cand, dict) or k not in cand:
                out.append((f"{path}.{k}", base[k], None, direction))
            else:
                out += _leaf_checks(f"{path}.{k}", base[k], cand[k],
                                    direction)
        return out
    return [(path, base, cand, direction)]


def compare(base: Dict, cand: Dict, bench: str,
            tolerance: float = 0.05) -> List[Dict]:
    """Returns regression findings (empty when candidate is no worse)."""
    findings = []
    for path, direction in SPECS[bench]:
        try:
            b = _get(base, path)
        except KeyError:
            continue            # baseline predates the field: nothing to gate
        try:
            c = _get(cand, path)
        except KeyError:
            findings.append({"path": path, "base": b, "cand": None,
                             "why": "missing in candidate"})
            continue
        for p, bv, cv, d in _leaf_checks(path, b, c, direction):
            if cv is None:
                findings.append({"path": p, "base": bv, "cand": None,
                                 "why": "missing in candidate"})
            elif d == "exact":
                if cv != bv:
                    findings.append({"path": p, "base": bv, "cand": cv,
                                     "why": "changed (exact field)"})
            elif d == "higher":
                if cv < bv * (1.0 - tolerance) - 1e-12:
                    findings.append({"path": p, "base": bv, "cand": cv,
                                     "why": f"regressed > {tolerance:.0%}"})
            elif d == "lower":
                if cv > bv * (1.0 + tolerance) + 1e-12:
                    findings.append({"path": p, "base": bv, "cand": cv,
                                     "why": f"regressed > {tolerance:.0%}"})
            else:
                raise ValueError(f"unknown direction {d!r} for {p}")
    return findings


def report(findings: List[Dict], bench: str, verbose: bool = True) -> bool:
    ok = not findings
    if verbose:
        if ok:
            print(f"[compare] {bench}: no regressions")
        else:
            print(f"[compare] {bench}: {len(findings)} regression(s)")
            for f in findings:
                print(f"  {f['path']}: {f['base']!r} -> {f['cand']!r} "
                      f"({f['why']})")
    return ok


def run(verbose: bool = True) -> Dict:
    """Self-check for the bench harness: the comparator must pass an identity
    compare and catch an injected 20% throughput regression."""
    base = {
        "acceptance_all": True,
        "throughput_ratio": 6.0,
        "ipw_ratio": 2.5,
        "scheduler": {"completed": 48, "batches": 13,
                      "caps_met_fraction": 1.0, "throughput_rps": 1000.0,
                      "ipw_seq_per_j": 50.0,
                      "p95_latency_s": {"interactive": 0.001,
                                        "standard": 0.002}},
        "per_call": {"throughput_rps": 200.0},
        "obs": {"parity_ok": True, "span_lifecycle_ok": True},
    }
    identity = compare(base, copy.deepcopy(base), "serving_schedule")
    hurt = copy.deepcopy(base)
    hurt["scheduler"]["throughput_rps"] *= 0.8
    hurt["scheduler"]["p95_latency_s"]["standard"] *= 2.0
    caught = compare(base, hurt, "serving_schedule")
    caught_paths = sorted(f["path"] for f in caught)
    ok = (not identity and
          caught_paths == ["scheduler.p95_latency_s.standard",
                           "scheduler.throughput_rps"])
    result = {"identity_clean": not identity,
              "regressions_caught": caught_paths,
              "self_check_ok": bool(ok)}
    if verbose:
        print(f"[compare] self-check: identity clean={not identity}, "
              f"injected regressions caught={caught_paths}, ok={ok}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--bench", default="serving_schedule",
                    choices=sorted(SPECS))
    ap.add_argument("--tolerance", type=float, default=0.05)
    args = ap.parse_args()
    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.candidate) as fh:
        cand = json.load(fh)
    findings = compare(base, cand, args.bench, tolerance=args.tolerance)
    if not report(findings, args.bench):
        sys.exit(1)


if __name__ == "__main__":
    main()
