"""Paper Section 5.5: edge vs cloud inference regimes.

Sweeps model scale and compares the heterogeneous edge platform against a
homogeneous datacenter GPU on ECE (coverage per joule, the paper's
battery-centric metric). The paper claims a transition: edge wins at
small-to-medium scale, cloud dominates at large scale."""
from __future__ import annotations

from typing import Dict

from repro.core import (CoverageParams, Workload, coverage, decompose,
                        homogeneous_assignment, plan_costs)
from repro.core.devices import CLOUD_GPU
from repro.configs.paper_models import PAPER_MODELS
from repro.models import Model
from benchmarks.common import PAPER_WORKLOAD, energy_aware_plan, fmt_table


# Cloud deployment overheads the raw accelerator roofline misses:
PUE = 1.35                 # datacenter power usage effectiveness
WAN_ENERGY_PER_QUERY = 1.0  # J: client radio + network path per request
N_QUERIES = PAPER_WORKLOAD.batch


def run(verbose: bool = True) -> Dict:
    rows = []
    edge_wins, sizes = [], []
    for name, cfg in PAPER_MODELS.items():
        N_m = Model(cfg).param_count() / 1e6
        cov_params = CoverageParams.calibrated(N_m, target_cov=0.70)
        cov = coverage(20, N_m, 256.0, cov_params)
        stages = decompose(cfg, PAPER_WORKLOAD)
        cloud_pc = plan_costs(stages, homogeneous_assignment(stages,
                                                             CLOUD_GPU),
                              "bf16", PAPER_WORKLOAD)
        cloud_e = cloud_pc.energy_j * PUE + WAN_ENERGY_PER_QUERY * N_QUERIES
        edge = energy_aware_plan(cfg, PAPER_WORKLOAD)
        ece_cloud = cov / cloud_e
        ece_edge = cov / edge.energy_j
        win = ece_edge > ece_cloud
        edge_wins.append(bool(win))
        sizes.append(N_m)
        rows.append([name, f"{N_m:.0f}M",
                     f"{edge.energy_j / 1e3:.2f}", f"{cloud_e / 1e3:.2f}",
                     f"{ece_edge * 1e3:.3f}", f"{ece_cloud * 1e3:.3f}",
                     "edge" if win else "cloud",
                     f"{cloud_pc.makespan_s / edge.latency_s:.2f}"])
    if verbose:
        print(fmt_table(
            ["model", "N", "edge kJ", "cloud kJ (+PUE+WAN)",
             "ECE edge (1/kJ)", "ECE cloud (1/kJ)", "regime",
             "cloud/edge time"],
            rows, "Section 5.5: edge vs cloud inference regimes (ECE)"))
        if any(edge_wins) and not all(edge_wins):
            flip = next(f"{s:.0f}M" for s, w in zip(sizes, edge_wins)
                        if not w)
            print(f"   regime transition reproduced: edge-optimal below, "
                  f"cloud-optimal from ~{flip} upward (paper Section 5.5)")
    return {"edge_wins": edge_wins,
            "edge_wins_small_models": bool(edge_wins[0]),
            "transition_exists": bool(any(edge_wins) and not all(edge_wins)),
            "n_edge_wins": sum(edge_wins)}
