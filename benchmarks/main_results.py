"""Paper Table 16: main cross-model results — five model families x
standard / energy-aware execution."""
from __future__ import annotations

from typing import Dict

from repro.core import CoverageParams, RunMetrics, coverage, cost_total
from repro.core.devices import EDGE_GPU_NVIDIA
from repro.configs.paper_models import PAPER_MODELS
from repro.models import Model
from benchmarks.common import (N_QUERIES, PAPER_TABLE16, PAPER_WORKLOAD,
                               effective_samples, energy_aware_plan,
                               fmt_table, standard_plan)


def _metrics(cfg, pc, cov, n_queries=N_QUERIES, samples=20) -> RunMetrics:
    total_tokens = n_queries * samples * (128 + 256)
    cost = cost_total(samples * n_queries, pc.energy_j,
                      EDGE_GPU_NVIDIA)["total"] / n_queries * 1000
    return RunMetrics(
        coverage=cov, accuracy=cov * 0.6,
        energy_j=pc.energy_j,
        latency_s=pc.makespan_s / (n_queries * samples),
        power_w=pc.avg_power_w,
        throughput_tps=total_tokens / max(pc.makespan_s, 1e-9),
        cost_usd_per_1k=cost)


def run(verbose: bool = True) -> Dict:
    rows = []
    agg = {"ipw_x": [], "cov_pp": [], "energy_pct": [], "lat_pct": [],
           "power_pct": [], "ppp_pct": []}
    for name, cfg in PAPER_MODELS.items():
        p = PAPER_TABLE16[name]
        N_m = Model(cfg).param_count() / 1e6
        cov_params = CoverageParams.calibrated(N_m, target_cov=p[0] / 100.0)

        std_pc = standard_plan(cfg)
        ea = energy_aware_plan(cfg)
        s_eff = effective_samples(20, std_pc.energy_j / ea.energy_j)

        std = _metrics(cfg, std_pc, coverage(20, N_m, 256.0, cov_params))
        eam = _metrics(cfg, ea.costs, coverage(s_eff, N_m, 256.0, cov_params))

        agg["ipw_x"].append(eam.ipw / std.ipw)
        agg["cov_pp"].append((eam.coverage - std.coverage) * 100)
        agg["energy_pct"].append((eam.energy_j / std.energy_j - 1) * 100)
        agg["lat_pct"].append((eam.latency_s / std.latency_s - 1) * 100)
        agg["power_pct"].append((eam.power_w / std.power_w - 1) * 100)
        agg["ppp_pct"].append((eam.ppp / std.ppp - 1) * 100)

        for label, m, pref in (("std", std, (p[0], p[2], p[4], p[6])),
                               ("EA", eam, (p[1], p[3], p[5], p[7]))):
            rows.append([name if label == "std" else "", label,
                         f"{m.ipw:.3f}", f"{m.coverage * 100:.1f}",
                         f"{m.energy_j / 1e3:.1f}", f"{m.ppp:.2f}",
                         f"{m.power_w:.1f}", f"{m.latency_s * 1e3:.3f}",
                         f"{pref[0]}% {pref[1]}kJ {pref[2]}W {pref[3]}ms"])

    mean = {k: sum(v) / len(v) for k, v in agg.items()}
    if verbose:
        print(fmt_table(
            ["model", "exec", "IPW", "pass@k %", "energy kJ", "PPP",
             "power W", "lat ms", "paper ref"],
            rows, "Table 16: main results (5 model families)"))
        print(f"   mean deltas (ours): IPW x{mean['ipw_x']:.2f}, "
              f"{mean['cov_pp']:+.1f}pp coverage, {mean['energy_pct']:+.1f}% "
              f"energy, {mean['lat_pct']:+.1f}% latency, "
              f"{mean['power_pct']:+.1f}% power, {mean['ppp_pct']:+.1f}% PPP")
        print("   paper means: x2.08-5.60 IPW, +8.9pp, -48.8% energy, "
              "-15.8% latency, -68.0% power, +39.0% PPP")
    return {"mean": mean,
            "energy_reduced_all": all(v < 0 for v in agg["energy_pct"]),
            "coverage_up_all": all(v > 0 for v in agg["cov_pp"])}
