"""Resident prefix-sharing KV pool vs per-batch sharing (PR 9 bench).

A seeded request stream shaped like real serving traffic: every prompt is
one of three Zipf-weighted **system prompts** (24 tokens = 6 full blocks)
followed by a short unique user suffix (4 tokens). Two backends see the
identical stream at the *identical KV block budget*:

* ``batch`` — the PR-5 path: prefix sharing and CoW inside one batch only;
  every batch re-prefills the system prompt from scratch.
* ``pool``  — the resident `PrefixPool`: a radix trie over token-id block
  chunks survives batch retirement, so a warm request resolves its cached
  system-prompt chain in one trie walk and prefills only the 4-token tail.

Reported per policy: prefill bytes moved (total and **steady-state** —
excluding the cold first batch), and throughput over a roofline-style
service model (fixed batch overhead + bandwidth-bound prefill term per
token actually moved + compute-bound decode term per sequence-token; the
decode term is identical for both policies because batch formation is,
so the throughput gap is purely the prefill traffic the pool avoids).

The pooled stream's hit/miss counters are cross-checked against an
**analytic replay**: a pure-python trie is driven with the recorded batch
memberships (``BatchRecord.request_entries``) and must reproduce the
backend's `serving_prefix_pool_{hits,misses}_total` exactly — the
eviction-free budget makes the expectation exact. A separate tight-budget
run forces LRU evictions and must still complete every request.

Acceptance (seeded, CI-gated): pooled decode is token/logprob bit-identical
to the non-pooled paged path (cold AND cache-hot, sampled, CoW tail);
steady-state prefill bytes are >= 3x lower than per-batch sharing at equal
block budget; throughput matches-or-beats the per-batch path; the obs
counters match the analytic replay; the tight run evicts (> 0) and
completes the full stream.

Run: PYTHONPATH=src python benchmarks/prefix_pool.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
import time
from types import SimpleNamespace
from typing import Dict, List

import numpy as np

SEED = 0
N_REQUESTS = 18
K_SAMPLES = 2                        # repeats exercise CoW on top of the pool
SYS_LEN = 24                         # 6 full blocks of shared system prompt
USER_LEN = 4                         # unique per-request tail
PROMPT_LEN = SYS_LEN + USER_LEN
MAX_NEW = 4
BLOCK_SIZE = 4
N_SYSTEM = 3
ZIPF_W = [1.0 / (i + 1) for i in range(N_SYSTEM)]
BUDGET_BLOCKS = 96                   # generous: main runs never evict
TIGHT_BLOCKS = 24                    # resident demand ~36 blocks -> LRU churn
# roofline-style service model: fixed pipeline overhead + bandwidth-bound
# prefill (per token moved) + compute-bound decode (per sequence-token)
BATCH_BASE_S = 0.5
PREFILL_S_PER_TOKEN = 0.02
DECODE_S_PER_SEQ_TOKEN = 0.01

ARCH = dict(name="pool-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


class _FixedRouter:
    """Deterministic routing double: this bench measures cross-batch KV
    reuse, not SLA routing (serving_schedule.py gates that)."""

    def __init__(self):
        self.tier = SimpleNamespace(name="standard")

    def resolve_tier(self, tier):
        return self.tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        return SimpleNamespace(
            tier=self.tier, tier_counts={}, assignment=object(),
            point_index=0, meets_caps=True, batch_costs=None,
            energy_j=float(len(tiers)), latency_s=BATCH_BASE_S, notes=[])


def _arrivals() -> List[Dict]:
    rng = np.random.default_rng(SEED)
    probs = np.asarray(ZIPF_W) / sum(ZIPF_W)
    systems = [rng.integers(0, ARCH["vocab_size"],
                            size=(SYS_LEN,)).astype(np.int32)
               for _ in range(N_SYSTEM)]
    t, out = 0.0, []
    for _ in range(N_REQUESTS):
        t += rng.exponential(0.4)
        sysid = int(rng.choice(N_SYSTEM, p=probs))
        suffix = rng.integers(0, ARCH["vocab_size"],
                              size=(USER_LEN,)).astype(np.int32)
        out.append({"t": t, "sysid": sysid,
                    "prompt": np.concatenate([systems[sysid], suffix])})
    return out


def _modeled_makespan(records) -> float:
    """Post-hoc service model over the recorded batches. Prefill tokens
    actually moved come from the records' savings accounting, so per-batch
    repeat sharing and pool hits both get credit."""
    from repro.models import ArchConfig
    from repro.models.cache import kv_bytes_per_token

    ktb = kv_bytes_per_token(ArchConfig(**ARCH), 4)
    total = 0.0
    for r in records:
        moved_tokens = r.n_sequences * PROMPT_LEN \
            - r.prefill_bytes_saved / ktb
        total += (BATCH_BASE_S + PREFILL_S_PER_TOKEN * moved_tokens
                  + DECODE_S_PER_SEQ_TOKEN * r.n_sequences * MAX_NEW)
    return total


def _run_stream(pooled: bool, arrivals, kv_blocks: int = BUDGET_BLOCKS,
                verbose: bool = True) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.models.cache import kv_bytes_per_token
    from repro.obs import make_observability
    from repro.serving import (ContinuousBatchingScheduler, ExecutionBackend,
                               SchedulerConfig)

    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    ktb = kv_bytes_per_token(cfg, 4)            # f32 model
    obs = make_observability()
    backend = ExecutionBackend(model, params, kv_blocks=kv_blocks,
                               kv_block_size=BLOCK_SIZE, kv_pool=pooled,
                               obs=obs)
    sched = ContinuousBatchingScheduler(
        backend, _FixedRouter(),
        SchedulerConfig(max_batch_requests=4, max_inflight_batches=2,
                        max_new_tokens=MAX_NEW, seed=SEED))

    prompt_by_id: Dict[int, np.ndarray] = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            adm = sched.submit(a["prompt"], tier="standard",
                               n_samples=K_SAMPLES, arrival_s=a["t"])
            assert adm.admitted, adm.reason
            prompt_by_id[adm.request_id] = a["prompt"]
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        sched.step()
    wall_s = time.perf_counter() - t0

    recs = list(sched.records)

    def moved(rs) -> int:
        return int(sum(r.n_sequences * PROMPT_LEN * ktb
                       - r.prefill_bytes_saved for r in rs))

    reg = obs.metrics
    resident = backend.prefix_pool.blocks_resident if pooled else 0
    out = {
        "policy": "pool" if pooled else "batch",
        "kv_blocks": kv_blocks,
        "completed": len(sched.completed),
        "batches": len(recs),
        "prefill_bytes_moved": moved(recs),
        "steady_prefill_bytes_moved": moved(recs[1:]),
        "prefill_bytes_saved": int(sum(r.prefill_bytes_saved for r in recs)),
        "pool_hit_blocks": int(sum(r.pool_hit_blocks for r in recs)),
        "pool_evictions": int(sum(r.pool_evictions for r in recs)),
        "pool_blocks_resident": int(resident),
        "pool_resident_bytes": int(resident * BLOCK_SIZE * ktb),
        "obs_hits": int(reg.counter(
            "serving_prefix_pool_hits_total").value()) if pooled else 0,
        "obs_misses": int(reg.counter(
            "serving_prefix_pool_misses_total").value()) if pooled else 0,
        "modeled_makespan_s": _modeled_makespan(recs),
        "wall_s": wall_s,
        "_records": recs,              # stripped before serialization
        "_prompt_by_id": prompt_by_id,
    }
    out["throughput_rps"] = out["completed"] / out["modeled_makespan_s"]
    if verbose:
        tag = out["policy"] + ("" if kv_blocks == BUDGET_BLOCKS else "+tight")
        print(f"  {tag:11s} {out['batches']:2d} batches, "
              f"prefill {out['prefill_bytes_moved'] / 1e3:.1f} kB "
              f"(steady {out['steady_prefill_bytes_moved'] / 1e3:.1f} kB), "
              f"hits {out['pool_hit_blocks']}, "
              f"evictions {out['pool_evictions']}, "
              f"{out['throughput_rps']:.3f} req/s")
    return out


def _analytic_replay(records, prompt_by_id) -> Dict[str, int]:
    """Drive a pure-python trie with the recorded batch memberships and
    predict the pool's hit/miss counters. Mirrors the backend accounting:
    per request ``plen // bs`` lookupable chunks, hits capped at
    ``(plen - 1) // bs`` (at least one tail token must remain for the
    first-token logits), inserts applied after the whole batch (acquires
    see the pre-batch trie; first writer wins)."""
    from repro.serving.prefix_pool import chunk_key

    full_prefix = PROMPT_LEN // BLOCK_SIZE
    max_hit = (PROMPT_LEN - 1) // BLOCK_SIZE
    root: Dict = {}
    hits = misses = 0
    for rec in records:
        chains = []
        for entry in rec.request_entries:
            prompt = prompt_by_id[entry["id"]]
            node, depth = root, 0
            while depth < max_hit:
                key = chunk_key(prompt, depth * BLOCK_SIZE, BLOCK_SIZE)
                if key not in node:
                    break
                node = node[key]
                depth += 1
            hits += depth
            misses += full_prefix - depth
            chains.append(prompt)
        for prompt in chains:
            node = root
            for d in range(full_prefix):
                key = chunk_key(prompt, d * BLOCK_SIZE, BLOCK_SIZE)
                node = node.setdefault(key, {})
    return {"hits": hits, "misses": misses}


def _parity() -> bool:
    """Pinned acceptance parity: pooled generation must be token- and
    logprob-identical to the non-pooled paged path, cold AND cache-hot,
    sampled, with a CoW partial tail block (plen % bs != 0)."""
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.serving import ExecutionBackend

    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    rng = np.random.default_rng(SEED)
    shared = rng.integers(0, ARCH["vocab_size"], size=(8,)).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(
        0, ARCH["vocab_size"], size=(3,)).astype(np.int32)])
        for _ in range(2)]                      # plen 11 -> CoW partial tail

    def gen(backend):
        h = backend.start_batch(prompts, K_SAMPLES, MAX_NEW, 0.8,
                                jax.random.key(42))
        while backend.decode_step(h):
            pass
        return backend.finalize(h)

    def same(a, b) -> bool:
        for ra, rb in zip(a, b):
            for s1, s2 in zip(ra.samples, rb.samples):
                if not np.array_equal(s1, s2):
                    return False
            if ra.logprobs != rb.logprobs:
                return False
        return True

    want = gen(ExecutionBackend(model, params, kv_blocks=64,
                                kv_block_size=BLOCK_SIZE))
    pooled = ExecutionBackend(model, params, kv_blocks=64,
                              kv_block_size=BLOCK_SIZE, kv_pool=True)
    cold = gen(pooled)                          # trie empty: all misses
    hot = gen(pooled)                           # warm: shared chain reused
    return same(cold, want) and same(hot, want)


def run(verbose: bool = True) -> Dict:
    arrivals = _arrivals()
    if verbose:
        print(f"stream: {N_REQUESTS} requests x {K_SAMPLES} samples, "
              f"{N_SYSTEM} Zipf system prompts of {SYS_LEN} + {USER_LEN} "
              f"user tokens, budget {BUDGET_BLOCKS} blocks of {BLOCK_SIZE} "
              f"(tight run: {TIGHT_BLOCKS})")
    batch = _run_stream(False, arrivals, verbose=verbose)
    pool = _run_stream(True, arrivals, verbose=verbose)
    tight = _run_stream(True, arrivals, kv_blocks=TIGHT_BLOCKS,
                        verbose=verbose)
    expected = _analytic_replay(pool["_records"], pool["_prompt_by_id"])
    hits_match = (pool["obs_hits"] == expected["hits"]
                  == pool["pool_hit_blocks"]
                  and pool["obs_misses"] == expected["misses"])
    parity_ok = _parity()
    for r in (batch, pool, tight):              # drop replay-only fields
        r.pop("_records"), r.pop("_prompt_by_id")

    steady_ratio = batch["steady_prefill_bytes_moved"] / \
        max(pool["steady_prefill_bytes_moved"], 1)
    prefill_ratio = batch["prefill_bytes_moved"] / \
        max(pool["prefill_bytes_moved"], 1)
    lookups = pool["obs_hits"] + pool["obs_misses"]
    result = {
        "seed": SEED,
        "k_samples": K_SAMPLES,
        "batch": batch,
        "pool": pool,
        "tight": tight,
        "parity_ok": parity_ok,
        "hits_match_analytic": hits_match,
        "expected_hits": expected["hits"],
        "hit_rate": pool["obs_hits"] / max(lookups, 1),
        "steady_prefill_ratio": steady_ratio,
        "prefill_bytes_ratio": prefill_ratio,
        "throughput_ratio": pool["throughput_rps"] / batch["throughput_rps"],
        "acceptance_all": bool(
            parity_ok and
            hits_match and
            steady_ratio >= 3.0 and
            pool["throughput_rps"] >= batch["throughput_rps"] and
            pool["completed"] == batch["completed"] == N_REQUESTS and
            pool["pool_evictions"] == 0 and        # budget sized to not evict
            tight["completed"] == N_REQUESTS and
            tight["pool_evictions"] > 0 and        # LRU actually reclaimed
            tight["pool_hit_blocks"] > 0),
    }
    if verbose:
        print(f"  parity_ok={parity_ok}, hits_match_analytic={hits_match} "
              f"({pool['obs_hits']} hits, rate {result['hit_rate']:.2f}), "
              f"steady prefill x{steady_ratio:.1f} less, "
              f"throughput x{result['throughput_ratio']:.2f}, "
              f"acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: prefix_pool.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
