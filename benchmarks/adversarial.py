"""Paper Table 12: adversarial robustness — input validation effectiveness
against the paper's four attack classes."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import InputValidator, OutputSanitizer
from benchmarks.common import fmt_table

PAPER = {"oversized": 100.0, "malformed": 100.0, "ddos": 99.2,
         "repetition": 94.0}


def run(verbose: bool = True, n: int = 500) -> Dict:
    rng = np.random.default_rng(0)
    ctx, vocab = 2048, 50257

    # oversized inputs (10x context)
    v = InputValidator(ctx, vocab)
    blocked = sum(not v.validate(
        np.zeros(int(ctx * rng.uniform(2, 10)), np.int32), float(i)).ok
        for i in range(n))
    oversized = blocked / n * 100

    # malformed encodings (out-of-range / negative token ids)
    v = InputValidator(ctx, vocab)
    blocked = 0
    for i in range(n):
        toks = rng.integers(0, vocab, 64).astype(np.int32)
        toks[rng.integers(0, 64)] = vocab + int(rng.integers(1, 1000)) \
            if rng.random() < 0.5 else -int(rng.integers(1, 100))
        blocked += not v.validate(toks, float(i)).ok
    malformed = blocked / n * 100

    # rapid-fire requests (DDoS): 100 rps limiter vs 5000 rps flood over 1 s
    v = InputValidator(ctx, vocab, max_requests_per_s=100)
    flood = 5000
    admitted = sum(v.validate(np.arange(8, dtype=np.int32),
                              now_s=i / flood).ok for i in range(flood))
    ddos_blocked = (flood - admitted) / flood * 100

    # repetition-inducing prompts: output sanitizer halting degenerate loops
    s = OutputSanitizer(expected_len=256)
    caught = 0
    n_rep = 200
    for i in range(n_rep):
        rep_frac = rng.uniform(0.85, 1.0)
        toks = rng.integers(0, vocab, 120).astype(np.int32)
        k = int(120 * rep_frac)
        toks[-k:] = 7
        if not s.check(toks).ok:
            caught += 1
    repetition = caught / n_rep * 100

    rows = [
        ["oversized input (10x ctx)", f"{oversized:.1f}%", "none",
         f"{PAPER['oversized']}%"],
        ["malformed encoding", f"{malformed:.1f}%", "none",
         f"{PAPER['malformed']}%"],
        ["rapid-fire (DDoS)", f"{ddos_blocked:.1f}%", "rate-limited",
         f"{PAPER['ddos']}%"],
        ["repetition-inducing", f"{repetition:.1f}%", "halted",
         f"{PAPER['repetition']}%"],
    ]
    if verbose:
        print(fmt_table(["attack", "blocked (ours)", "system impact",
                         "paper"], rows, "Table 12: adversarial robustness"))
    return {"oversized_pct": oversized, "malformed_pct": malformed,
            "ddos_pct": ddos_blocked, "repetition_pct": repetition,
            "all_structural_blocked": oversized == 100 and malformed == 100}
