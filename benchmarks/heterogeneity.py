"""Paper Table 3: controlled heterogeneity ablation — homogeneous GPU / NPU /
CPU vs QEIL heterogeneous orchestration, GPT-2 (125M), S=20, WikiText scale.

Coverage mechanism (documented reproduction decision, EXPERIMENTS.md §Perf):
the paper's +10.5pp coverage for heterogeneous execution comes from its
adaptive sample budget — energy saved per sample is reinvested as extra
samples at iso-energy. We reproduce exactly that: S_eff = S * (E_std/E_het),
coverage from the per-model calibrated Formalism 1.
"""
from __future__ import annotations

from typing import Dict

from repro.core import (CoverageParams, RunMetrics, Workload, coverage,
                        cost_total, decompose, homogeneous_assignment,
                        plan_costs)
from repro.core.devices import (EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU,
                                EDGE_PLATFORM)
from repro.configs.paper_models import GPT2_125M
from repro.models import Model
from benchmarks.common import (PAPER_WORKLOAD, N_QUERIES, effective_samples,
                               energy_aware_plan, fmt_table, standard_plan)

PAPER_ROWS = {
    "homog GPU": (59.5, 43.1, 1.73, 0.149, 402.5, 16.85),
    "homog NPU": (58.2, 31.8, 2.41, 0.312, 186.4, 14.21),
    "homog CPU": (57.8, 38.6, 3.12, 0.187, 309.2, 12.94),
    "QEIL heterogeneous": (70.0, 22.5, 1.34, 0.718, 83.5, 20.74),
}


def _metrics(cfg, plan_cost, S_eff: float, cov_params, n_queries=N_QUERIES,
             samples=20) -> RunMetrics:
    cov = coverage(S_eff, Model(cfg).param_count() / 1e6, 256.0, cov_params)
    total_tokens = n_queries * samples * (128 + 256)
    cost = cost_total(samples * n_queries, plan_cost.energy_j,
                      EDGE_GPU_NVIDIA)["total"] / n_queries * 1000
    return RunMetrics(
        coverage=cov, accuracy=coverage(1, Model(cfg).param_count() / 1e6,
                                        256.0, cov_params),
        energy_j=plan_cost.energy_j,
        latency_s=plan_cost.makespan_s / (n_queries * samples),
        power_w=plan_cost.avg_power_w,
        throughput_tps=total_tokens / max(plan_cost.makespan_s, 1e-9),
        cost_usd_per_1k=cost)


def run(verbose: bool = True) -> Dict:
    cfg = GPT2_125M
    N_m = Model(cfg).param_count() / 1e6
    # calibrate coverage params so standard S=20 gives the paper's 59.5%
    cov_params = CoverageParams.calibrated(N_m, target_cov=0.595)
    w = PAPER_WORKLOAD

    stages = decompose(cfg, w)
    plans = {
        "homog GPU": plan_costs(stages, homogeneous_assignment(
            stages, EDGE_GPU_NVIDIA), "bf16", w),
        "homog NPU": plan_costs(stages, homogeneous_assignment(
            stages, EDGE_NPU), "bf16", w),
        "homog CPU": plan_costs(stages, homogeneous_assignment(
            stages, EDGE_CPU), "bf16", w),
    }
    het = energy_aware_plan(cfg, w)
    plans["QEIL heterogeneous"] = het.costs

    e_std = plans["homog GPU"].energy_j
    rows = []
    results = {}
    for name, pc in plans.items():
        s_eff = effective_samples(20, e_std / pc.energy_j) \
            if name == "QEIL heterogeneous" else 20.0
        m = _metrics(cfg, pc, s_eff, cov_params)
        results[name] = m
        p = PAPER_ROWS[name]
        rows.append([name, f"{m.coverage * 100:.1f}",
                     f"{m.energy_j / 1e3:.1f}",
                     f"{m.latency_s * 1e3:.3f}",
                     f"{m.ipw:.3f}", f"{m.power_w:.1f}", f"{m.ppp:.2f}",
                     f"{p[0]:.1f}/{p[1]:.1f}kJ"])

    base = results["homog GPU"]
    het_m = results["QEIL heterogeneous"]
    deltas = {
        "coverage_pp": (het_m.coverage - base.coverage) * 100,
        "energy_pct": (het_m.energy_j / base.energy_j - 1) * 100,
        "latency_pct": (het_m.latency_s / base.latency_s - 1) * 100,
        "ipw_x": het_m.ipw / base.ipw,
    }
    if verbose:
        print(fmt_table(
            ["config", "pass@k %", "energy kJ", "lat ms", "IPW", "power W",
             "PPP", "paper(cov/E)"],
            rows, "Table 3: controlled heterogeneity ablation (GPT-2, S=20)"))
        print(f"   deltas vs homog GPU: {deltas}")
        print("   paper deltas: +10.5pp coverage, -47.7% energy, "
              "-22.5% latency, 4.8x IPW")
    return {"deltas": deltas,
            "heterogeneous_wins_energy":
                het_m.energy_j < min(p.energy_j for n, p in results.items()
                                     if n != "QEIL heterogeneous"),
            "coverage_gain_pp": deltas["coverage_pp"]}
