"""Deliverable (g): the roofline table — three terms per (arch x shape) from
the single-pod dry-run artifacts, dominant bottleneck, MODEL_FLOPS ratio.

Reads experiments/dryrun/*.json produced by repro.launch.dryrun.

Conventions (documented in EXPERIMENTS.md §Roofline):
  * XLA's cost_analysis on the SPMD-partitioned module reports PER-DEVICE
    FLOPs/bytes and does NOT multiply while-loop (scan) trip counts; we
    re-scale by the scan trip count (n_scanned_super_blocks) and chip count
    to obtain whole-program totals, and report the raw numbers alongside.
  * collective bytes are payload bytes of every collective op result,
    also per-device x chips.
  * MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.roofline import RooflineTerms, terms_from_counts
from repro.core.devices import TPU_V5E
from repro.configs import get_config
from repro.models.cache import n_scanned_super_blocks
from repro.models.config import INPUT_SHAPES
from benchmarks.common import fmt_table

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "dryrun")


def model_flops(art: Dict) -> float:
    """Analytic useful FLOPs for the workload."""
    n_active = art["active_param_count"]
    shape = INPUT_SHAPES[art["shape"]]
    if art["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if art["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1     # decode: one token per sequence
    return 2.0 * n_active * tokens


def scaled_counts(art: Dict) -> Optional[Dict]:
    """Whole-program FLOPs/bytes/collective-bytes from the artifact."""
    cost = art.get("cost_analysis", {})
    if "error" in cost or "flops" not in cost:
        return None
    cfg = get_config(art["arch"])
    trip = n_scanned_super_blocks(cfg)
    chips = art["n_chips"]
    # cost_analysis: per-device module, scan body counted once -> scale.
    flops = cost["flops"] * trip * chips
    bytes_moved = cost.get("bytes accessed", 0.0) * trip * chips
    coll = art["collective_bytes"]["total"] * trip * chips
    return {"flops": flops, "bytes": bytes_moved, "collective": coll,
            "raw_flops": cost["flops"], "trip": trip}


def analyze(art: Dict) -> Optional[Dict]:
    sc = scaled_counts(art)
    if sc is None:
        return None
    terms = terms_from_counts(sc["flops"], sc["bytes"], sc["collective"],
                              art["n_chips"], TPU_V5E)
    mf = model_flops(art)
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "terms": terms, "model_flops": mf,
        "flops_ratio": mf / sc["flops"] if sc["flops"] else float("nan"),
        "counts": sc,
    }


def load_artifacts(mesh: str = "single") -> List[Dict]:
    arts = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def load_variants(mesh: str = "single") -> List[Dict]:
    """Tagged §Perf variant artifacts (…__<mesh>__<tag>.json)."""
    arts = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR,
                                              f"*__{mesh}__*.json"))):
        with open(path) as f:
            a = json.load(f)
        a["tag"] = os.path.basename(path).split("__")[-1].replace(".json", "")
        arts.append(a)
    return arts


def run(verbose: bool = True, mesh: str = "single") -> Dict:
    arts = load_artifacts(mesh)
    rows = []
    analyzed = []
    failures = []
    for art in arts:
        if "error" in art:
            failures.append((art["arch"], art["shape"]))
            continue
        a = analyze(art)
        if a is None:
            failures.append((art["arch"], art["shape"]))
            continue
        analyzed.append(a)
        t: RooflineTerms = a["terms"]
        rows.append([a["arch"], a["shape"],
                     f"{t.compute_s * 1e3:.2f}", f"{t.memory_s * 1e3:.2f}",
                     f"{t.collective_s * 1e3:.2f}", t.dominant,
                     f"{a['flops_ratio']:.2f}"])
    if verbose:
        print(fmt_table(
            ["arch", "shape", "compute ms", "memory ms", "collective ms",
             "dominant", "MODEL/HLO"],
            rows, f"Roofline terms per (arch x shape), {mesh} pod "
                  f"({len(analyzed)} ok, {len(failures)} missing/failed)"))
        vrows = []
        for art in load_variants(mesh):
            if "error" in art:
                continue
            a = analyze(art)
            if a is None:
                continue
            t = a["terms"]
            vrows.append([a["arch"], a["shape"], art["tag"],
                          f"{t.compute_s * 1e3:.2f}",
                          f"{t.memory_s * 1e3:.2f}",
                          f"{t.collective_s * 1e3:.2f}", t.dominant])
        if vrows:
            print(fmt_table(
                ["arch", "shape", "variant", "compute ms", "memory ms",
                 "collective ms", "dominant"],
                vrows, "§Perf hillclimb variants (EXPERIMENTS.md §Perf)"))
    return {"n_analyzed": len(analyzed), "n_failed": len(failures),
            "failures": failures, "analyzed": analyzed}
