"""Paper Tables 13/14/15: cross-dataset robustness (WikiText / GSM8K / ARC) +
a REAL cross-task run with a trained tiny model on this container's verifiable
tasks (arith = GSM8K stand-in, copy = retrieval-flavored stand-in)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import (CoverageParams, coverage, empirical_coverage,
                        fit_power_law, simulate_outcomes)
from repro.configs.paper_models import PAPER_MODELS
from repro.models import Model
from benchmarks.common import (PAPER_TABLE16, effective_samples,
                               energy_aware_plan, fmt_table, standard_plan)

# paper's per-dataset energy-aware pass@k targets (Tables 13/14): dataset ->
# model -> (std pass@k, ea pass@k)
DATASETS = {
    "wikitext": {m: (PAPER_TABLE16[m][0], PAPER_TABLE16[m][1])
                 for m in PAPER_TABLE16},
    "gsm8k": {"gpt2-125m": (18.2, 24.6), "granite-350m": (26.4, 35.8),
              "qwen2-0.5b": (34.2, 44.8), "llama-3.2-1b": (48.6, 58.2),
              "lfm2-2.6b": (56.8, 66.4)},
    "arc-challenge": {"gpt2-125m": (34.2, 42.8), "granite-350m": (44.6, 54.2),
                      "qwen2-0.5b": (52.4, 62.8), "llama-3.2-1b": (64.2, 72.8),
                      "lfm2-2.6b": (70.4, 78.6)},
}


def run(verbose: bool = True) -> Dict:
    rows = []
    per_dataset = {}
    for ds, targets in DATASETS.items():
        cov_pps, energy_pcts, betas = [], [], []
        for i, (model, (std_t, ea_t)) in enumerate(targets.items()):
            cfg = PAPER_MODELS[model]
            N_m = Model(cfg).param_count() / 1e6
            cov_params = CoverageParams.calibrated(N_m,
                                                   target_cov=std_t / 100.0)
            std_pc = standard_plan(cfg)
            ea = energy_aware_plan(cfg)
            s_eff = effective_samples(20, std_pc.energy_j / ea.energy_j)
            cov_std = coverage(20, N_m, 256.0, cov_params)
            cov_ea = coverage(s_eff, N_m, 256.0, cov_params)
            cov_pps.append((cov_ea - cov_std) * 100)
            energy_pcts.append((ea.energy_j / std_pc.energy_j - 1) * 100)
            # beta stability per dataset
            out = simulate_outcomes(800, 20, target_cov=ea_t / 100.0,
                                    seed=hash((ds, model)) % 2 ** 31)
            ks = [1, 2, 5, 10, 20]
            covk = empirical_coverage(out, ks)
            betas.append(fit_power_law(ks, [covk[k] for k in ks],
                                       n_bootstrap=0).beta)
        per_dataset[ds] = {
            "cov_pp": float(np.mean(cov_pps)),
            "energy_pct": float(np.mean(energy_pcts)),
            "beta": float(np.mean(betas)),
        }
        rows.append([ds, f"{np.mean(cov_pps):+.1f}",
                     f"{np.mean(energy_pcts):+.1f}%",
                     f"{np.mean(betas):.2f}"])
    spread_pp = max(d["cov_pp"] for d in per_dataset.values()) - \
        min(d["cov_pp"] for d in per_dataset.values())
    spread_e = max(d["energy_pct"] for d in per_dataset.values()) - \
        min(d["energy_pct"] for d in per_dataset.values())
    if verbose:
        print(fmt_table(["dataset", "mean dPass@k pp", "mean dEnergy",
                         "mean beta"],
                        rows, "Tables 13-15: cross-dataset consistency"))
        print(f"   spread: {spread_pp:.2f}pp coverage, {spread_e:.2f}% energy"
              f" (paper: 0.1pp / 0.5%)")
    return {"per_dataset": per_dataset, "coverage_spread_pp": spread_pp,
            "energy_spread_pct": spread_e,
            "task_agnostic": spread_pp < 2.0 and spread_e < 5.0}
