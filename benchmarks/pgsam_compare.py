"""Greedy vs exhaustive-oracle vs PGSAM comparison (the v2 tentpole bench).

Emits a JSON document with, per small case (<= 12 stages, where the
exponential oracle is tractable): energy, makespan and wall-clock for all
three orchestrators plus PGSAM/oracle and greedy/oracle energy ratios; and,
on the heterogeneous 4-device edge fixture: the epsilon-constraint greedy
sweep frontier vs the PGSAM archive frontier with their shared-reference
2-D hypervolumes.

All randomness is seeded (PGSAMConfig.seed) — the numbers are reproducible
run-to-run.

Run: PYTHONPATH=src python benchmarks/pgsam_compare.py [--out pgsam.json]
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List

from repro.configs.paper_models import GPT2_125M
from repro.core import (Constraints, GreedyOrchestrator, Workload, decompose,
                        exhaustive_oracle, hypervolume_2d)
from repro.core.devices import (EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU,
                                EDGE_PLATFORM)
from repro.models import ArchConfig
from repro.qeil2 import PGSAMConfig, PGSAMOrchestrator

SEED = 0

TINY4 = ArchConfig(name="tiny-4l", arch_type="dense", n_layers=4, d_model=256,
                   n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1000)
TINY5 = ArchConfig(name="tiny-5l", arch_type="dense", n_layers=5, d_model=320,
                   n_heads=4, n_kv_heads=2, d_ff=640, vocab_size=1000)

SMALL_W = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=4)

# (case name, config, device set) — all decompose to <= 12 stages
SMALL_CASES = [
    ("tiny4_npu_gpu", TINY4, [EDGE_NPU, EDGE_GPU_NVIDIA]),
    ("tiny4_cpu_npu", TINY4, [EDGE_CPU, EDGE_NPU]),
    ("tiny5_npu_gpu", TINY5, [EDGE_NPU, EDGE_GPU_NVIDIA]),
]

HETERO_W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)


def _small_case(name: str, cfg: ArchConfig, devices: List) -> Dict:
    n_stages = len(decompose(cfg, SMALL_W))
    unconstrained = Constraints(latency_budget_factor=None)

    t0 = time.perf_counter()
    oracle = exhaustive_oracle(cfg, SMALL_W, devices, max_stages=12)
    t_oracle = time.perf_counter() - t0

    t0 = time.perf_counter()
    greedy = GreedyOrchestrator(devices, unconstrained).assign(cfg, SMALL_W)
    t_greedy = time.perf_counter() - t0

    t0 = time.perf_counter()
    pgsam = PGSAMOrchestrator(devices, unconstrained,
                              config=PGSAMConfig(seed=SEED)).assign(
                                  cfg, SMALL_W)
    t_pgsam = time.perf_counter() - t0

    return {
        "case": name, "n_stages": n_stages,
        "devices": [d.name for d in devices],
        "oracle": {"energy_j": oracle.energy_j,
                   "makespan_s": oracle.latency_s,
                   "wall_clock_s": t_oracle},
        "greedy": {"energy_j": greedy.energy_j,
                   "makespan_s": greedy.latency_s,
                   "wall_clock_s": t_greedy},
        "pgsam": {"energy_j": pgsam.energy_j,
                  "makespan_s": pgsam.latency_s,
                  "wall_clock_s": t_pgsam},
        "pgsam_over_oracle": pgsam.energy_j / oracle.energy_j,
        "greedy_over_oracle": greedy.energy_j / oracle.energy_j,
        "pgsam_within_5pct": pgsam.energy_j <= oracle.energy_j * 1.05,
    }


def _greedy_sweep_points(cfg: ArchConfig, w: Workload,
                         devices: List) -> List[Dict]:
    """Epsilon-constraint greedy baseline: the v1 way to trace a frontier."""
    from repro.core.orchestrator import greedy_sla_sweep
    base = GreedyOrchestrator(devices,
                              Constraints(latency_budget_factor=None)).assign(
                                  cfg, w)
    points = [{"energy_j": base.energy_j, "makespan_s": base.latency_s}]
    for a in greedy_sla_sweep(devices, cfg, w, base.latency_s):
        if a.mapping and a.feasible:
            points.append({"energy_j": a.energy_j,
                           "makespan_s": a.latency_s})
    return points


def _hetero_fixture() -> Dict:
    devices = EDGE_PLATFORM            # the heterogeneous 4-device fixture
    cfg, w = GPT2_125M, HETERO_W

    t0 = time.perf_counter()
    greedy_pts = _greedy_sweep_points(cfg, w, devices)
    t_greedy = time.perf_counter() - t0

    t0 = time.perf_counter()
    orch = PGSAMOrchestrator(devices, Constraints(latency_budget_factor=None),
                             config=PGSAMConfig(seed=SEED))
    frontier = orch.pareto_frontier(cfg, w)
    t_pgsam = time.perf_counter() - t0
    pgsam_pts = [{"energy_j": a.energy_j, "makespan_s": a.latency_s}
                 for a in frontier if a.mapping]

    # shared reference: 10% beyond the worst point of either frontier, so the
    # two hypervolumes are directly comparable.
    all_pts = greedy_pts + pgsam_pts
    ref = (1.1 * max(p["energy_j"] for p in all_pts),
           1.1 * max(p["makespan_s"] for p in all_pts))
    hv_greedy = hypervolume_2d(
        [(p["energy_j"], p["makespan_s"]) for p in greedy_pts], ref)
    hv_pgsam = hypervolume_2d(
        [(p["energy_j"], p["makespan_s"]) for p in pgsam_pts], ref)

    return {
        "model": cfg.name, "devices": [d.name for d in devices],
        "greedy_frontier": {"points": greedy_pts,
                            "hypervolume": hv_greedy,
                            "wall_clock_s": t_greedy},
        "pgsam_frontier": {"points": pgsam_pts,
                           "hypervolume": hv_pgsam,
                           "wall_clock_s": t_pgsam},
        "hv_ref": list(ref),
        "pgsam_hv_ge_greedy": hv_pgsam >= hv_greedy,
    }


def run(verbose: bool = True) -> Dict:
    result = {
        "seed": SEED,
        "small_cases": [_small_case(*c) for c in SMALL_CASES],
        "hetero_4device": _hetero_fixture(),
    }
    result["all_within_5pct_of_oracle"] = all(
        c["pgsam_within_5pct"] for c in result["small_cases"])
    if verbose:
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: pgsam_compare.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
