"""Paper Figures 5/6: coverage scaling curves per model family — standard
(homogeneous, S samples) vs energy-aware (heterogeneous, adaptive budget)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import CoverageParams, coverage
from repro.configs.paper_models import PAPER_MODELS
from repro.models import Model
from benchmarks.common import (PAPER_TABLE16, effective_samples,
                               energy_aware_plan, fmt_table, standard_plan)

BUDGETS = (1, 2, 5, 10, 15, 20)


def run(verbose: bool = True) -> Dict:
    rows = []
    gains = []
    for name, cfg in PAPER_MODELS.items():
        p = PAPER_TABLE16[name]
        N_m = Model(cfg).param_count() / 1e6
        cov_params = CoverageParams.calibrated(N_m, target_cov=p[0] / 100.0)
        std_pc = standard_plan(cfg)
        ea = energy_aware_plan(cfg)
        boost = effective_samples(1, std_pc.energy_j / ea.energy_j)
        std_curve = [coverage(s, N_m, 256.0, cov_params) for s in BUDGETS]
        ea_curve = [coverage(s * boost, N_m, 256.0, cov_params)
                    for s in BUDGETS]
        gains.append((ea_curve[-1] - std_curve[-1]) * 100)
        rows.append([name] +
                    [f"{a * 100:.0f}/{b * 100:.0f}"
                     for a, b in zip(std_curve, ea_curve)])
    consistent = float(np.std(gains)) < 3.0
    if verbose:
        print(fmt_table(["model"] + [f"S={s}" for s in BUDGETS], rows,
                        "Figures 5/6: coverage curves, std/energy-aware (%)"))
        print(f"   gain at S=20: {[round(g, 1) for g in gains]}pp "
              f"(paper: 7-10.5pp, consistent across archs)")
    return {"gains_pp": gains, "consistent_across_models": bool(consistent),
            "mean_gain_pp": float(np.mean(gains))}
