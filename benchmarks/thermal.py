"""Paper Table 10: thermal protection — 30-minute sustained inference with and
without the theta=0.85 proactive throttle (simulated RC thermal model)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import ThermalModel, THETA_THROTTLE
from repro.core.devices import EDGE_GPU_NVIDIA
from benchmarks.common import fmt_table

PAPER = {"max_temp": (89, 72), "events": (47, 0),
         "lat_mean_std": ((1.89, 0.84), (1.41, 0.08)),
         "p99": (4.21, 1.58), "throughput": (142847, 156892)}


def _simulate(protected: bool, minutes: int = 30, dt: float = 2.0,
              seed: int = 0) -> Dict:
    """Drive the GPU at near-peak inference power; hardware throttling (when
    unprotected) halves throughput for a cooldown interval and adds latency
    jitter — the behavior the paper measures."""
    rng = np.random.default_rng(seed)
    tm = ThermalModel(EDGE_GPU_NVIDIA)
    dev = EDGE_GPU_NVIDIA
    steps = int(minutes * 60 / dt)
    base_power = 290.0
    base_lat_ms = 1.41
    lats, temps = [], []
    tokens = 0.0
    hw_throttled_until = -1.0
    events = 0
    t = 0.0
    for i in range(steps):
        t += dt
        if protected:
            speed = tm.state.throttle
        else:
            speed = 0.5 if t < hw_throttled_until else 1.0
        power = base_power * speed
        st = tm.step(power, dt)
        temps.append(st.temp_c)
        if not protected and st.temp_c >= dev.t_max - 1.0 and \
                t >= hw_throttled_until:
            events += 1
            hw_throttled_until = t + 20.0
        jitter = rng.lognormal(0, 0.03)
        lat = base_lat_ms / max(speed, 0.05) * jitter
        if not protected and t < hw_throttled_until:
            lat *= 1.0 + rng.random()      # erratic under hardware throttle
        lats.append(lat)
        tokens += dt / (lat * 1e-3) * 0.1  # 0.1 tokens per ms-slot scale
    lats = np.asarray(lats)
    return {"max_temp": float(np.max(temps)), "events": events,
            "lat_mean": float(lats.mean()), "lat_std": float(lats.std()),
            "p99": float(np.percentile(lats, 99)),
            "throughput": int(tokens)}


def run(verbose: bool = True) -> Dict:
    unprot = _simulate(protected=False)
    prot = _simulate(protected=True)
    rows = [
        ["max GPU temp C", f"{unprot['max_temp']:.0f}",
         f"{prot['max_temp']:.0f}", "89 / 72"],
        ["throttle events", unprot["events"], prot["events"], "47 / 0"],
        ["avg latency ms", f"{unprot['lat_mean']:.2f}+-{unprot['lat_std']:.2f}",
         f"{prot['lat_mean']:.2f}+-{prot['lat_std']:.2f}",
         "1.89+-0.84 / 1.41+-0.08"],
        ["latency p99 ms", f"{unprot['p99']:.2f}", f"{prot['p99']:.2f}",
         "4.21 / 1.58"],
        ["total tokens", unprot["throughput"], prot["throughput"],
         "142847 / 156892"],
    ]
    if verbose:
        print(fmt_table(["metric", "no protection", "with protection",
                         "paper (no/with)"],
                        rows, "Table 10: thermal protection, 30-min sustained"))
        print(f"   safety-first improves throughput: "
              f"{prot['throughput'] > unprot['throughput']}")
    return {
        "zero_events_with_protection": prot["events"] == 0,
        "unprotected_events": unprot["events"],
        "protection_improves_throughput":
            prot["throughput"] > unprot["throughput"],
        "protected_below_limit":
            prot["max_temp"] < EDGE_GPU_NVIDIA.t_max,
    }
