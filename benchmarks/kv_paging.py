"""Paged KV cache vs dense per-batch cache (PR 5 tentpole bench).

A seeded mixed-tier request stream where every request carries the paper's
repeated-sampling budget (k = 4 samples per prompt — the EAC/ARDE cascade
shape). Two backends see the identical stream at the *identical KV memory
budget* (same bytes; dense counts sequence slots, paged counts fixed-size
blocks):

* ``dense``  — the pre-PR backend: every repeat is prefilled independently
  and the batch holds ``B x (plen + max_new)`` rows until retirement.
* ``paged``  — `BlockAllocator` + block tables: one prefill per unique
  prompt, repeats share prefix blocks (copy-on-write at the first divergent
  token), admission priced at shared-prefix cost.

Reported per policy: prefill bytes moved (KV bytes written during prefill —
the row-linear traffic the roofline model says dominates edge prefill), the
*physical* KV high-water mark in bytes (live batches' pool arrays — paged
pools are per-batch and resident until retirement), and throughput
(requests/s over the simulated pipeline makespan; the per-batch service
model is identical for both policies, so the throughput gap is purely
admission concurrency — paged fits more requests per batch into the same
bytes). A third run adds CSVET early-stops (once one sample of a prompt
verifies, pass@k cannot change — the remaining repeats' private blocks are
released mid-flight): that frees *budget* before the donor batch's pool is
physically reclaimed, buying extra throughput at a transient physical
overcommit bounded by the released blocks (both the budget and physical
high-water marks are reported; a cross-batch shared pool — ROADMAP —
removes the overcommit).

Acceptance (seeded, CI-gated): paged moves >= 2x fewer prefill bytes at
k = 4, holds a strictly lower KV high-water mark, matches-or-beats dense
throughput at equal memory, and is token/logprob bit-identical to dense on
a pinned sub-stream; the CSVET run completes everything, frees blocks
mid-flight, never exceeds the block *budget* at admission, and its
physical overcommit stays within the early-released block count.

Run: PYTHONPATH=src python benchmarks/kv_paging.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
import time
from types import SimpleNamespace
from typing import Dict, List

import numpy as np

SEED = 0
N_REQUESTS = 16
PROMPT_LEN = 12
MAX_NEW = 8
K_SAMPLES = 4                        # repeated-sampling budget per prompt
BLOCK_SIZE = 4
TIER_MIX = (("interactive", 0.3), ("standard", 0.4), ("economy", 0.3))
# equal-memory budget: 8 dense sequence slots' worth of KV rows
BUDGET_SLOTS = 8
BUDGET_ROWS = BUDGET_SLOTS * (PROMPT_LEN + MAX_NEW)
BUDGET_BLOCKS = BUDGET_ROWS // BLOCK_SIZE
# simulated per-batch service model (identical for both policies):
# fixed pipeline overhead + per-sequence decode cost
BATCH_BASE_S = 1.0
PER_SEQ_S = 0.25

ARCH = dict(name="kv-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


class _FixedRouter:
    """Deterministic routing double: this bench measures memory/bytes/
    admission concurrency, not SLA routing (serving_schedule.py gates
    that), so every batch gets the same simulated operating point."""

    def __init__(self, tiers):
        self.tiers = {t: SimpleNamespace(name=t) for t in tiers}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        n_seqs = kw.get("samples", 1) * len(tiers)
        return SimpleNamespace(
            tier=self.resolve_tier(tiers[0]), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=float(n_seqs),
            latency_s=BATCH_BASE_S + PER_SEQ_S * n_seqs, notes=[])


def _arrivals() -> List[Dict]:
    rng = np.random.default_rng(SEED)
    names = [n for n, _ in TIER_MIX]
    probs = [p for _, p in TIER_MIX]
    t, out = 0.0, []
    for _ in range(N_REQUESTS):
        t += rng.exponential(0.5)
        out.append({"t": t, "tier": names[rng.choice(len(names), p=probs)],
                    "prompt": rng.integers(0, ARCH["vocab_size"],
                                           size=(PROMPT_LEN,)
                                           ).astype(np.int32)})
    return out


def _run_stream(paged: bool, arrivals, early_stop: bool = False,
                verbose: bool = True) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.models.cache import kv_bytes_per_token
    from repro.serving import (ContinuousBatchingScheduler, ExecutionBackend,
                               SchedulerConfig)

    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    ktb = kv_bytes_per_token(cfg, 4)            # f32 model
    if paged:
        backend = ExecutionBackend(model, params, kv_blocks=BUDGET_BLOCKS,
                                   kv_block_size=BLOCK_SIZE)
    else:
        backend = ExecutionBackend(model, params, max_slots=BUDGET_SLOTS)
    sched = ContinuousBatchingScheduler(
        backend, _FixedRouter([n for n, _ in TIER_MIX]),
        SchedulerConfig(max_batch_requests=8, max_inflight_batches=2,
                        max_new_tokens=MAX_NEW, seed=SEED))

    def kv_bytes_now() -> int:
        # *physical* footprint: paged pools are per-batch arrays resident
        # until retirement, which can exceed the allocator's budget
        # accounting after CSVET early releases — the high-water mark must
        # not hide that overcommit
        if paged:
            return backend.pool_blocks_resident * BLOCK_SIZE * ktb
        return backend.slots_in_use * (PROMPT_LEN + MAX_NEW) * ktb

    stop_rng = np.random.default_rng(SEED + 1)
    stopped: set = set()
    high_water = 0
    budget_high_water = 0
    blocks_freed_early = 0
    i = 0
    t0 = time.perf_counter()
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            adm = sched.submit(a["prompt"], tier=a["tier"],
                               n_samples=K_SAMPLES, arrival_s=a["t"])
            assert adm.admitted, adm.reason
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        sched.step()
        high_water = max(high_water, kv_bytes_now())
        if paged:
            budget_high_water = max(budget_high_water,
                                    backend.allocator.blocks_in_use
                                    * BLOCK_SIZE * ktb)
        if early_stop:
            # CSVET signal (simulated, seeded): once one sample of a prompt
            # verifies, the remaining repeats cannot change pass@k — their
            # private blocks go back to the free list mid-flight
            for entry in list(sched.inflight):
                if entry.handle.step < 2:
                    continue
                for r in entry.requests:
                    if r.id not in stopped and stop_rng.random() < 0.5:
                        stopped.add(r.id)
                        blocks_freed_early += sched.early_stop(
                            r.id, list(range(1, r.n_samples)))
    wall_s = time.perf_counter() - t0

    recs = list(sched.records)
    seqs = sum(r.n_sequences for r in recs)
    prefill_moved = seqs * PROMPT_LEN * ktb \
        - sum(r.prefill_bytes_saved for r in recs)
    out = {
        "policy": "paged" if paged else "dense",
        "early_stop": early_stop,
        "completed": len(sched.completed),
        "batches": len(recs),
        "mean_batch_requests": float(np.mean([r.n_requests for r in recs])),
        "prefill_bytes_moved": int(prefill_moved),
        "prefill_bytes_saved": int(sum(r.prefill_bytes_saved for r in recs)),
        "kv_high_water_bytes": int(high_water),       # physical footprint
        "kv_budget_high_water_bytes": int(budget_high_water if paged
                                          else high_water),
        "kv_budget_bytes": int(BUDGET_ROWS * ktb),
        "makespan_s": sched.pipeline_free_t,
        "throughput_rps": len(sched.completed) / sched.pipeline_free_t,
        "blocks_freed_early": int(blocks_freed_early),
        "wall_s": wall_s,
    }
    if verbose:
        tag = out["policy"] + ("+csvet" if early_stop else "")
        print(f"  {tag:12s} {out['batches']:2d} batches "
              f"(mean {out['mean_batch_requests']:.1f} req), "
              f"prefill {out['prefill_bytes_moved'] / 1e3:.0f} kB, "
              f"high-water {out['kv_high_water_bytes'] / 1e3:.0f} kB, "
              f"{out['throughput_rps']:.2f} req/s")
    return out


def _parity() -> bool:
    """Pinned sub-stream: paged generation (prefix sharing + CoW) must be
    token- and logprob-identical to dense."""
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.serving import ExecutionBackend

    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    rng = np.random.default_rng(SEED)
    prompts = [rng.integers(0, ARCH["vocab_size"],
                            size=(PROMPT_LEN - 1,)).astype(np.int32)
               for _ in range(2)]                  # plen % block != 0 -> CoW

    def gen(backend):
        h = backend.start_batch(prompts, K_SAMPLES, MAX_NEW, 0.8,
                                jax.random.key(42))
        while backend.decode_step(h):
            pass
        return backend.finalize(h)

    want = gen(ExecutionBackend(model, params))
    got = gen(ExecutionBackend(model, params, kv_blocks=64,
                               kv_block_size=BLOCK_SIZE))
    for a, b in zip(want, got):
        for s1, s2 in zip(a.samples, b.samples):
            if not np.array_equal(s1, s2):
                return False
        if a.logprobs != b.logprobs:
            return False
    return True


def run(verbose: bool = True) -> Dict:
    from repro.models import ArchConfig
    from repro.models.cache import kv_bytes_per_token

    ktb = kv_bytes_per_token(ArchConfig(**ARCH), 4)
    arrivals = _arrivals()
    if verbose:
        print(f"stream: {N_REQUESTS} requests x {K_SAMPLES} samples, "
              f"prompt {PROMPT_LEN} + {MAX_NEW} new, KV budget "
              f"{BUDGET_SLOTS} slots == {BUDGET_BLOCKS} blocks "
              f"of {BLOCK_SIZE}")
    dense = _run_stream(False, arrivals, verbose=verbose)
    paged = _run_stream(True, arrivals, verbose=verbose)
    csvet = _run_stream(True, arrivals, early_stop=True, verbose=verbose)
    parity_ok = _parity()

    prefill_ratio = dense["prefill_bytes_moved"] / \
        max(paged["prefill_bytes_moved"], 1)
    result = {
        "seed": SEED,
        "k_samples": K_SAMPLES,
        "kv_budget_bytes": dense["kv_budget_bytes"],
        "dense": dense,
        "paged": paged,
        "paged_csvet": csvet,
        "parity_ok": parity_ok,
        "prefill_bytes_ratio": prefill_ratio,
        "high_water_ratio": dense["kv_high_water_bytes"] /
        max(paged["kv_high_water_bytes"], 1),
        "throughput_ratio": paged["throughput_rps"] /
        dense["throughput_rps"],
        "acceptance_all": bool(
            parity_ok and
            prefill_ratio >= 2.0 and
            paged["kv_high_water_bytes"] < dense["kv_high_water_bytes"] and
            paged["throughput_rps"] >= dense["throughput_rps"] and
            paged["completed"] == dense["completed"] == N_REQUESTS and
            csvet["completed"] == N_REQUESTS and
            csvet["blocks_freed_early"] > 0 and
            # admission never exceeds the block budget...
            csvet["kv_budget_high_water_bytes"] <=
            paged["kv_budget_high_water_bytes"] and
            # ...and the transient physical overcommit (per-batch pools
            # outlive their early-released budget) is bounded by what was
            # released
            csvet["kv_high_water_bytes"] - dense["kv_budget_bytes"] <=
            csvet["blocks_freed_early"] * BLOCK_SIZE * ktb),
    }
    if verbose:
        print(f"  parity_ok={parity_ok}, prefill bytes x{prefill_ratio:.1f} "
              f"less, high-water x{result['high_water_ratio']:.2f} lower, "
              f"throughput x{result['throughput_ratio']:.2f}, "
              f"csvet freed {csvet['blocks_freed_early']} blocks early, "
              f"acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: kv_paging.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
