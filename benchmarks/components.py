"""Paper Table 4: component contribution analysis — progressively enable QEIL
features on GPT-2 and measure (pass@k, energy, IPW)."""
from __future__ import annotations

from typing import Dict

from repro.core import (Constraints, CoverageParams, GreedyOrchestrator,
                        RunMetrics, Workload, coverage, decompose,
                        homogeneous_assignment, plan_costs)
from repro.core.devices import EDGE_GPU_NVIDIA, EDGE_NPU, EDGE_PLATFORM
from repro.configs.paper_models import GPT2_125M
from repro.models import Model
from benchmarks.common import (PAPER_WORKLOAD, effective_samples, fmt_table,
                               standard_plan)

PAPER_ROWS = {
    "baseline (GPU-only)": (59.5, 43.1, 0.149),
    "+ device ranking": (61.2, 38.7, 0.178),
    "+ prefill/decode split": (65.8, 29.4, 0.412),
    "+ greedy layer assignment": (68.3, 25.1, 0.584),
    "+ adaptive sample budget": (69.2, 23.4, 0.672),
    "+ safety constraints": (70.0, 22.5, 0.718),
}


def run(verbose: bool = True) -> Dict:
    cfg = GPT2_125M
    N_m = Model(cfg).param_count() / 1e6
    cov_params = CoverageParams.calibrated(N_m, target_cov=0.595)
    w = PAPER_WORKLOAD
    w8 = Workload(batch=w.batch, prompt_tokens=w.prompt_tokens,
                  decode_tokens=w.decode_tokens, samples=w.samples,
                  bytes_per_param=1.0)
    stages = decompose(cfg, w)
    stages8 = decompose(cfg, w8)
    base = plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                      "bf16", w)
    sla = 0.95 * base.makespan_s

    plans = {}
    plans["baseline (GPU-only)"] = (base, 20.0, 1.0)

    # + device ranking: whole model on the top-ranked device that fits
    ranked = GreedyOrchestrator(EDGE_PLATFORM).ranked_devices()
    total_bytes = sum(s.param_bytes for s in stages)
    top = next(d for d in ranked if total_bytes <= d.mem_cap * 0.9)
    pc = plan_costs(stages, homogeneous_assignment(stages, top), "bf16", w)
    plans["+ device ranking"] = (pc, 20.0, 1.0)

    # + prefill/decode split: phase-level disaggregation (prefill -> GPU,
    # decode -> most energy-efficient fitting device), fp8 decode path
    mapping = {}
    for st in stages8:
        mapping[st.name] = EDGE_GPU_NVIDIA if st.phase in ("prefill", "embed",
                                                           "head") \
            else EDGE_NPU
    pc = plan_costs(stages8, mapping, "fp8", w8)
    plans["+ prefill/decode split"] = (pc, 20.0, 1.0)

    # + greedy layer assignment: the full orchestrator
    greedy = GreedyOrchestrator(EDGE_PLATFORM,
                                Constraints(latency_sla_s=sla), quant="fp8")
    a = greedy.assign(cfg, w8)
    plans["+ greedy layer assignment"] = (a.costs, 20.0, 1.0)

    # + adaptive sample budget: reinvest energy savings as samples
    s_eff = effective_samples(20, base.energy_j / a.costs.energy_j)
    plans["+ adaptive sample budget"] = (a.costs, s_eff, 1.0)

    # + safety constraints: prevents hardware thermal throttling; without it
    # the GPU duty-cycles (paper Table 10: 47 events, +9.8% effective time &
    # energy on GPU stages). Modeled as removing that penalty.
    plans["+ safety constraints"] = (a.costs, s_eff, 1.0)
    thermal_penalty = 1.098   # applied to every config EXCEPT the last

    rows, results = [], {}
    for i, (name, (pc, s_eff, _)) in enumerate(plans.items()):
        pen = 1.0 if name == "+ safety constraints" else thermal_penalty
        energy = pc.energy_j * pen
        cov = coverage(s_eff, N_m, 256.0, cov_params)
        ipw = cov / max(pc.avg_power_w, 1e-9)
        results[name] = {"coverage": cov, "energy_j": energy, "ipw": ipw}
        p = PAPER_ROWS[name]
        rows.append([name, f"{cov * 100:.1f}", f"{energy / 1e3:.2f}",
                     f"{ipw:.3f}", f"{p[0]}/{p[1]}/{p[2]}"])

    monotone_energy = all(
        results[a_]["energy_j"] >= results[b_]["energy_j"] * 0.98
        for a_, b_ in zip(list(plans), list(plans)[1:]))
    if verbose:
        print(fmt_table(["configuration", "pass@k %", "energy kJ", "IPW",
                         "paper (cov/E/IPW)"],
                        rows, "Table 4: component contribution analysis"))
        print(f"   energy monotonically decreasing: {monotone_energy}")
    return {"monotone_energy": monotone_energy,
            "final_coverage": results["+ safety constraints"]["coverage"]}
