"""Paper Table 7 / Figure 2: energy breakdown by phase, standard vs
energy-aware execution on GPT-2."""
from __future__ import annotations

from typing import Dict

from repro.configs.paper_models import GPT2_125M
from benchmarks.common import (PAPER_WORKLOAD, energy_aware_plan, fmt_table,
                               standard_plan)

PAPER = {"total": (43057.7, 22487.8, -47.8), "prefill": (12450.2, 8234.1, -33.9),
         "decode": (28892.5, 12876.4, -55.4), "overhead": (1715.0, 1377.3, -19.7)}


def run(verbose: bool = True) -> Dict:
    std = standard_plan(GPT2_125M)
    ea = energy_aware_plan(GPT2_125M)

    pe_std = std.phase_energy()
    pe_ea = ea.costs.phase_energy()

    def grp(pe):
        decode = pe.get("decode", 0.0)
        prefill = pe.get("prefill", 0.0)
        overhead = pe.get("embed", 0.0) + pe.get("head", 0.0) + \
            pe.get("transfer", 0.0)
        return {"prefill": prefill, "decode": decode, "overhead": overhead,
                "total": prefill + decode + overhead}

    g_std, g_ea = grp(pe_std), grp(pe_ea)
    rows = []
    deltas, saved = {}, {}
    for phase in ("total", "prefill", "decode", "overhead"):
        d = (g_ea[phase] / g_std[phase] - 1) * 100 if g_std[phase] else 0.0
        deltas[phase] = d
        saved[phase] = g_std[phase] - g_ea[phase]
        p = PAPER[phase]
        rows.append([phase, f"{g_std[phase]:.1f}", f"{g_ea[phase]:.1f}",
                     f"{d:+.1f}%", f"{saved[phase]:.0f} J",
                     f"{p[2]:+.1f}%"])
    # the paper's key insight is about the magnitude of decode savings —
    # decode is where most joules live, so most joules saved come from it.
    decode_dominates = saved["decode"] >= saved["prefill"]
    if verbose:
        print(fmt_table(["phase", "standard J", "energy-aware J", "delta %",
                         "saved J", "paper delta"],
                        rows, "Table 7: energy breakdown by phase (GPT-2)"))
        print(f"   decode savings dominate in joules (paper's key insight): "
              f"{decode_dominates} "
              f"({saved['decode']:.0f} J vs {saved['prefill']:.0f} J)")
    return {"deltas": deltas, "saved_j": saved,
            "decode_dominates": bool(decode_dominates)}
