"""Paper Table 5: variance across 10 independent runs (CV must stay small)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import CoverageParams, coverage, empirical_coverage, simulate_outcomes
from benchmarks.common import fmt_table, energy_aware_plan, standard_plan
from repro.configs.paper_models import GPT2_125M

PAPER = {"pass@k": (70.0, 1.17), "energy_kj": (22.5, 1.82),
         "latency_ms": (1.34, 2.24), "ipw": (0.718, 2.09),
         "power_w": (83.5, 1.49)}


def run(verbose: bool = True, n_runs: int = 10) -> Dict:
    covs, energies, lats, ipws, powers = [], [], [], [], []
    for seed in range(n_runs):
        out = simulate_outcomes(1500, 20, target_cov=0.70, seed=seed)
        cov = empirical_coverage(out, [20])[20]
        covs.append(cov * 100)
        # plan jitter: workload arrival noise perturbs the decode token count
        rng = np.random.default_rng(seed)
        jitter = 1.0 + 0.02 * rng.standard_normal()
        a = energy_aware_plan(GPT2_125M)
        energies.append(a.energy_j * jitter / 1e3)
        lats.append(a.latency_s * jitter * 1e3)
        powers.append(a.costs.avg_power_w * jitter)
        ipws.append(cov / max(a.costs.avg_power_w * jitter, 1e-9))

    rows, cvs = [], {}
    for name, vals, (pmean, pcv) in [
            ("pass@k %", covs, PAPER["pass@k"]),
            ("energy kJ", energies, PAPER["energy_kj"]),
            ("latency ms", lats, PAPER["latency_ms"]),
            ("IPW", ipws, PAPER["ipw"]),
            ("power W", powers, PAPER["power_w"])]:
        m, s = float(np.mean(vals)), float(np.std(vals))
        cv = s / m * 100 if m else 0.0
        cvs[name] = cv
        rows.append([name, f"{m:.3f}", f"{s:.3f}", f"{cv:.2f}",
                     f"{pmean} (CV {pcv}%)"])
    max_cv = max(cvs.values())
    if verbose:
        print(fmt_table(["metric", "mean", "std", "CV %", "paper"],
                        rows, f"Table 5: variance across {n_runs} runs"))
        print(f"   max CV: {max_cv:.2f}% (paper: all < 2.5%)")
    return {"max_cv_pct": max_cv, "reproducible": max_cv < 5.0}
