"""Speculative multi-token decode vs plain decode (PR 8 tentpole bench).

The same seeded mixed-tier greedy request stream is served three ways
through the paged backend, the v2-costed PGSAM router, and the
`repro.spec.SpecPlanner` (which sweeps draft depths through the router's
spec-priced workload per formed batch):

* ``off``   — plain one-token-per-step decode (the PR 5/6 baseline).
* ``ngram`` — prompt-lookup drafting: free proposals, but a random-init
  model accepts almost none of them (~1/vocab per token).
* ``draft`` — draft model == target model: the deterministic accept-rate
  fixture. Greedy verify accepts every proposal, so each verify step
  commits n + 1 tokens for roughly one token's weight-stream cost.

Reported per variant: completed requests, committed tokens per decode
forward (the architecture-level speedup — decode is memory-bound, so
forwards are the unit wall-clock is proportional to), routed v2 energy at
the planner's priced depth, IPW, and the accept rates measured from the
scheduler's "spec" trace records. After each spec variant the bench closes
the calibration loop: `CalibrationFitter` fits the measured accept rates
into a profile, the planner refreshes, and the bench re-routes one batch —
the draft fixture must keep its full depth, the ngram variant must flip
drafting off (depth 0) purely by losing the price comparison.

Acceptance (seeded, CI-gated): every variant completes the stream; greedy
speculative decode is token-identical to plain decode (logprobs allclose)
for both policies on the engine path; the draft fixture commits >= 1.5x
tokens per decode forward with energy per request no worse than ``off``;
the fitter recovers the planted accept rates (draft ~1.0, ngram low); and
the refreshed planner picks depth 0 for ngram, full depth for draft.

Run: PYTHONPATH=src python benchmarks/spec_decode.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

import numpy as np

SEED = 0
N_REQUESTS = 10
PROMPT_LEN = 16
MAX_NEW = 12
K_SAMPLES = 1
SPEC_N = 4
BLOCK_SIZE = 4
KV_BLOCKS = 160
TIER_MIX = (("interactive", 0.3), ("standard", 0.4), ("economy", 0.3))
SPEEDUP_FLOOR = 1.5            # committed tokens per decode forward, draft
LOGPROB_ATOL = 3e-5            # one verify forward vs n single-token
                               # forwards: same math, different matmul
                               # reduction order (f32 ~1e-6 per element)

ARCH = dict(name="spec-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


def _build_router():
    from repro.core import Constraints, Workload
    from repro.core.devices import EDGE_PLATFORM
    from repro.models import ArchConfig
    from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                             SLATier)

    cfg = ArchConfig(**ARCH)
    w = Workload(batch=1, prompt_tokens=PROMPT_LEN, decode_tokens=MAX_NEW,
                 samples=K_SAMPLES)
    orch = PGSAMOrchestrator(
        EDGE_PLATFORM, Constraints(latency_budget_factor=None),
        config=PGSAMConfig(seed=SEED, iters_max=1500, incremental=True),
        energy_model="v2")
    router = ParetoRouter(orch, cfg, w)
    # tiers mirror serving_schedule.py: latency caps data-driven off the
    # frontier so they are feasible by construction; economy is pure-energy,
    # which is where speculative pricing shows the starkest depth choice
    c8 = min(router.recost(a, router.batch_workload(8)).makespan_s
             for a in router.frontier)
    router.add_tier(SLATier("interactive", latency_p99_s=1.05 * c8,
                            energy_weight=0.0, latency_weight=1.0))
    router.add_tier(SLATier("standard", latency_p99_s=1.25 * c8,
                            energy_weight=0.5, latency_weight=0.5))
    router.add_tier(SLATier("economy", energy_weight=1.0,
                            latency_weight=0.0))
    return cfg, router


def _arrivals() -> List[Dict]:
    rng = np.random.default_rng(SEED)
    names = [n for n, _ in TIER_MIX]
    probs = [p for _, p in TIER_MIX]
    t, out = 0.0, []
    for _ in range(N_REQUESTS):
        t += rng.exponential(0.5)
        out.append({"t": t, "tier": names[rng.choice(len(names), p=probs)],
                    "prompt": rng.integers(0, ARCH["vocab_size"],
                                           size=(PROMPT_LEN,)
                                           ).astype(np.int32)})
    return out


def _make_policy(kind: str, model, params):
    from repro.spec import make_draft_policy
    return make_draft_policy(kind, draft_model=model, draft_params=params)


def _make_backend(cfg, model, params, policy):
    from repro.serving import ExecutionBackend
    kw = {"spec_policy": policy, "spec_n": SPEC_N} if policy else {}
    return ExecutionBackend(model, params, kv_blocks=KV_BLOCKS,
                            kv_block_size=BLOCK_SIZE, **kw)


def _generate(backend, prompts, seed: int):
    """Engine-path greedy generation: prefill + decode to completion."""
    import jax
    h = backend.start_batch(prompts, 1, MAX_NEW, 0.0, jax.random.key(seed),
                            {})
    steps = 0
    while backend.decode_step(h):
        steps += 1
    return backend.finalize(h), steps + 1


def _parity(cfg, model, params, prompts) -> Dict:
    """Greedy spec output must be token-identical to plain decode (the
    accept rule degenerates to argmax agreement + argmax correction, which
    reproduces the sequential greedy chain exactly); logprobs only match to
    reduction-order tolerance."""
    ref, _ = _generate(_make_backend(cfg, model, params, None), prompts,
                       SEED + 1)
    out = {}
    for kind in ("ngram", "draft"):
        got, _ = _generate(
            _make_backend(cfg, model, params,
                          _make_policy(kind, model, params)),
            prompts, SEED + 1)
        tokens_equal = all(
            np.array_equal(a.samples[0], b.samples[0])
            for a, b in zip(ref, got))
        lp_close = all(
            np.allclose(a.logprobs, b.logprobs, atol=LOGPROB_ATOL)
            for a, b in zip(ref, got))
        out[kind] = {"tokens_equal": bool(tokens_equal),
                     "logprobs_allclose": bool(lp_close)}
    return out


def _run_variant(kind: str, cfg, router, model, params, arrivals,
                 verbose: bool = True) -> Dict:
    from repro.qeil2.telemetry import CalibrationFitter, TraceStore
    from repro.serving import ContinuousBatchingScheduler, SchedulerConfig
    from repro.spec import SpecPlanner

    policy = _make_policy(kind, model, params) if kind != "off" else None
    backend = _make_backend(cfg, model, params, policy)
    planner = (SpecPlanner(kind, depths=(0, SPEC_N // 2, SPEC_N),
                           model_name=cfg.name) if policy else None)
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        backend, router,
        SchedulerConfig(max_batch_requests=8, max_inflight_batches=2,
                        max_new_tokens=MAX_NEW, temperature=0.0, seed=SEED),
        trace=trace, spec_planner=planner)

    # count decode forwards: in the memory-bound decode regime each forward
    # re-streams the weights once, so forwards are the bench's time unit
    decode_calls = 0
    inner = backend.decode_step

    def counted(h):
        nonlocal decode_calls
        decode_calls += 1
        return inner(h)

    backend.decode_step = counted

    i = 0
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            adm = sched.submit(a["prompt"], tier=a["tier"],
                               n_samples=K_SAMPLES, temperature=0.0,
                               arrival_s=a["t"])
            assert adm.admitted, adm.reason
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        sched.step()

    recs = list(sched.records)
    completed = len(sched.completed)
    n_seqs = completed * K_SAMPLES
    total_tokens = n_seqs * MAX_NEW
    # the first token of every sequence is sampled at prefill; the rest are
    # committed by decode forwards
    tps = (total_tokens - n_seqs) / max(decode_calls, 1)
    energy = sum(r.energy_j for r in recs)
    proposed = sum(r.spec_proposed for r in recs)
    accepted = sum(r.spec_accepted for r in recs)
    depths = sorted({r.spec_n for r in recs})

    out = {
        "policy": kind,
        "completed": completed,
        "batches": len(recs),
        "decode_forwards": int(decode_calls),
        "tokens_per_forward": float(tps),
        "energy_j": float(energy),
        "energy_per_request_j": float(energy / max(completed, 1)),
        "ipw": completed / energy,
        "proposed": int(proposed),
        "accepted": int(accepted),
        "accept_rate": (accepted / proposed) if proposed else None,
        "routed_depths": depths,
        "leaks": int(backend.allocator.blocks_in_use),
    }
    if policy is not None:
        # close the loop: fit the measured accept rates, refresh the
        # planner, and re-route one economy batch at the fitted rate
        profile, _ = CalibrationFitter(trace, n_bootstrap=0).fit()
        planner.refresh(profile)
        fitted = planner.accept_rate_for("economy")
        d = planner.route_batch(router, ["economy"] * 4, samples=K_SAMPLES,
                                prompt_tokens=PROMPT_LEN,
                                decode_tokens=MAX_NEW)
        out["fitted_accept_rate"] = float(fitted)
        out["refit_depth"] = int(d.spec.n)
    if verbose:
        rate = (f"{out['accept_rate']:.2f}" if out["accept_rate"] is not None
                else "-")
        refit = (f", refit a={out['fitted_accept_rate']:.2f} -> "
                 f"n={out['refit_depth']}" if policy is not None else "")
        print(f"  {kind:5s}: {completed} done in {len(recs)} batches, "
              f"{decode_calls} decode forwards "
              f"({tps:.2f} tok/fwd), E={energy:.3f} J, "
              f"accept={rate}{refit}")
    return out


def run(verbose: bool = True) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model

    cfg, router = _build_router()
    model = Model(ArchConfig(**ARCH), dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    arrivals = _arrivals()
    if verbose:
        print(f"stream: {N_REQUESTS} greedy requests, prompt {PROMPT_LEN} + "
              f"{MAX_NEW} new, draft depth {SPEC_N}, paged KV "
              f"{KV_BLOCKS}x{BLOCK_SIZE}")

    parity = _parity(cfg, model, params,
                     [a["prompt"] for a in arrivals[:4]])
    if verbose:
        for kind, p in parity.items():
            print(f"  parity {kind:5s}: tokens_equal={p['tokens_equal']} "
                  f"logprobs_allclose={p['logprobs_allclose']}")

    by_kind = {}
    for kind in ("off", "ngram", "draft"):
        by_kind[kind] = _run_variant(kind, cfg, router, model, params,
                                     arrivals, verbose=verbose)

    off, ng, dr = by_kind["off"], by_kind["ngram"], by_kind["draft"]
    speedup = dr["tokens_per_forward"] / off["tokens_per_forward"]
    result = {
        "seed": SEED,
        "spec_n": SPEC_N,
        "parity": parity,
        "variants": by_kind,
        "tokens_per_forward_ratio": float(speedup),
        "energy_ratio_draft": dr["energy_per_request_j"] /
        off["energy_per_request_j"],
        "acceptance_all": bool(
            all(v["completed"] == N_REQUESTS for v in by_kind.values()) and
            all(v["leaks"] == 0 for v in by_kind.values()) and
            all(p["tokens_equal"] and p["logprobs_allclose"]
                for p in parity.values()) and
            speedup >= SPEEDUP_FLOOR and
            dr["energy_per_request_j"] <= off["energy_per_request_j"] *
            (1 + 1e-9) and
            dr["ipw"] >= off["ipw"] and
            dr["accept_rate"] is not None and dr["accept_rate"] > 0.99 and
            ng["accept_rate"] is not None and ng["accept_rate"] < 0.3 and
            dr["fitted_accept_rate"] > 0.99 and
            ng["fitted_accept_rate"] < 0.3 and
            dr["refit_depth"] == SPEC_N and
            ng["refit_depth"] == 0),
    }
    if verbose:
        print(f"  draft commits x{speedup:.2f} tokens/forward vs off, "
              f"energy/req x{result['energy_ratio_draft']:.3f}, "
              f"acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: spec_decode.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
