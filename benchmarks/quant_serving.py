"""Quantized serving vs bf16 at an equal KV byte budget (PR 6 tentpole bench).

The same seeded mixed-tier request stream (the kv_paging shape: every request
carries k = 4 repeated samples) is served three ways, all through the paged
backend and the v2-costed router:

* ``bf16``  — full-precision weights, bf16 paged KV (the PR 5 baseline).
* ``int8``  — per-channel int8 weights (fused dequant-matmul via
  `repro.models.layers.dense` dispatch) + int8 paged KV: half the cache
  bytes per token slot, so the same byte budget buys ~2x the block budget.
* ``int4``  — group-wise int4 weights + int8 KV: the paper's headline
  efficiency point (4-bit weights are where its best IPW lands).

Every variant's router is a fixed-device v2 coster: each formed batch is
decomposed (`repro.core.decomposition` with the variant's re-priced
`Workload` — packed weight bytes, 1-byte KV elements) and costed with
``plan_costs(model="v2", quant=fmt)``, so batch energy reflects both the
byte reduction (DASI/roofline time) and the paper's f(Q) power factor.
Quality is a deterministic fixed-batch NLL delta against the bf16 model on
identical token batches — no sampling in the quality probe.

Reported per variant: completed requests, KV block budget + high-water at
the equal byte budget, total v2 batch energy, IPW (completed inferences per
joule), and NLL delta. Acceptance (seeded, CI-gated): every variant
completes the stream; the int8-KV block budget is >= 1.8x bf16's at equal
bytes (pos metadata keeps it shy of exactly 2x); int4 beats bf16 IPW while
holding the NLL quality floor; v2 energy is strictly monotone decreasing
bf16 > int8 > int4; and the serve trace records carry the quant formats.

Run: PYTHONPATH=src python benchmarks/quant_serving.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
from types import SimpleNamespace
from typing import Dict, List

import numpy as np

SEED = 0
N_REQUESTS = 12
PROMPT_LEN = 12
MAX_NEW = 8
K_SAMPLES = 4
BLOCK_SIZE = 4
GROUP_SIZE = 16
TIER_MIX = (("interactive", 0.3), ("standard", 0.4), ("economy", 0.3))
# equal KV byte budget across variants, denominated in bf16 blocks
BUDGET_BLOCKS_BF16 = 24
# quality floor: quantized fixed-batch NLL may not drift more than this
# from bf16 (random-init tiny model; NLL approx log(vocab) = 4.2 nats)
QUALITY_FLOOR_NLL = {"int8": 0.05, "int4": 0.35}

ARCH = dict(name="quant-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)

VARIANTS = (("bf16", "bf16"), ("int8", "int8"), ("int4", "int8"))


class _V2Router:
    """Fixed-device router double that costs every formed batch with the v2
    energy model at the variant's quantized byte prices. Routing policy is
    out of scope here (serving_schedule.py gates that); what this bench
    needs is decision.energy_j/latency_s moving with the quant format, and
    real ``batch_costs`` so `plan_signals` feeds the trace."""

    def __init__(self, cfg, fmt: str, kv_format: str):
        from repro.core.devices import TPU_V5E
        self.cfg = cfg
        self.fmt = fmt
        self.kv_format = kv_format
        self.device = TPU_V5E
        self.tiers = {t: SimpleNamespace(name=t) for t, _ in TIER_MIX}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, *, samples=1, prompt_tokens=PROMPT_LEN,
                    decode_tokens=MAX_NEW, **kw):
        from repro.core.decomposition import Workload, decompose
        from repro.core.energy import plan_costs
        from repro.quant import quant_workload

        wl = quant_workload(
            Workload(batch=len(tiers), prompt_tokens=prompt_tokens,
                     decode_tokens=decode_tokens, samples=samples),
            self.fmt, kv_format=self.kv_format)
        stages = decompose(self.cfg, wl)
        assignment = {st.name: self.device for st in stages}
        costs = plan_costs(stages, assignment, quant=self.fmt, workload=wl,
                           model="v2")
        return SimpleNamespace(
            tier=self.resolve_tier(tiers[0]), tier_counts={},
            assignment=assignment, point_index=0, meets_caps=True,
            batch_costs=costs, energy_j=costs.energy_j,
            latency_s=costs.makespan_s, notes=[])


def _arrivals() -> List[Dict]:
    rng = np.random.default_rng(SEED)
    names = [n for n, _ in TIER_MIX]
    probs = [p for _, p in TIER_MIX]
    t, out = 0.0, []
    for _ in range(N_REQUESTS):
        t += rng.exponential(0.5)
        out.append({"t": t, "tier": names[rng.choice(len(names), p=probs)],
                    "prompt": rng.integers(0, ARCH["vocab_size"],
                                           size=(PROMPT_LEN,)
                                           ).astype(np.int32)})
    return out


def _kv_token_bytes(cfg, kv_format: str) -> int:
    from repro.models.cache import kv_bytes_per_token
    return kv_bytes_per_token(cfg, 1 if kv_format == "int8" else 2)


def _nll(model, params, batch) -> float:
    return float(model.loss(params, batch))


def _quality_batch(cfg, n_codebooks_vocab: int):
    rng = np.random.default_rng(SEED + 7)
    toks = rng.integers(0, n_codebooks_vocab, size=(4, 24)).astype(np.int32)
    import jax.numpy as jnp
    tokens = jnp.asarray(toks)
    pos = jnp.broadcast_to(jnp.arange(24, dtype=jnp.int32)[None], (4, 24))
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:],
            "positions": pos[:, :-1]}


def _run_variant(fmt: str, kv_format: str, arrivals, nll_ref: float,
                 verbose: bool = True) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.qeil2.telemetry import TraceStore
    from repro.quant import param_bytes, quantize_model
    from repro.serving import (ContinuousBatchingScheduler, ExecutionBackend,
                               SchedulerConfig)

    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.bfloat16)
    params = model.init(jax.random.key(SEED))
    qparams = quantize_model(params, fmt, GROUP_SIZE) \
        if fmt != "bf16" else params

    # equal byte budget: bf16's block budget in bytes, re-denominated in
    # this variant's (possibly int8) KV blocks
    budget_bytes = BUDGET_BLOCKS_BF16 * BLOCK_SIZE * _kv_token_bytes(cfg,
                                                                     "bf16")
    kv_blocks = budget_bytes // (BLOCK_SIZE * _kv_token_bytes(cfg, kv_format))
    backend = ExecutionBackend(model, qparams, kv_blocks=int(kv_blocks),
                               kv_block_size=BLOCK_SIZE, kv_format=kv_format)
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        backend, _V2Router(cfg, fmt, kv_format),
        SchedulerConfig(max_batch_requests=8, max_inflight_batches=2,
                        max_new_tokens=MAX_NEW, seed=SEED),
        trace=trace)

    block_high_water = 0
    i = 0
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            adm = sched.submit(a["prompt"], tier=a["tier"],
                               n_samples=K_SAMPLES, arrival_s=a["t"])
            assert adm.admitted, adm.reason
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        sched.step()
        block_high_water = max(block_high_water,
                               backend.allocator.blocks_in_use)

    recs = list(sched.records)
    energy = sum(r.energy_j for r in recs)
    completed = len(sched.completed)
    nll = _nll(model, qparams, _quality_batch(cfg, ARCH["vocab_size"]))
    serve_recs = trace.records("serve")
    out = {
        "fmt": fmt,
        "kv_format": kv_format,
        "completed": completed,
        "batches": len(recs),
        "weight_bytes": int(param_bytes(qparams)),
        "kv_blocks": int(kv_blocks),
        "kv_block_high_water": int(block_high_water),
        "kv_token_bytes": int(backend.kv_token_bytes),
        "energy_j": float(energy),
        "ipw": completed / energy,
        "nll": nll,
        "nll_delta": abs(nll - nll_ref),
        "makespan_s": sched.pipeline_free_t,
        "trace_quants": sorted(list(pair) for pair in
                               {(r["quant"], r["kv_format"])
                                for r in serve_recs}),
        "trace_has_bytes": all("weight_bytes" in r and "kv_bytes_in_use" in r
                               for r in serve_recs),
    }
    if verbose:
        print(f"  {fmt:5s}/{kv_format}-kv: {completed} done in "
              f"{out['batches']} batches, weights "
              f"{out['weight_bytes'] / 1e3:.0f} kB, blocks "
              f"{out['kv_blocks']} (hw {out['kv_block_high_water']}), "
              f"E={energy:.3f} J, IPW={out['ipw']:.2f}, "
              f"dNLL={out['nll_delta']:.4f}")
    return out


def run(verbose: bool = True) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model

    arrivals = _arrivals()
    cfg = ArchConfig(**ARCH)
    model = Model(cfg, dtype=jnp.bfloat16)
    params = model.init(jax.random.key(SEED))
    nll_ref = _nll(model, params, _quality_batch(cfg, ARCH["vocab_size"]))
    if verbose:
        print(f"stream: {N_REQUESTS} requests x {K_SAMPLES} samples, "
              f"prompt {PROMPT_LEN} + {MAX_NEW} new, budget "
              f"{BUDGET_BLOCKS_BF16} bf16 blocks of {BLOCK_SIZE} "
              f"(ref NLL {nll_ref:.4f})")

    by_fmt = {}
    for fmt, kvf in VARIANTS:
        by_fmt[fmt] = _run_variant(fmt, kvf, arrivals, nll_ref,
                                   verbose=verbose)

    bf16, i8, i4 = by_fmt["bf16"], by_fmt["int8"], by_fmt["int4"]
    blocks_ratio = i8["kv_blocks"] / bf16["kv_blocks"]
    result = {
        "seed": SEED,
        "k_samples": K_SAMPLES,
        "group_size": GROUP_SIZE,
        "variants": by_fmt,
        "kv_blocks_ratio": blocks_ratio,
        "ipw_ratio_int4": i4["ipw"] / bf16["ipw"],
        "acceptance_all": bool(
            all(v["completed"] == N_REQUESTS for v in by_fmt.values()) and
            blocks_ratio >= 1.8 and
            i4["ipw"] > bf16["ipw"] and
            i8["nll_delta"] <= QUALITY_FLOOR_NLL["int8"] and
            i4["nll_delta"] <= QUALITY_FLOOR_NLL["int4"] and
            bf16["energy_j"] > i8["energy_j"] > i4["energy_j"] and
            i8["weight_bytes"] < bf16["weight_bytes"] and
            i4["weight_bytes"] < i8["weight_bytes"] and
            i8["trace_quants"] == [["int8", "int8"]] and
            i4["trace_quants"] == [["int4", "int8"]] and
            all(v["trace_has_bytes"] for v in by_fmt.values())),
    }
    if verbose:
        print(f"  int8-KV block budget x{blocks_ratio:.2f}, "
              f"int4 IPW x{result['ipw_ratio_int4']:.2f} vs bf16, "
              f"energy {bf16['energy_j']:.3f} > {i8['energy_j']:.3f} > "
              f"{i4['energy_j']:.3f} J, "
              f"acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: quant_serving.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
