"""Paper Table 8 / Figure 3: latency breakdown, CPU-only vs heterogeneous
CPU-GPU-NPU execution."""
from __future__ import annotations

from typing import Dict

from repro.core import decompose, homogeneous_assignment, plan_costs
from repro.core.devices import EDGE_CPU
from repro.configs.paper_models import GPT2_125M
from benchmarks.common import PAPER_WORKLOAD, energy_aware_plan, fmt_table

PAPER = {"compute": (18.2, 7.2, -60.4), "transfer": (2.1, 0.9, -57.1),
         "controller": (0.4, 0.5, +25.0), "total": (20.7, 8.6, -58.5)}


def run(verbose: bool = True) -> Dict:
    w = PAPER_WORKLOAD
    stages = decompose(GPT2_125M, w)
    cpu = plan_costs(stages, homogeneous_assignment(stages, EDGE_CPU),
                     "bf16", w)
    het = energy_aware_plan(GPT2_125M, w).costs

    # controller overhead: the paper's orchestration coordination cost —
    # modeled per Formalism 3 as const + a*log(S), zero for single-device.
    import math
    ctrl_cpu = 2e-4 * w.samples * w.batch
    ctrl_het = (2e-4 + 5e-5 * math.log(w.samples)) * w.samples * w.batch * 1.25

    unit = 1e3  # report in ms over the whole query set / 1e3 for readability
    rows, result = [], {}
    for name, t_cpu, t_het, p in [
            ("compute", cpu.makespan_s - cpu.transfer_time_s,
             het.makespan_s - het.transfer_time_s, PAPER["compute"]),
            ("memory transfer", cpu.transfer_time_s, het.transfer_time_s,
             PAPER["transfer"]),
            ("controller overhead", ctrl_cpu, ctrl_het, PAPER["controller"]),
    ]:
        d = (t_het / t_cpu - 1) * 100 if t_cpu else float("inf")
        rows.append([name, f"{t_cpu * unit:.1f}", f"{t_het * unit:.1f}",
                     f"{d:+.1f}%", f"{p[2]:+.1f}%"])
        result[name] = d
    tot_cpu = cpu.makespan_s + ctrl_cpu
    tot_het = het.makespan_s + ctrl_het
    d_tot = (tot_het / tot_cpu - 1) * 100
    rows.append(["TOTAL", f"{tot_cpu * unit:.1f}", f"{tot_het * unit:.1f}",
                 f"{d_tot:+.1f}%", f"{PAPER['total'][2]:+.1f}%"])
    if verbose:
        print(fmt_table(["component", "CPU-only ms", "heterogeneous ms",
                         "delta", "paper delta"],
                        rows, "Table 8: latency breakdown (x1000 queries)"))
    return {"total_delta_pct": d_tot,
            "heterogeneous_faster": d_tot < 0,
            "controller_overhead_added": result["controller overhead"] > 0}
