"""Benchmark harness entry point: one function per paper table.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) after the
human-readable tables. ``PYTHONPATH=src python -m benchmarks.run``.
"""
from __future__ import annotations

import sys
import time

BENCHES = [
    ("table1_beta_stability", "benchmarks.beta_stability", "mean_beta"),
    ("table3_heterogeneity", "benchmarks.heterogeneity", "coverage_gain_pp"),
    ("table4_components", "benchmarks.components", "final_coverage"),
    ("table5_variance", "benchmarks.variance", "max_cv_pct"),
    ("table7_energy_breakdown", "benchmarks.energy_breakdown",
     "decode_dominates"),
    ("table8_latency_breakdown", "benchmarks.latency_breakdown",
     "total_delta_pct"),
    ("table10_thermal", "benchmarks.thermal",
     "zero_events_with_protection"),
    ("table11_fault_tolerance", "benchmarks.fault_tolerance",
     "all_recovered"),
    ("table12_adversarial", "benchmarks.adversarial",
     "all_structural_blocked"),
    ("tables13_15_cross_dataset", "benchmarks.cross_dataset",
     "task_agnostic"),
    ("table16_main_results", "benchmarks.main_results",
     "energy_reduced_all"),
    ("sec5_5_edge_vs_cloud", "benchmarks.edge_vs_cloud",
     "edge_wins_small_models"),
    ("fig5_6_coverage_curves", "benchmarks.coverage_curves",
     "mean_gain_pp"),
    ("roofline_table", "benchmarks.roofline_table", "n_analyzed"),
    ("kernel_bench", "benchmarks.kernel_bench", "flash_attention_us"),
    ("pgsam_compare", "benchmarks.pgsam_compare",
     "all_within_5pct_of_oracle"),
    ("pareto_router", "benchmarks.pareto_router", "acceptance_all"),
    ("calibration_report", "benchmarks.calibration_report",
     "acceptance_all"),
    ("serving_schedule", "benchmarks.serving_schedule",
     "acceptance_all"),
    ("kv_paging", "benchmarks.kv_paging", "acceptance_all"),
    ("quant_serving", "benchmarks.quant_serving", "acceptance_all"),
    ("spec_decode", "benchmarks.spec_decode", "acceptance_all"),
    ("prefix_pool", "benchmarks.prefix_pool", "acceptance_all"),
    ("preemption", "benchmarks.preemption", "acceptance_all"),
    ("bench_compare", "benchmarks.compare", "self_check_ok"),
]


def main() -> None:
    import importlib
    csv_lines = ["name,us_per_call,derived"]
    failures = []
    for name, module, key in BENCHES:
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(module)
            result = mod.run(verbose=True)
            derived = result.get(key, "")
        except Exception as e:  # keep the harness going; report at the end
            import traceback
            traceback.print_exc()
            failures.append(name)
            derived = f"ERROR:{e!r}"
        us = (time.perf_counter() - t0) * 1e6
        csv_lines.append(f"{name},{us:.0f},{derived}")

    print("\n" + "\n".join(csv_lines))
    if failures:
        print(f"\nFAILED BENCHES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
