"""Mixed-tier continuous batching vs per-call routing (PR 4 tentpole bench).

A seeded Poisson request stream on the paper's 4-device edge platform: three
SLA tiers (interactive / standard / economy) arrive interleaved at an
offered load sized to overload *per-call* serving. Two policies see the
identical stream:

* ``scheduler`` — the scheduler-centric stack: tier-aware admission,
  mixed-tier batches routed to one shared operating point (`route_batch`
  re-costs every frontier point under the batch workload, so decode
  weight-streaming amortization is priced into feasibility), prefill/decode
  interleaving over the real execution backend (tiny model, this
  container's CPU). Latencies are simulated (operating-point makespans on a
  serialized pipeline) — the same clock the SLA caps are defined on.
* ``per_call`` — the pre-refactor world: every request is its own
  `generate` call at its tier's `route()` operating point, serialized in
  arrival order (what `RoutedServingEngine` did before it became a shim).

Reported per policy: throughput (requests/s over the simulated makespan),
per-tier p95 latency (queue delay + service), and IPW (served sequences per
joule). Acceptance: the scheduler beats per-call routing on throughput at
equal-or-better per-tier p95 latency, with equal-or-better IPW — batching
amortizes the decode weight re-streaming that dominates edge inference
energy, which is exactly the paper's repeated-sampling amortization argument
lifted from one call to the whole request stream.

Everything except wall-clock is seeded and reproducible.

The bench doubles as the observability overhead gate: after the official
(obs-off) run, the identical stream replays twice more over the same warm
backend — once instrumented (`backend.set_obs`), once not — and the result's
``obs`` section reports (a) bit-parity of sampled tokens/logprobs between the
off and on runs (instrumentation must not perturb the RNG stream), (b) span
lifecycle completeness (every request reconstructs admit -> queue ->
schedule -> prefill -> decode -> release), and (c) the relative wall-clock
overhead of running instrumented, gated at <5% in CI.

Run: PYTHONPATH=src python benchmarks/serving_schedule.py \
         [--out FILE] [--spans-out FILE] [--metrics-out FILE]
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

SEED = 0
N_REQUESTS = 48
PROMPT_LEN = 12
MAX_NEW = 8
SAMPLES = 2
TIER_MIX = (("interactive", 0.3), ("standard", 0.4), ("economy", 0.3))
# offered load relative to per-call capacity at the standard tier's
# operating point: > 1 means per-call serving cannot keep up
OFFERED_LOAD = 1.6

ARCH = dict(name="sched-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


def _build_router():
    from repro.core import Constraints, Workload
    from repro.core.devices import EDGE_PLATFORM
    from repro.models import ArchConfig
    from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                             SLATier)

    cfg = ArchConfig(**ARCH)
    w = Workload(batch=1, prompt_tokens=PROMPT_LEN, decode_tokens=MAX_NEW,
                 samples=SAMPLES)
    orch = PGSAMOrchestrator(
        EDGE_PLATFORM, Constraints(latency_budget_factor=None),
        config=PGSAMConfig(seed=SEED, iters_max=1500, incremental=True),
        energy_model="v2")
    router = ParetoRouter(orch, cfg, w)
    # caps are data-driven off the frontier so they are feasible by
    # construction at moderate batch sizes: interactive admits batches of
    # ~4 at the fastest point, standard of ~8 — a tight-SLA member caps how
    # much batching its batch absorbs (the scheduler's shrink loop)
    c4 = min(router.recost(a, router.batch_workload(4)).makespan_s
             for a in router.frontier)
    c8 = min(router.recost(a, router.batch_workload(8)).makespan_s
             for a in router.frontier)
    router.add_tier(SLATier("interactive", latency_p99_s=1.01 * c4,
                            energy_weight=0.0, latency_weight=1.0))
    router.add_tier(SLATier("standard", latency_p99_s=1.05 * c8,
                            energy_weight=0.5, latency_weight=0.5))
    router.add_tier(SLATier("economy", energy_weight=1.0,
                            latency_weight=0.0))
    return cfg, w, router


def _arrivals(router) -> List[Dict]:
    """Seeded Poisson stream; rate sized against per-call standard-tier
    service time so per-call serving runs at OFFERED_LOAD utilization."""
    rng = np.random.default_rng(SEED)
    svc = router.recost(router.route("standard").assignment,
                        router.batch_workload(1)).makespan_s
    rate = OFFERED_LOAD / svc
    names = [n for n, _ in TIER_MIX]
    probs = [p for _, p in TIER_MIX]
    t = 0.0
    out = []
    for _ in range(N_REQUESTS):
        t += rng.exponential(1.0 / rate)
        out.append({"t": t, "tier": names[rng.choice(len(names), p=probs)],
                    "prompt": rng.integers(
                        0, ARCH["vocab_size"],
                        size=(PROMPT_LEN,)).astype(np.int32)})
    return out


def _percentiles(lat: Dict[str, List[float]]) -> Dict[str, float]:
    return {t: float(np.percentile(v, 95)) for t, v in sorted(lat.items())}


def _make_backend(cfg):
    import jax
    import jax.numpy as jnp
    from repro.models import Model
    from repro.serving import ExecutionBackend

    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    return ExecutionBackend(model, params)


def _drive(sched, arrivals) -> float:
    """Replay the stream through the scheduler; returns wall seconds spent."""
    t0 = time.perf_counter()
    i = 0
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            sched.submit(a["prompt"], tier=a["tier"], n_samples=SAMPLES,
                         arrival_s=a["t"])
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        sched.step()
    return time.perf_counter() - t0


def _sampled(sched) -> Dict[int, Tuple]:
    """Bit-parity fingerprint: per request, sampled tokens + logprobs."""
    return {rid: ([s.tolist() for s in c.result.samples],
                  [float(lp) for lp in c.result.logprobs])
            for rid, c in sched.completed.items()}


def _run_scheduler(cfg, router, arrivals, verbose: bool, backend=None,
                   obs=None) -> Tuple[Dict, "object"]:
    from repro.qeil2 import TraceStore
    from repro.serving import ContinuousBatchingScheduler, SchedulerConfig

    if backend is None:
        backend = _make_backend(cfg)
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        backend, router,
        SchedulerConfig(max_batch_requests=8, max_inflight_batches=2,
                        max_new_tokens=MAX_NEW, seed=SEED), trace=trace,
        obs=obs)
    wall_s = _drive(sched, arrivals)

    s = sched.stats()
    out = {
        "completed": s["completed"],
        "batches": s["batches"],
        "mean_batch_requests": s["mean_batch_requests"],
        "caps_met_fraction": s["caps_met_fraction"],
        "throughput_rps": s["completed"] / s["makespan_s"],
        "p95_latency_s": s["latency_p95_s"],
        "energy_j": s["energy_j"],
        "ipw_seq_per_j": s["sequences"] / max(s["energy_j"], 1e-12),
        "serve_trace_records": len(trace.records("serve")),
        "wall_s": wall_s,
    }
    if verbose:
        print(f"  scheduler: {out['batches']} batches "
              f"(mean {out['mean_batch_requests']:.1f} req/batch), "
              f"{out['throughput_rps']:.1f} req/s, "
              f"ipw={out['ipw_seq_per_j']:.3f} seq/J, "
              f"caps met {out['caps_met_fraction']:.0%}")
    return out, sched


def _run_per_call(router, arrivals, verbose: bool) -> Dict:
    """Analytic per-call baseline: each request served alone at its tier's
    routed point, serialized in arrival order (identical cost model)."""
    free = 0.0
    energy = 0.0
    lat: Dict[str, List[float]] = {}
    for a in arrivals:
        d = router.route(a["tier"])
        costs = router.recost(d.assignment, router.batch_workload(1))
        start = max(a["t"], free)
        free = start + costs.makespan_s
        energy += costs.energy_j
        lat.setdefault(a["tier"], []).append(free - a["t"])
    n = len(arrivals)
    out = {
        "completed": n,
        "throughput_rps": n / free,
        "p95_latency_s": _percentiles(lat),
        "energy_j": energy,
        "ipw_seq_per_j": n * SAMPLES / max(energy, 1e-12),
    }
    if verbose:
        print(f"  per_call:  serialized, {out['throughput_rps']:.1f} req/s, "
              f"ipw={out['ipw_seq_per_j']:.3f} seq/J")
    return out


def _run_obs_gate(cfg, router, arrivals, backend, reference: Dict[int, Tuple],
                  verbose: bool) -> Tuple[Dict, "object"]:
    """Replay the stream twice over the warm shared backend — obs off then
    obs on (`backend.set_obs` flips instrumentation without cold jit) — and
    gate parity, lifecycle completeness, and relative overhead."""
    from repro.obs import lifecycles_complete, make_observability

    wall_off = []
    wall_on = []
    obs_sched = None
    for rep in range(2):                      # interleave off/on, take mins
        off_out, _ = _run_scheduler(cfg, router, arrivals, False,
                                    backend=backend)
        wall_off.append(off_out["wall_s"])
        obs = make_observability()
        backend.set_obs(obs)
        try:
            on_out, obs_sched = _run_scheduler(cfg, router, arrivals, False,
                                               backend=backend, obs=obs)
        finally:
            from repro.obs import NULL_OBS
            backend.set_obs(NULL_OBS)
        wall_on.append(on_out["wall_s"])

    tracer = obs_sched.obs.tracer
    parity_ok = _sampled(obs_sched) == reference
    life_ok = lifecycles_complete(tracer.spans,
                                  expect_requests=len(reference))
    t_off, t_on = min(wall_off), min(wall_on)
    overhead = t_on / t_off - 1.0
    gate = {
        "parity_ok": bool(parity_ok),
        "span_lifecycle_ok": bool(life_ok),
        "n_spans": len(tracer),
        "wall_off_s": t_off,
        "wall_on_s": t_on,
        "overhead_frac": overhead,
        "overhead_ok": bool(overhead < 0.05),
    }
    if verbose:
        print(f"  obs gate:  parity={parity_ok} lifecycle={life_ok} "
              f"spans={len(tracer)} overhead={overhead:+.1%} "
              f"(off {t_off:.2f}s / on {t_on:.2f}s)")
    return gate, obs_sched


def run(verbose: bool = True) -> Dict:
    cfg, _w, router = _build_router()
    arrivals = _arrivals(router)
    if verbose:
        mix = {}
        for a in arrivals:
            mix[a["tier"]] = mix.get(a["tier"], 0) + 1
        print(f"stream: {N_REQUESTS} requests, tier mix {mix}, "
              f"offered load {OFFERED_LOAD}x per-call capacity")
    backend = _make_backend(cfg)
    sched, sched_obj = _run_scheduler(cfg, router, arrivals, verbose,
                                      backend=backend)
    base = _run_per_call(router, arrivals, verbose)
    obs_gate, obs_sched = _run_obs_gate(cfg, router, arrivals, backend,
                                        _sampled(sched_obj), verbose)
    run._obs_sched = obs_sched        # artifact hook for __main__

    tiers = sorted(base["p95_latency_s"])
    p95_ok = {t: sched["p95_latency_s"][t] <= base["p95_latency_s"][t] *
              (1 + 1e-9) for t in tiers}
    result = {
        "seed": SEED,
        "n_requests": N_REQUESTS,
        "offered_load": OFFERED_LOAD,
        "scheduler": sched,
        "per_call": base,
        "throughput_ratio": sched["throughput_rps"] / base["throughput_rps"],
        "ipw_ratio": sched["ipw_seq_per_j"] / base["ipw_seq_per_j"],
        "p95_no_worse": p95_ok,
        "obs": obs_gate,
        # overhead_ok is wall-clock (noisy on shared runners) so it gates a
        # separate CI assert, not the seeded acceptance bit
        "acceptance_all": bool(
            sched["throughput_rps"] > base["throughput_rps"] and
            all(p95_ok.values()) and
            sched["ipw_seq_per_j"] >= base["ipw_seq_per_j"] and
            sched["completed"] == N_REQUESTS and
            obs_gate["parity_ok"] and obs_gate["span_lifecycle_ok"]),
    }
    if verbose:
        for t in tiers:
            print(f"  p95[{t:12s}] scheduler {sched['p95_latency_s'][t]:.4f}s"
                  f" vs per-call {base['p95_latency_s'][t]:.4f}s "
                  f"ok={p95_ok[t]}")
        print(f"  throughput x{result['throughput_ratio']:.2f}, "
              f"ipw x{result['ipw_ratio']:.2f}, "
              f"acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


def _flag(name: str) -> Optional[str]:
    if name not in sys.argv:
        return None
    idx = sys.argv.index(name) + 1
    if idx >= len(sys.argv):
        sys.exit("usage: serving_schedule.py [--out FILE] "
                 "[--spans-out FILE] [--metrics-out FILE]")
    return sys.argv[idx]


if __name__ == "__main__":
    out_path = _flag("--out")
    spans_path = _flag("--spans-out")
    metrics_path = _flag("--metrics-out")
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
    obs_sched = getattr(run, "_obs_sched", None)
    if spans_path and obs_sched is not None:
        obs_sched.obs.tracer.save(spans_path)
        print(f"wrote {spans_path}", file=sys.stderr)
    if metrics_path and obs_sched is not None:
        obs_sched.obs.metrics.write(metrics_path)
        print(f"wrote {metrics_path}", file=sys.stderr)
