"""Shared benchmark utilities: table rendering, timing, the paper's reference
numbers, and the standard QEIL workload used across tables."""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, List, Sequence

from repro.core import (Constraints, GreedyOrchestrator, Workload, decompose,
                        homogeneous_assignment, plan_costs)
from repro.core.devices import (EDGE_CPU, EDGE_GPU_INTEL, EDGE_GPU_NVIDIA,
                                EDGE_NPU, EDGE_PLATFORM)
from repro.configs.paper_models import PAPER_MODELS

# The paper's benchmark scale: WikiText-style eval with S=20 samples, T=256
# decode tokens, averaged prompt 128 tokens, per-query; tables report totals
# over the full query set.
N_QUERIES = 500
PAPER_WORKLOAD = Workload(batch=N_QUERIES, prompt_tokens=128,
                          decode_tokens=256, samples=20)

# Table 16 reference values: model -> (std pass@k %, ea pass@k %,
#   std energy kJ, ea energy kJ, std power W, ea power W, std lat ms, ea lat ms)
PAPER_TABLE16 = {
    "gpt2-125m": (59.5, 70.0, 43.1, 22.5, 402.5, 83.5, 1.73, 1.34),
    "granite-350m": (61.0, 70.0, 403.1, 88.0, 460.4, 82.3, 1.69, 1.41),
    "qwen2-0.5b": (56.0, 66.5, 352.3, 187.9, 244.7, 74.4, 1.76, 1.62),
    "llama-3.2-1b": (63.0, 70.0, 330.5, 213.0, 164.5, 79.0, 1.91, 1.66),
    "lfm2-2.6b": (62.0, 70.0, 490.3, 314.3, 175.8, 75.0, 1.86, 1.51),
}


def fmt_table(headers: Sequence[str], rows: List[Sequence], title: str = ""
              ) -> str:
    cols = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
            else len(str(h)) for i, h in enumerate(headers)]
    out = []
    if title:
        out.append(f"\n== {title} ==")
    out.append("  ".join(str(h).ljust(c) for h, c in zip(headers, cols)))
    out.append("  ".join("-" * c for c in cols))
    for r in rows:
        out.append("  ".join(str(v).ljust(c) for v, c in zip(r, cols)))
    return "\n".join(out)


@contextmanager
def timed(record: Dict):
    t0 = time.perf_counter()
    yield
    record["us_per_call"] = (time.perf_counter() - t0) * 1e6


def standard_plan(cfg, workload=PAPER_WORKLOAD, quant="bf16"):
    """Paper's 'standard' execution: homogeneous NVIDIA GPU."""
    stages = decompose(cfg, workload)
    return plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                      quant, workload)


def energy_aware_plan(cfg, workload=PAPER_WORKLOAD, quant="fp8",
                      latency_sla_s=None):
    """Paper's 'energy-aware' execution: QEIL greedy heterogeneous
    orchestration with fp8 quantization (halved weight/KV bytes — this is
    what lets memory-bound decode spread off the GPU without violating the
    latency budget). The latency budget defaults to 95% of the *standard*
    (bf16 homogeneous GPU) makespan, so the plan must beat the baseline on
    both axes."""
    w8 = Workload(batch=workload.batch, prompt_tokens=workload.prompt_tokens,
                  decode_tokens=workload.decode_tokens,
                  samples=workload.samples, bytes_per_param=1.0,
                  bytes_per_act=workload.bytes_per_act)
    if latency_sla_s is None:
        latency_sla_s = 0.95 * standard_plan(cfg, workload).makespan_s
    orch = GreedyOrchestrator(EDGE_PLATFORM,
                              Constraints(latency_sla_s=latency_sla_s),
                              quant=quant)
    return orch.assign(cfg, w8)


# Adaptive sample budget (paper Table 4's "+ Adaptive Sample Budget"): the
# orchestrator reinvests a conservative fraction of the per-sample energy
# saving as extra samples; full reinvestment would blow the latency SLA.
REINVEST_FRACTION = 0.5
S_EFF_CAP = 2.5


def effective_samples(S: int, energy_ratio: float) -> float:
    gain = min(max(energy_ratio, 1.0), S_EFF_CAP) - 1.0
    return S * (1.0 + REINVEST_FRACTION * gain)
