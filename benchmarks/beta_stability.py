"""Paper Table 1 + Table 2: scaling-exponent beta stability across the five
model families, with bootstrap CIs and sample-range sensitivity."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import empirical_coverage, fit_power_law, simulate_outcomes
from benchmarks.common import PAPER_TABLE16, fmt_table

PAPER_BETAS = {"gpt2-125m": (0.68, (0.64, 0.72), 0.994),
               "granite-350m": (0.71, (0.67, 0.75), 0.991),
               "qwen2-0.5b": (0.69, (0.65, 0.73), 0.993),
               "llama-3.2-1b": (0.72, (0.68, 0.76), 0.996),
               "lfm2-2.6b": (0.70, (0.66, 0.74), 0.995)}


def run(verbose: bool = True, include_real: bool = True) -> Dict:
    rows: List = []
    betas = []
    for i, (model, refs) in enumerate(PAPER_TABLE16.items()):
        target = refs[1] / 100.0       # energy-aware pass@20
        out = simulate_outcomes(n_tasks=1500, n_samples=20,
                                target_cov=target, seed=100 + i)
        ks = [1, 2, 5, 10, 15, 20]
        cov = empirical_coverage(out, ks)
        fit = fit_power_law(ks, [cov[k] for k in ks], n_bootstrap=1000,
                            seed=i)
        betas.append(fit.beta)
        pb, pci, pr2 = PAPER_BETAS[model]
        rows.append([model, f"{fit.beta:.2f}",
                     f"[{fit.beta_ci[0]:.2f}, {fit.beta_ci[1]:.2f}]",
                     f"{fit.r2:.3f}", f"{pb:.2f}",
                     f"[{pci[0]:.2f}, {pci[1]:.2f}]", f"{pr2:.3f}"])
    mean_beta = float(np.mean(betas))
    rows.append(["MEAN", f"{mean_beta:.2f}", "", "", "0.70", "", "0.994"])

    # Table 2: sensitivity to sample-budget range
    sens_rows = []
    out_big = simulate_outcomes(n_tasks=1500, n_samples=100, target_cov=0.70,
                                seed=100)
    for lo, hi in [(1, 10), (1, 20), (5, 50), (10, 100)]:
        ks = sorted({k for k in (lo, lo * 2, (lo + hi) // 2,
                                 int(hi * 0.75), hi)})
        cov = empirical_coverage(out_big, ks)
        fit = fit_power_law(ks, [cov[k] for k in ks], n_bootstrap=0)
        sens_rows.append([f"S in [{lo}, {hi}]", f"{fit.beta:.2f}"])

    # REAL-model validation: train a tiny model on the verifiable arithmetic
    # task, sample with the actual serving engine, fit beta from genuine
    # pass@k outcomes (not simulation). Coverage is high (easy task), so the
    # curve is in its saturation regime; we check the fit machinery and the
    # monotone saturating shape rather than the 0.7 exponent itself.
    real = _real_model_fit() if include_real else None

    if verbose:
        print(fmt_table(
            ["model", "beta (ours)", "95% CI (ours)", "R2 (ours)",
             "beta (paper)", "95% CI (paper)", "R2 (paper)"],
            rows, "Table 1: scaling exponent stability"))
        print(fmt_table(["sample range", "beta"], sens_rows,
                        "Table 2: sensitivity to sample-budget range"))
        if real is not None:
            print(f"\n   REAL sampling run (tiny model, arith task): "
                  f"beta={real['beta']:.2f} R2={real['r2']:.3f} "
                  f"cov@16={real['cov16']:.2f} (saturation regime)")
    out = {"mean_beta": mean_beta, "betas": betas,
           "in_paper_band": bool(0.64 <= mean_beta <= 0.76)}
    if real is not None:
        out["real_run"] = real
    return out


def _real_model_fit():
    import jax
    import jax.numpy as jnp
    from repro.core import run_pass_at_k, fit_power_law
    from repro.data import ArithGenerator, DataConfig, data_iterator
    from repro.models import ArchConfig, Model
    from repro.serving import ServingEngine
    from repro.training import AdamWConfig, train

    cfg = ArchConfig(name="arith-beta", arch_type="dense", n_layers=2,
                     d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
                     vocab_size=16)
    model = Model(cfg, dtype=jnp.float32)
    dc = DataConfig(vocab_size=16, seq_len=24, batch_size=32, kind="arith")
    params, _ = train(model, AdamWConfig(lr=3e-3, warmup_steps=10,
                                         total_steps=100),
                      data_iterator(dc), 100)
    gen = ArithGenerator(dc)
    engine = ServingEngine(model, params, max_new_tokens=2, temperature=1.3)
    rng = np.random.default_rng(0)
    tasks = [gen.make_prompt(rng) for _ in range(24)]
    tasks = [(p, (lambda s, a=a: gen.verify(s, a))) for p, a in tasks]
    res = run_pass_at_k(engine, tasks, n_samples=16, budgets=(1, 2, 4, 8, 16))
    ks = sorted(res.coverage_by_k)
    fit = fit_power_law(ks, [res.coverage_by_k[k] for k in ks],
                        n_bootstrap=200)
    return {"beta": fit.beta, "r2": fit.r2,
            "cov16": res.coverage_by_k[16]}
