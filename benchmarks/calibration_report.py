"""Calibration loop end-to-end on the synthetic trace fixture (PR 3 bench).

Ground truth -> traces -> fit -> runtime feedback, all seeded:

1. generate the synthetic trace fixture (known true coefficients deliberately
   off the documented defaults, lognormal measurement noise);
2. fit a `CalibrationProfile` with `CalibrationFitter` (bounded least squares
   + bootstrap CIs);
3. check the acceptance properties the PR gates on:
   * **identity parity** — `plan_costs(model="v2")` with an identity-profile
     provider is bit-identical to the providerless path;
   * **residuals** — fitted coefficients reduce energy-prediction RMSE vs the
     documented defaults, and every fitted coefficient carries a bootstrap CI;
   * **recovery** — each fitted coefficient lands closer to ground truth than
     its default (the fit moved for the right reason, not just overfit);
   * **runtime feedback** — a PGSAM anneal under the fitted provider produces
     longer (measured-kernel) makespans than the analytic anneal, and every
     calibrated DASI stays in [0, 1].

Everything except wall-clock is seeded and reproducible.

Run: PYTHONPATH=src python benchmarks/calibration_report.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict

from repro.configs.paper_models import GPT2_125M
from repro.core import Constraints, Workload, decompose, plan_costs
from repro.core.devices import EDGE_PLATFORM
from repro.qeil2 import (CalibratedSignalProvider, CalibrationFitter,
                         CalibrationProfile, PGSAMConfig, PGSAMOrchestrator,
                         synthetic_trace_store)
from repro.qeil2.telemetry.fit import COEF_NAMES, COEF_DEFAULTS
from repro.qeil2.telemetry.synthetic import TRUE_COEFFS, TRUE_KERNEL_ETA
try:
    from benchmarks.common import fmt_table
except ModuleNotFoundError:      # run as a script: benchmarks/ is sys.path[0]
    from common import fmt_table

SEED = 0
N_BOOTSTRAP = 200
W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
UNCONSTRAINED = Constraints(latency_budget_factor=None)


def _identity_parity() -> bool:
    """plan_costs(model='v2') must be bit-identical under an identity
    provider: same energy, same makespan, same per-stage joules."""
    stages = decompose(GPT2_125M, W)
    assign = {st.name: EDGE_PLATFORM[i % len(EDGE_PLATFORM)]
              for i, st in enumerate(stages)}
    temps = {d.name: 40.0 + 7.0 * i for i, d in enumerate(EDGE_PLATFORM)}
    base = plan_costs(stages, assign, workload=W, model="v2", temps=temps)
    ident = plan_costs(stages, assign, workload=W, model="v2", temps=temps,
                       provider=CalibratedSignalProvider(
                           CalibrationProfile.identity()))
    return (base.energy_j == ident.energy_j and
            base.makespan_s == ident.makespan_s and
            all(a.energy_j == b.energy_j and a.time_s == b.time_s
                for a, b in zip(base.executions, ident.executions)))


def run(verbose: bool = True) -> Dict:
    t0 = time.perf_counter()
    store = synthetic_trace_store(seed=SEED)
    fitter = CalibrationFitter(store, n_bootstrap=N_BOOTSTRAP, seed=SEED)
    profile, report = fitter.fit()
    fit_wall_s = time.perf_counter() - t0

    # --- acceptance properties ---------------------------------------------
    identity_parity = _identity_parity()
    rmse_improved = report.rmse_fitted < report.rmse_default

    truth = dict(TRUE_COEFFS)
    recovery = {}
    for j, name in enumerate(COEF_NAMES):
        fitted = report.coefficients[name]["fitted"]
        recovery[name] = (abs(fitted - truth[name]) <
                          abs(COEF_DEFAULTS[j] - truth[name]))
    for name, true_eta in TRUE_KERNEL_ETA.items():
        fitted = report.kernel_eta[name]["fitted"]
        recovery[f"eta:{name}"] = (abs(fitted - true_eta) <
                                   abs(1.0 - true_eta))
    coefficients_recovered = all(recovery.values())

    all_cis = all(len(row["ci"]) == 2 and row["ci"][0] <= row["ci"][1]
                  for row in list(report.coefficients.values()) +
                  list(report.kernel_eta.values()))

    # --- runtime feedback: anneal under the fitted provider ----------------
    provider = CalibratedSignalProvider(profile)
    t1 = time.perf_counter()
    analytic = PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED, config=PGSAMConfig(seed=SEED,
                                                         iters_max=600),
        energy_model="v2").assign(GPT2_125M, W)
    calibrated = PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED, config=PGSAMConfig(seed=SEED,
                                                         iters_max=600),
        energy_model="v2", provider=provider).assign(GPT2_125M, W)
    anneal_wall_s = time.perf_counter() - t1
    # measured kernels are slower than the roofline (eta < 1), so the
    # calibrated anneal's best plan must report a longer makespan
    measured_makespan_longer = (calibrated.latency_s > analytic.latency_s)

    dasi_in_bounds = True
    for st in decompose(GPT2_125M, W):
        for dev in EDGE_PLATFORM:
            d = provider.dasi(st, dev)
            if not (0.0 <= d <= 1.0):
                dasi_in_bounds = False

    result = {
        "seed": SEED,
        "n_bootstrap": N_BOOTSTRAP,
        "trace_counts": store.counts(),
        "report": report.to_dict(),
        "profile": profile.to_dict(),
        "true_coefficients": {**truth,
                              **{f"eta:{k}": v
                                 for k, v in TRUE_KERNEL_ETA.items()}},
        "recovery": recovery,
        "identity_parity": identity_parity,
        "rmse_improved": rmse_improved,
        "coefficients_recovered": coefficients_recovered,
        "all_cis_present": all_cis,
        "measured_makespan_longer": measured_makespan_longer,
        "dasi_in_bounds": dasi_in_bounds,
        "analytic_makespan_s": analytic.latency_s,
        "calibrated_makespan_s": calibrated.latency_s,
        "fit_wall_s": round(fit_wall_s, 3),
        "anneal_wall_s": round(anneal_wall_s, 3),
    }
    result["acceptance_all"] = all([
        identity_parity, rmse_improved, coefficients_recovered, all_cis,
        measured_makespan_longer, dasi_in_bounds])

    if verbose:
        rows = []
        for j, name in enumerate(COEF_NAMES):
            row = report.coefficients[name]
            rows.append([name, f"{row['default']:.4g}",
                         f"{truth[name]:.4g}", f"{row['fitted']:.4g}",
                         f"[{row['ci'][0]:.3g}, {row['ci'][1]:.3g}]",
                         "yes" if recovery[name] else "NO"])
        for name, true_eta in sorted(TRUE_KERNEL_ETA.items()):
            row = report.kernel_eta[name]
            rows.append([f"eta:{name}", "1", f"{true_eta:.4g}",
                         f"{row['fitted']:.4g}",
                         f"[{row['ci'][0]:.3g}, {row['ci'][1]:.3g}]",
                         "yes" if recovery[f'eta:{name}'] else "NO"])
        print(fmt_table(
            ["coefficient", "default", "truth", "fitted", "bootstrap CI",
             "recovered"],
            rows, "Calibration fit vs ground truth (synthetic fixture)"))
        print(f"\nlog-energy RMSE: defaults {report.rmse_default:.4f} -> "
              f"fitted {report.rmse_fitted:.4f} "
              f"({report.improvement_pct:.1f}% lower)")
        print(f"identity parity: {identity_parity}   "
              f"makespan analytic {analytic.latency_s:.4g}s -> "
              f"calibrated {calibrated.latency_s:.4g}s")
        print(f"acceptance_all: {result['acceptance_all']}")
    return result


def main() -> None:
    out = None
    args = sys.argv[1:]
    if "--out" in args:
        i = args.index("--out")
        if i + 1 >= len(args):
            raise SystemExit("usage: calibration_report.py [--out FILE]")
        out = args[i + 1]
    result = run(verbose=True)
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    if not result["acceptance_all"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
