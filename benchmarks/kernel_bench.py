"""Kernel microbenchmarks: interpret-mode us/call on CPU (correctness-path
cost) + modeled TPU v5e roofline time for the production shapes each kernel
serves."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import TPU_V5E
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.ssd_scan.ops import ssd_chunk
from benchmarks.common import fmt_table


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def _tpu_roofline_us(flops: float, bytes_moved: float) -> float:
    t = max(flops / (TPU_V5E.peak_flops * TPU_V5E.util),
            bytes_moved / (TPU_V5E.mem_bw * TPU_V5E.util))
    return t * 1e6


def run(verbose: bool = True) -> Dict:
    rows = []
    results = {}

    # flash attention: one prefill tile set (small CPU shape; model the 32k)
    B, S, H, D = 1, 256, 4, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    us = _time(flash_attention_pallas, q, k, v, block_q=128, block_k=128)
    # production shape: qwen2-72b prefill_32k per chip slice
    Sp, Hp = 32768, 4  # heads per chip after sharding
    fl = 4.0 * Sp * Sp / 2 * Hp * 128
    by = (3 * Sp * Hp * 128) * 2
    rows.append(["flash_attention", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl, by):.0f} (32k tile/chip)"])
    results["flash_attention_us"] = us

    # decode attention: cache streaming
    W = 1024
    kc = jax.random.normal(ks[1], (2, W, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, W, 2, 64), jnp.float32)
    qd = jax.random.normal(ks[0], (2, 1, 4, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(W)[None], (2, W)).astype(jnp.int32)
    qpos = jnp.full((2,), W - 1, jnp.int32)
    us = _time(decode_attention_pallas, qd, kc, vc, pos, qpos, block_k=256)
    fl_d = 4.0 * 32768 * 8 * 128 * 8   # decode_32k per chip: 8 batch x kv8
    by_d = 32768 * 2 * 8 * 128 * 2 * 8
    rows.append(["decode_attention", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl_d, by_d):.0f} (32k cache/chip)"])
    results["decode_attention_us"] = us

    # ssd chunk
    Bh, nc, Q, P, N = 2, 4, 64, 32, 64
    x = jax.random.normal(ks[0], (2, nc, Q, 2, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, nc, Q, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)))
    dA = dt * A[None, None, None]
    dAcs = jnp.cumsum(dA, axis=2)
    Bm = jax.random.normal(ks[1], (2, nc, Q, 2, N), jnp.float32)
    Cm = jax.random.normal(ks[2], (2, nc, Q, 2, N), jnp.float32)
    us = _time(ssd_chunk, x, dt, dA, dAcs, Bm, Cm)
    # mamba2-370m prefill_32k per chip: 32 heads/16 = 2 heads x 32k tokens
    fl_s = 2 * (32768 / 256) * (2 * 256 * 256 * (64 + 128))
    by_s = 2 * 32768 * (64 + 2 * 128) * 4
    rows.append(["ssd_scan", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl_s, by_s):.0f} (32k scan/chip)"])
    results["ssd_scan_us"] = us

    if verbose:
        print(fmt_table(["kernel", "interpret us/call",
                         "modeled TPU us (prod shape)"],
                        rows, "Kernel microbenchmarks"))
    return results
