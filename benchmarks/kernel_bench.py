"""Kernel microbenchmarks: interpret-mode us/call on CPU (correctness-path
cost) + modeled TPU v5e roofline time for the production shapes each kernel
serves.

Besides the human-readable table, ``run()`` emits machine-readable per-kernel
trace records (one per timing rep: flops, bytes, measured us, roofline us)
suitable for `repro.qeil2.telemetry.TraceStore.ingest` — the measurement side
of the calibration loop. ``python benchmarks/kernel_bench.py --out FILE``
appends them to a JSONL trace directly.

Note the honesty caveat carried in each record's ``backend`` field: the
measured numbers here are CPU interpret-mode timings while the roofline is
the modeled TPU time, so the implied duty factor eta = roofline/measured is
meaningful only when both sides describe the same silicon (the fitter clamps
eta to (0, 1]; real calibration feeds records measured on the target device).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import TPU_V5E
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.ssd_scan.ops import ssd_chunk
try:
    from benchmarks.common import fmt_table
except ModuleNotFoundError:      # run as a script: benchmarks/ is sys.path[0]
    from common import fmt_table


def _time_reps(fn, *args, n=3, **kw) -> List[float]:
    """Per-rep us/call (warm call excluded) — reps feed the bootstrap CI of
    the fitted per-kernel duty factor."""
    fn(*args, **kw)  # warm
    out = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        out.append((time.perf_counter() - t0) * 1e6)
    return out


def _tpu_roofline_us(flops: float, bytes_moved: float) -> float:
    t = max(flops / (TPU_V5E.peak_flops * TPU_V5E.util),
            bytes_moved / (TPU_V5E.mem_bw * TPU_V5E.util))
    return t * 1e6


def _records(kernel: str, reps: List[float], flops: float,
             bytes_moved: float, quant: str = "fp32") -> List[dict]:
    # quant stamps the numeric format the kernel ran at; the calibration
    # fitter keys quantized formats as "<kernel>:<quant>" (full-precision
    # records keep the bare kernel name — see telemetry.fit._eta_key)
    roofline = _tpu_roofline_us(flops, bytes_moved)
    return [{"kind": "kernel", "kernel": kernel, "rep": i,
             "flops": flops, "bytes": bytes_moved,
             "measured_us": us, "roofline_us": roofline,
             "device": TPU_V5E.name, "backend": "cpu-interpret",
             "quant": quant}
            for i, us in enumerate(reps)]


def run(verbose: bool = True) -> Dict:
    rows = []
    results: Dict = {"records": []}

    # flash attention: one prefill tile set (small CPU shape; model the 32k)
    B, S, H, D = 1, 256, 4, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    reps = _time_reps(flash_attention_pallas, q, k, v,
                      block_q=128, block_k=128)
    us = float(np.mean(reps))
    # measured shape's analytic costs (causal: half the score matrix)
    fl_m = 4.0 * B * S * S / 2 * H * D
    by_m = 4 * B * S * H * D * 4                    # q,k,v,out fp32
    results["records"] += _records("flash_attention", reps, fl_m, by_m)
    # production shape: qwen2-72b prefill_32k per chip slice
    Sp, Hp = 32768, 4  # heads per chip after sharding
    fl = 4.0 * Sp * Sp / 2 * Hp * 128
    by = (3 * Sp * Hp * 128) * 2
    rows.append(["flash_attention", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl, by):.0f} (32k tile/chip)"])
    results["flash_attention_us"] = us

    # decode attention: cache streaming
    W = 1024
    kc = jax.random.normal(ks[1], (2, W, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, W, 2, 64), jnp.float32)
    qd = jax.random.normal(ks[0], (2, 1, 4, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(W)[None], (2, W)).astype(jnp.int32)
    qpos = jnp.full((2,), W - 1, jnp.int32)
    reps = _time_reps(decode_attention_pallas, qd, kc, vc, pos, qpos,
                      block_k=256)
    us = float(np.mean(reps))
    fl_m = 4.0 * 2 * W * 4 * 64                     # QK^T + PV over the cache
    by_m = 2 * W * 2 * 64 * 2 * 4                   # k+v cache fp32 streams
    results["records"] += _records("decode_attention", reps, fl_m, by_m)
    fl_d = 4.0 * 32768 * 8 * 128 * 8   # decode_32k per chip: 8 batch x kv8
    by_d = 32768 * 2 * 8 * 128 * 2 * 8
    rows.append(["decode_attention", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl_d, by_d):.0f} (32k cache/chip)"])
    results["decode_attention_us"] = us

    # ssd chunk
    Bh, nc, Q, P, N = 2, 4, 64, 32, 64
    x = jax.random.normal(ks[0], (2, nc, Q, 2, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, nc, Q, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)))
    dA = dt * A[None, None, None]
    dAcs = jnp.cumsum(dA, axis=2)
    Bm = jax.random.normal(ks[1], (2, nc, Q, 2, N), jnp.float32)
    Cm = jax.random.normal(ks[2], (2, nc, Q, 2, N), jnp.float32)
    reps = _time_reps(ssd_chunk, x, dt, dA, dAcs, Bm, Cm)
    us = float(np.mean(reps))
    fl_m = 2 * nc * (2 * Q * Q * 2 * (P + N))       # chunked scan matmuls
    by_m = 2 * nc * Q * 2 * (P + 2 * N) * 4
    results["records"] += _records("ssd_scan", reps, fl_m, by_m)
    # mamba2-370m prefill_32k per chip: 32 heads/16 = 2 heads x 32k tokens
    fl_s = 2 * (32768 / 256) * (2 * 256 * 256 * (64 + 128))
    by_s = 2 * 32768 * (64 + 2 * 128) * 4
    rows.append(["ssd_scan", f"{us:.0f}",
                 f"{_tpu_roofline_us(fl_s, by_s):.0f} (32k scan/chip)"])
    results["ssd_scan_us"] = us

    # fused dequant-matmul: weight streaming at packed bytes (repro.quant)
    from repro.kernels.dequant_matmul.dequant_matmul import (
        dequant_matmul_int4_pallas, dequant_matmul_int8_pallas)
    from repro.quant import quantize_int4, quantize_int8
    M, Kd, Nd = 8, 256, 256
    xq = jax.random.normal(ks[0], (M, Kd), jnp.float32)
    wq = jax.random.normal(ks[1], (Kd, Nd), jnp.float32)
    fl_q = 2.0 * M * Kd * Nd
    # production shape: one llama-8b-class 4096x4096 decode projection
    fl_p = 2.0 * 8 * 4096 * 4096
    for fmt, quantize, kern, wbytes, wbytes_p in (
            ("int8", quantize_int8, dequant_matmul_int8_pallas,
             Kd * Nd, 4096 * 4096),
            ("int4", lambda w: quantize_int4(w, 32),
             dequant_matmul_int4_pallas, Kd * Nd // 2, 4096 * 4096 // 2)):
        qw, sc = quantize(wq)
        reps = _time_reps(kern, xq, qw, sc, interpret=True)
        us = float(np.mean(reps))
        by_q = wbytes + sc.size * 4 + (M * Kd + M * Nd) * 4
        results["records"] += _records("dequant_matmul", reps, fl_q, by_q,
                                       quant=fmt)
        by_p = wbytes_p + 8 * 2 * 4096 * 2
        rows.append([f"dequant_matmul[{fmt}]", f"{us:.0f}",
                     f"{_tpu_roofline_us(fl_p, by_p):.0f} (4k proj/chip)"])
        results[f"dequant_matmul_{fmt}_us"] = us

    if verbose:
        print(fmt_table(["kernel", "interpret us/call",
                         "modeled TPU us (prod shape)"],
                        rows, "Kernel microbenchmarks"))
        print(f"{len(results['records'])} trace records "
              f"(TraceStore-ingestible)")
    return results


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="append per-rep kernel records to this JSONL trace")
    args = ap.parse_args()
    results = run(verbose=True)
    if args.out:
        from repro.qeil2.telemetry import TraceStore
        store = TraceStore(path=args.out)
        n = store.ingest_kernel_bench(results)
        print(f"appended {n} kernel records -> {args.out}")


if __name__ == "__main__":
    main()
