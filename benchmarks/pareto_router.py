"""Pareto-routed serving runtime vs static placement (PR 2 tentpole bench).

A synthetic diurnal day on the paper's 4-device edge platform: offered load
swings sinusoidally, two exogenous thermal ramps heat the NVIDIA GPU (peak
hours) and then the CPU (a co-located batch job), and one device fails
mid-run and later recovers. Three policies see the *identical* schedule:

* ``router``       — the closed control loop (orchestrate -> execute ->
                     heat -> re-orchestrate): drift events trigger bounded
                     warm-started re-anneals, hot devices cool outside the
                     placement pool.
* ``static_pgsam`` — the same PGSAM operating point, frozen at t=0 (PR 1's
                     world: the frontier as a one-shot artifact).
* ``greedy``       — the v1 greedy plan, frozen at t=0.

Reported per policy: IPW (served inferences per joule — numerically equal
to sustained inferences/second per watt), hardware-throttle events, served
fraction, re-anneal count and wall-clock. The second section times the
`DeltaEvaluator` incremental path against the full `plan_costs` path on a
50-stage / 8-device anneal and checks objective parity.

Everything except wall-clock is seeded and reproducible.

Run: PYTHONPATH=src python benchmarks/pareto_router.py [--out FILE]
"""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Dict, List

import numpy as np

from repro.configs.paper_models import GPT2_125M
from repro.core import (Constraints, GreedyOrchestrator, SafetyMonitor,
                        Workload, decompose)
from repro.core.devices import EDGE_PLATFORM
from repro.models import ArchConfig
from repro.qeil2 import (ControlLoop, LoopConfig, PGSAMConfig,
                         PGSAMOrchestrator)
from repro.qeil2.runtime.incremental import DeltaEvaluator

SEED = 0
STEPS = 120
DT_S = 5.0
LOAD_BASE, LOAD_SWING = 1.0, 0.8          # diurnal: 0.2 .. 1.8x
GPU = "nvidia-rtx-pro-5000"
CPU = "intel-core-ultra9-285hx"
# Exogenous heat (co-located processes / enclosure): sized so that ramp +
# idle stays below T_max (the adaptive loop can always save the device by
# shedding its load) while ramp + idle + the static plan's dynamic draw
# crosses T_max near the diurnal peak (static placement cannot).
GPU_RAMP = (25, 52, 255.0)                # steps [a, b): +W exogenous heat
CPU_RAMP = (95, 112, 50.0)
FAULT_AT, RECOVER_AT = 62, 100

W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
# tight-ish SLA: keeps real work (and therefore watts) on the big GPU
SLA = Constraints(latency_sla_s=0.15)


def _load(i: int) -> float:
    return LOAD_BASE + LOAD_SWING * math.sin(2 * math.pi * i / STEPS)


def _extra_power(i: int) -> Dict[str, float]:
    out: Dict[str, float] = {}
    a, b, watts = GPU_RAMP
    if a <= i < b:
        out[GPU] = watts
    a, b, watts = CPU_RAMP
    if a <= i < b:
        out[CPU] = out.get(CPU, 0.0) + watts
    return out


def _simulate(name: str, orch, adaptive: bool, verbose: bool) -> Dict:
    safety = SafetyMonitor(EDGE_PLATFORM)
    if hasattr(orch, "safety"):
        orch.safety = safety           # hot-aware v2 re-anneals
    loop = ControlLoop(orch, safety, GPT2_125M, W,
                       LoopConfig(dt_s=DT_S, reanneal_iters=400,
                                  adaptive=adaptive))
    inferences = energy = 0.0
    served_steps = 0
    fault_dev = None
    max_temp: Dict[str, float] = {}
    for i in range(STEPS):
        if i == FAULT_AT:
            # fail a device the *initial* plan actually uses (prefer one
            # that is not the thermal-ramp target, so the two disturbances
            # stay distinguishable in the telemetry)
            used = (loop.assignment.device_names()
                    if loop.assignment and loop.assignment.mapping else [])
            cands = [d for d in used if d != GPU] or used or [CPU]
            fault_dev = cands[0]
            safety.health.fail_device(fault_dev, now_s=loop.t_s)
        if i == RECOVER_AT and fault_dev is not None:
            safety.health.recover_device(fault_dev)
        r = loop.step(load=_load(i), extra_power=_extra_power(i))
        inferences += r.inferences
        energy += r.energy_j
        served_steps += int(r.served)
        for dev, t in r.temps.items():
            max_temp[dev] = max(max_temp.get(dev, 0.0), t)
    events = safety.total_throttle_events()
    out = {
        "policy": name,
        "inferences": round(inferences, 1),
        "energy_kj": round(energy / 1e3, 3),
        "ipw_inf_per_j": inferences / max(energy, 1e-9),
        "throttle_events": events,
        "served_fraction": served_steps / STEPS,
        "reanneals": loop.reanneals,
        "reanneal_wall_s": round(loop.reanneal_wall_s, 3),
        "fault_device": fault_dev,
        "max_temp_c": {d: round(t, 1) for d, t in sorted(max_temp.items())},
    }
    if verbose:
        print(f"  {name:14s} inf={out['inferences']:>9} "
              f"E={out['energy_kj']:>7.2f} kJ "
              f"ipw={out['ipw_inf_per_j']:.4f} "
              f"events={events} served={out['served_fraction']:.2f} "
              f"reanneals={out['reanneals']} "
              f"({out['reanneal_wall_s']:.2f}s)")
    return out


def _diurnal(verbose: bool) -> Dict:
    if verbose:
        print(f"diurnal: {STEPS} steps x {DT_S:.0f}s, load "
              f"{LOAD_BASE - LOAD_SWING:.1f}..{LOAD_BASE + LOAD_SWING:.1f}x, "
              f"GPU ramp +{GPU_RAMP[2]:.0f}W @[{GPU_RAMP[0]},{GPU_RAMP[1]}), "
              f"CPU ramp +{CPU_RAMP[2]:.0f}W @[{CPU_RAMP[0]},{CPU_RAMP[1]}), "
              f"fault @{FAULT_AT} recover @{RECOVER_AT}")

    def pgsam():
        return PGSAMOrchestrator(
            EDGE_PLATFORM, SLA,
            config=PGSAMConfig(seed=SEED, incremental=True),
            energy_model="v2")

    policies = {
        "router": _simulate("router", pgsam(), adaptive=True,
                            verbose=verbose),
        "static_pgsam": _simulate("static_pgsam", pgsam(), adaptive=False,
                                  verbose=verbose),
        "greedy": _simulate("greedy", GreedyOrchestrator(EDGE_PLATFORM, SLA),
                            adaptive=False, verbose=verbose),
    }
    router, static = policies["router"], policies["static_pgsam"]
    return {
        "steps": STEPS, "dt_s": DT_S,
        "load": [LOAD_BASE - LOAD_SWING, LOAD_BASE + LOAD_SWING],
        "policies": policies,
        "router_zero_throttle": router["throttle_events"] == 0,
        "static_throttle_events": static["throttle_events"],
        "router_ipw_over_static": (router["ipw_inf_per_j"] /
                                   max(static["ipw_inf_per_j"], 1e-12)),
    }


# ------------------------------------------------- incremental evaluation

DELTA_CFG = ArchConfig(name="bench-24l", arch_type="dense", n_layers=24,
                       d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                       vocab_size=1000)
DELTA_W = Workload(batch=1, prompt_tokens=64, decode_tokens=64, samples=4)
DELTA_ITERS = 3000


def _delta_evaluator(verbose: bool) -> Dict:
    devices = EDGE_PLATFORM + [d.with_overrides(name=d.name + "-b")
                               for d in EDGE_PLATFORM]
    stages = decompose(DELTA_CFG, DELTA_W)
    unconstrained = Constraints(latency_budget_factor=None)
    walls = {}
    energies = {}
    for inc in (False, True):
        cfg = PGSAMConfig(seed=SEED, iters_max=DELTA_ITERS,
                          hv_patience=10 ** 9, incremental=inc)
        orch = PGSAMOrchestrator(devices, unconstrained, config=cfg,
                                 energy_model="v2")
        t0 = time.perf_counter()
        a = orch.assign(DELTA_CFG, DELTA_W)
        walls[inc] = time.perf_counter() - t0
        energies[inc] = a.energy_j

    # parity: incremental objectives vs full plan_costs over random moves
    from repro.core import plan_costs
    rng = np.random.default_rng(SEED)
    mapping = list(rng.integers(0, len(devices), len(stages)))
    ev = DeltaEvaluator(stages, devices, mapping, "bf16", DELTA_W,
                        model="v2")
    worst = 0.0
    for _ in range(300):
        si = int(rng.integers(len(stages)))
        di = int(rng.integers(len(devices)))
        ev.apply(si, di)
        mapping[si] = di
        assign = {st.name: devices[d] for st, d in zip(stages, mapping)}
        costs = plan_costs(stages, assign, "bf16", DELTA_W, model="v2")
        got = ev.objectives()
        per = costs.per_device_time()
        busy = sum(per.values())
        want = (costs.energy_j, costs.makespan_s,
                1.0 - busy / (len(devices) * costs.makespan_s))
        for g, w_ in zip(got, want):
            worst = max(worst, abs(g - w_) / max(abs(w_), 1e-30))

    speedup = walls[False] / max(walls[True], 1e-9)
    out = {
        "n_stages": len(stages), "n_devices": len(devices),
        "iters": DELTA_ITERS,
        "full_wall_s": round(walls[False], 3),
        "incremental_wall_s": round(walls[True], 3),
        "speedup": round(speedup, 2),
        "speedup_ge_5x": speedup >= 5.0,
        "parity_max_rel_err": worst,
        "parity_ok": worst < 1e-9,
        "best_energy_full_j": energies[False],
        "best_energy_incremental_j": energies[True],
    }
    if verbose:
        print(f"delta evaluator: {len(stages)} stages x {len(devices)} "
              f"devices, {DELTA_ITERS} iters: full {walls[False]:.2f}s vs "
              f"incremental {walls[True]:.2f}s -> {speedup:.1f}x, "
              f"parity {worst:.2e}")
    return out


def run(verbose: bool = True) -> Dict:
    result = {
        "seed": SEED,
        "diurnal": _diurnal(verbose),
        "delta_evaluator": _delta_evaluator(verbose),
    }
    d = result["diurnal"]
    result["acceptance_all"] = bool(
        d["router_zero_throttle"] and
        d["static_throttle_events"] >= 1 and
        d["router_ipw_over_static"] >= 1.0 and
        result["delta_evaluator"]["speedup_ge_5x"] and
        result["delta_evaluator"]["parity_ok"])
    if verbose:
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: pareto_router.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
