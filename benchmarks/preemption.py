"""Preemptive serving under faults vs run-to-completion (PR 10 bench).

A seeded mixed-tier Poisson stream on the paper's 4-device edge platform,
with a mid-run chaos plan injected through the REAL `SafetyMonitor` event
bus: one device failure (+ recovery), one thermal spike, and a tight-KV
window (`kv_squeeze`). Two policies see the identical stream AND the
identical fault plan:

* ``preempt`` — decode-boundary preemption on: an interactive arrival cuts
  the lowest-priority pipeline-tail batch (victim state snapshots, its
  filled KV blocks park in the resident prefix pool, resume is a trie hit
  that prefills only the post-preemption tail), fault evictions retry with
  exponential backoff.
* ``run_to_completion`` — tier preemption off: interactive arrivals wait
  behind whatever the pipeline is serving. Device-failure eviction still
  fires (a dead placement must never run to completion — that is
  correctness, not policy), so the fault-recovery comparison is apples to
  apples.

Gates (the PR 10 robustness acceptance):
  1. zero lost — every admitted request completes under chaos, both
     policies (fault evictions are retried, never dropped);
  2. interactive p95 with preemption >= 1.5x better than run-to-completion;
  3. resume prefill bytes < 25% of what a pool-less full re-prefill of the
     preempted histories would have moved (parked chains make resume a
     trie hit);
  4. zero leaked KV blocks after drain: allocator residency equals the
     prefix-trie residency exactly, and no live batch handles remain.

Everything except wall-clock is seeded and reproducible.

Run: PYTHONPATH=src python benchmarks/preemption.py [--out FILE]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

SEED = 0
N_REQUESTS = 36
PROMPT_LEN = 12
MAX_NEW_LONG = 16         # economy / standard decode horizon
MAX_NEW_INTERACTIVE = 4
SAMPLES = 2
TIER_MIX = (("interactive", 0.3), ("standard", 0.2), ("economy", 0.5))
OFFERED_LOAD = 1.5
KV_BLOCKS = 192
KV_BLOCK_SIZE = 4

ARCH = dict(name="preempt-bench", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


def _build_router():
    from repro.core import Constraints, Workload
    from repro.core.devices import EDGE_PLATFORM
    from repro.models import ArchConfig
    from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                             SLATier)

    cfg = ArchConfig(**ARCH)
    w = Workload(batch=1, prompt_tokens=PROMPT_LEN,
                 decode_tokens=MAX_NEW_LONG, samples=SAMPLES)
    orch = PGSAMOrchestrator(
        EDGE_PLATFORM, Constraints(latency_budget_factor=None),
        config=PGSAMConfig(seed=SEED, iters_max=1500, incremental=True),
        energy_model="v2")
    router = ParetoRouter(orch, cfg, w)
    # no hard caps: the contrast under test is pure service ORDER (tier
    # scalarization + preemption), not cap-driven batch shrinking
    router.add_tier(SLATier("interactive", energy_weight=0.0,
                            latency_weight=1.0))
    router.add_tier(SLATier("standard", energy_weight=0.5,
                            latency_weight=0.5))
    router.add_tier(SLATier("economy", energy_weight=1.0,
                            latency_weight=0.0))
    return cfg, router


def _arrivals(router) -> List[Dict]:
    """Seeded Poisson stream: interactive requests are short-horizon, the
    rest long-horizon (distinct buckets, so an interactive arrival always
    finds a *long* batch in front of it — the preemption win)."""
    rng = np.random.default_rng(SEED)
    svc = router.recost(router.route("economy").assignment,
                        router.batch_workload(1)).makespan_s
    rate = OFFERED_LOAD / svc
    names = [n for n, _ in TIER_MIX]
    probs = [p for _, p in TIER_MIX]
    t, out = 0.0, []
    for _ in range(N_REQUESTS):
        t += rng.exponential(1.0 / rate)
        tier = names[rng.choice(len(names), p=probs)]
        out.append({
            "t": t, "tier": tier,
            "max_new": (MAX_NEW_INTERACTIVE if tier == "interactive"
                        else MAX_NEW_LONG),
            "prompt": rng.integers(0, ARCH["vocab_size"],
                                   size=(PROMPT_LEN,)).astype(np.int32)})
    return out


def _chaos_plan(router, arrivals) -> "object":
    """Mid-run plan pinned to the arrival stream's own timeline: a failure
    of the device the economy tier actually routes onto (so in-flight
    batches are hit), a thermal spike, and a tight-KV window."""
    from repro.serving.chaos import FaultAction, FaultPlan

    dev = router.route("economy").assignment.device_names()[0]
    t_fail = arrivals[N_REQUESTS // 3]["t"]
    t_spike = arrivals[N_REQUESTS // 2]["t"]
    t_squeeze = arrivals[N_REQUESTS // 4]["t"]
    horizon = arrivals[-1]["t"]
    return FaultPlan(seed=SEED, actions=[
        FaultAction(t_squeeze, "kv_squeeze", value=float(KV_BLOCKS // 3),
                    detail="tight KV window"),
        FaultAction(t_fail, "device_fail", device=dev, detail="injected"),
        FaultAction(t_fail + 0.25 * horizon, "device_recover", device=dev),
        FaultAction(t_spike, "thermal_spike", device=dev, value=96.0),
        FaultAction(t_spike + 0.1 * horizon, "kv_squeeze", value=0.0),
    ])


def _make_backend(cfg):
    import jax
    import jax.numpy as jnp
    from repro.models import Model
    from repro.serving import ExecutionBackend

    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(SEED))
    return ExecutionBackend(model, params, kv_blocks=KV_BLOCKS,
                            kv_block_size=KV_BLOCK_SIZE, kv_pool=True)


def _drive(sched, arrivals, chaos) -> None:
    """Replay the stream; the chaos driver pumps on the same sim clock the
    scheduler advances, so injected faults land on live batches."""
    i = 0
    while i < len(arrivals) or sched.queue.pending or sched.inflight:
        # the sim clock only advances at batch boundaries; a fault whose
        # t_s falls inside an in-flight batch's service window must land
        # while that batch is still in flight (it gets preempted mid-run,
        # not conveniently after retiring)
        now = max([sched.clock] + [e.done_t - 1e-12
                                   for e in sched.inflight])
        chaos.apply_due(now)
        horizon = max(sched.clock, sched.pipeline_free_t)
        while i < len(arrivals) and arrivals[i]["t"] <= horizon:
            a = arrivals[i]
            adm = sched.submit(a["prompt"], tier=a["tier"],
                               n_samples=SAMPLES, max_new_tokens=a["max_new"],
                               arrival_s=a["t"])
            assert adm.admitted, adm.reason
            i += 1
        if not sched.queue.pending and not sched.inflight:
            sched.advance_to(arrivals[i]["t"])
            continue
        # if everything queued is backoff-parked but the stream has more
        # arrivals first, advance to the arrival — otherwise step() would
        # jump the clock past it to the retry instant and every later
        # request would inherit a phantom backoff wait
        nb = sched.queue.earliest_not_before()
        if (not sched.inflight and i < len(arrivals)
                and sched.queue.peek_ready(sched.clock) is None
                and nb is not None and arrivals[i]["t"] < nb):
            sched.advance_to(arrivals[i]["t"])
            continue
        if not sched.step() and (sched.queue.pending or sched.inflight):
            # starved mid-chaos (e.g. KV squeeze): advance to the next
            # chaos action or arrival so the squeeze can release
            nxt = [a["t"] for a in arrivals[i:]]
            nxt += [c.t_s for c in chaos._pending]
            if not nxt:
                raise RuntimeError("scheduler starved with no future event")
            sched.advance_to(min(x for x in nxt if x > sched.clock))
    chaos.apply_due(float("inf"))          # flush trailing actions


def _run_policy(cfg, router_factory, arrivals, preempt: bool,
                verbose: bool) -> Dict:
    from repro.core.devices import EDGE_PLATFORM
    from repro.core.safety import SafetyMonitor
    from repro.serving import ContinuousBatchingScheduler, SchedulerConfig
    from repro.serving.chaos import attach

    router = router_factory()
    backend = _make_backend(cfg)
    # retry backoff on the stream's own timescale (~2 batch services):
    # an absolute constant here would dwarf the sub-millisecond sim horizon
    # and turn one fault into a global stall for both policies
    svc = router.recost(router.route("economy").assignment,
                        router.batch_workload(1)).makespan_s
    sched = ContinuousBatchingScheduler(
        backend, router,
        # one in-flight batch: the pipeline is a single serialized server,
        # so head-of-line blocking is real and the only way an interactive
        # arrival gets ahead of a long economy batch is to preempt it
        SchedulerConfig(max_batch_requests=4, max_inflight_batches=1,
                        max_new_tokens=MAX_NEW_LONG, seed=SEED,
                        preempt=preempt, retry_backoff_s=2.0 * svc,
                        max_retries=8))
    safety = SafetyMonitor(EDGE_PLATFORM)
    chaos = attach(_chaos_plan(router, arrivals), safety, sched)
    _drive(sched, arrivals, chaos)

    s = sched.stats()
    alloc = backend.allocator
    leaked = (alloc.blocks_in_use - backend.prefix_pool.blocks_resident
              + len(backend._live))
    out = {
        "completed": s["completed"],
        "cancelled": s["cancelled"],
        "batches": s["batches"],
        "p95_latency_s": s["latency_p95_s"],
        "preemptions": s["preemptions"],
        "preemptions_total": s["preemptions_total"],
        "retries_total": s["retries_total"],
        "resume_full_tokens": s["resume_full_tokens"],
        "resume_tail_tokens": s["resume_tail_tokens"],
        "chaos_applied": len(chaos.applied),
        "leaked_blocks": int(leaked),
    }
    if verbose:
        name = "preempt" if preempt else "run_to_completion"
        print(f"  {name}: {out['completed']}/{N_REQUESTS} done, "
              f"{out['preemptions_total']} preemptions {out['preemptions']}, "
              f"{out['retries_total']} retries, "
              f"p95[interactive]={out['p95_latency_s'].get('interactive', 0):.3f}s, "
              f"leaked={out['leaked_blocks']}")
    return out


def run(verbose: bool = True) -> Dict:
    cfg, router0 = _build_router()
    arrivals = _arrivals(router0)
    plan = _chaos_plan(router0, arrivals)
    if verbose:
        mix: Dict[str, int] = {}
        for a in arrivals:
            mix[a["tier"]] = mix.get(a["tier"], 0) + 1
        print(f"stream: {N_REQUESTS} requests, tier mix {mix}, "
              f"offered load {OFFERED_LOAD}x; chaos: "
              f"{[(a.kind, f'{a.t_s * 1e3:.2f}ms') for a in plan.actions]}")

    # each policy gets its own router (its own healthy-set state machine)
    # over an identically-seeded frontier
    def router_factory():
        return _build_router()[1]

    pre = _run_policy(cfg, router_factory, arrivals, True, verbose)
    rtc = _run_policy(cfg, router_factory, arrivals, False, verbose)

    p95_pre = pre["p95_latency_s"].get("interactive", float("inf"))
    p95_rtc = rtc["p95_latency_s"].get("interactive", 0.0)
    p95_ratio = p95_rtc / max(p95_pre, 1e-12)
    tail_ratio = (pre["resume_tail_tokens"]
                  / max(pre["resume_full_tokens"], 1))
    gates = {
        "zero_lost": bool(pre["completed"] == N_REQUESTS
                          and rtc["completed"] == N_REQUESTS
                          and pre["cancelled"] == 0
                          and rtc["cancelled"] == 0),
        "interactive_p95_gain_ok": bool(p95_ratio >= 1.5),
        "resume_bytes_ok": bool(pre["resume_full_tokens"] > 0
                                and tail_ratio < 0.25),
        "zero_leaked": bool(pre["leaked_blocks"] == 0
                            and rtc["leaked_blocks"] == 0),
        "chaos_fully_applied": bool(
            pre["chaos_applied"] == len(plan.actions)
            and rtc["chaos_applied"] == len(plan.actions)),
        "faults_recovered": bool(pre["retries_total"] > 0
                                 and rtc["retries_total"] > 0),
    }
    result = {
        "seed": SEED,
        "n_requests": N_REQUESTS,
        "offered_load": OFFERED_LOAD,
        "chaos_actions": [(a.kind, a.device, a.value)
                          for a in plan.actions],
        "preempt": pre,
        "run_to_completion": rtc,
        "interactive_p95_ratio": p95_ratio,
        "resume_tail_ratio": tail_ratio,
        "gates": gates,
        "acceptance_all": all(gates.values()),
    }
    if verbose:
        print(f"  interactive p95: {p95_rtc:.3f}s -> {p95_pre:.3f}s "
              f"(x{p95_ratio:.2f}, gate >= 1.5)")
        print(f"  resume prefill: {pre['resume_tail_tokens']} of "
              f"{pre['resume_full_tokens']} tokens moved "
              f"({tail_ratio:.1%}, gate < 25%)")
        print(f"  gates: {gates}")
        print(f"  acceptance_all={result['acceptance_all']}")
        print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    out_path: Optional[str] = None
    if "--out" in sys.argv:
        idx = sys.argv.index("--out") + 1
        if idx >= len(sys.argv):
            sys.exit("usage: preemption.py [--out FILE]")
        out_path = sys.argv[idx]
    res = run()
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {out_path}", file=sys.stderr)
    if not res["acceptance_all"]:
        sys.exit(1)
