"""Paper Table 11: fault tolerance — recovery from simulated device failures
with the orchestrator redistributing stages."""
from __future__ import annotations

from typing import Dict, List

from repro.core import (Constraints, GreedyOrchestrator, HealthMonitor,
                        Workload)
from repro.core.devices import EDGE_PLATFORM
from repro.configs.paper_models import GPT2_125M
from benchmarks.common import PAPER_WORKLOAD, fmt_table

SCENARIOS = [
    ("NPU failure", ["intel-ai-boost-npu"], (78, -31)),
    ("GPU failure", ["nvidia-rtx-pro-5000"], (124, -58)),
    ("both GPU failure", ["nvidia-rtx-pro-5000", "intel-graphics-gpu"],
     (156, -72)),
    ("NPU + 1 GPU failure", ["intel-ai-boost-npu", "nvidia-rtx-pro-5000"],
     (98, -64)),
]


def run(verbose: bool = True) -> Dict:
    w = PAPER_WORKLOAD
    orch = GreedyOrchestrator(EDGE_PLATFORM,
                              Constraints(latency_budget_factor=1.5))
    healthy_plan = orch.assign(GPT2_125M, w)

    rows: List = []
    all_recovered = True
    zero_loss = True
    for name, failed, paper in SCENARIOS:
        hm = HealthMonitor(EDGE_PLATFORM)
        rec = None
        for i, dev in enumerate(failed):
            rec = hm.fail_device(dev, now_s=float(i) * 0.01,
                                 inflight_queries=64)
        plan = orch.reassign_on_failure(GPT2_125M, w, failed=failed)
        ok = bool(plan.mapping)
        all_recovered &= ok
        zero_loss &= rec.queries_lost == 0
        tput_delta = (healthy_plan.latency_s / plan.latency_s - 1) * 100 \
            if ok else -100.0
        rows.append([name, f"{rec.recovery_ms:.0f}",
                     f"{tput_delta:+.0f}%", rec.queries_lost,
                     f"{paper[0]} ms / {paper[1]}% / 0"])
    if verbose:
        print(fmt_table(["scenario", "recovery ms", "throughput delta",
                         "queries lost", "paper (rec/tput/lost)"],
                        rows, "Table 11: fault tolerance"))
        print(f"   100% recovery: {all_recovered}, zero query loss: "
              f"{zero_loss}")
    return {"all_recovered": all_recovered, "zero_query_loss": zero_loss}
