"""Regenerate the machine-derived tables of EXPERIMENTS.md from the dry-run
artifacts. Run after any dry-run refresh:

    PYTHONPATH=src:. python scripts/gen_experiments_tables.py > experiments/tables.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_table import analyze, load_artifacts  # noqa: E402


def dryrun_table(mesh: str) -> None:
    arts = load_artifacts(mesh)
    print(f"\n### Dry-run artifacts — {mesh} pod "
          f"({arts[0]['n_chips'] if arts else '?'} chips)\n")
    print("| arch | shape | kind | per-dev args GB | per-dev temp GB | "
          "HLO flops/dev/body | coll bytes/dev/body | coll ops | "
          "lower s | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in arts:
        if "error" in a:
            print(f"| {a['arch']} | {a['shape']} | — | FAILED: "
                  f"{a['error'][:60]} | | | | | | |")
            continue
        m = a["memory_analysis"]
        c = a["cost_analysis"]
        print(f"| {a['arch']} | {a['shape']} | {a['kind']} "
              f"| {m.get('argument_size_in_bytes', 0) / 1e9:.2f} "
              f"| {m.get('temp_size_in_bytes', 0) / 1e9:.1f} "
              f"| {c.get('flops', 0):.2e} "
              f"| {a['collective_bytes']['total']:.2e} "
              f"| {a['collective_bytes'].get('n_ops', 0):.0f} "
              f"| {a['lower_s']:.1f} | {a['compile_s']:.1f} |")


def roofline_md() -> None:
    arts = load_artifacts("single")
    print("\n### Roofline terms — single pod (256 x v5e: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s/link)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL_FLOPS | MODEL/HLO | one-line lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    levers = {
        "compute": "higher MXU util (larger tiles / fused matmuls)",
        "memory": "cut bytes: fp8 cache/weights, fused layers, remat tuning",
        "collective": "layout change: less TP, seq-parallel, overlap",
    }
    for art in arts:
        if "error" in art:
            continue
        a = analyze(art)
        if a is None:
            continue
        t = a["terms"]
        print(f"| {a['arch']} | {a['shape']} | {t.compute_s:.3f} "
              f"| {t.memory_s:.3f} | {t.collective_s:.3f} | {t.dominant} "
              f"| {a['model_flops']:.2e} | {a['flops_ratio']:.3f} "
              f"| {levers[t.dominant]} |")


if __name__ == "__main__":
    dryrun_table("single")
    dryrun_table("multi")
    roofline_md()
