"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips over ("data", "model").
    Multi-pod: 2x16x16 = 512 chips over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires >= n placeholder
    devices — see tests/test_distributed.py which sets the XLA flag in a
    subprocess)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
