"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input, per
(architecture x input shape). Weak-type-correct, shardable, no device
allocation: the multi-pod dry-run lowers against these.

Shapes:
  train_4k     -> train_step   (tokens + labels, full sequence)
  prefill_32k  -> prefill      (tokens + fresh cache)
  decode_32k   -> serve_step   (ONE new token against a seq_len cache)
  long_500k    -> serve_step   (window/SSM cache; batch 1)

long_500k policy (DESIGN.md §4): architectures with attention run the
sliding-window variant (window 4096) at this shape — ``adapt_config`` applies
the override — so the cache is O(window), not O(524288). SSM archs are
natively O(1)-state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.cache import make_cache
from repro.models.config import ArchConfig, InputShape, INPUT_SHAPES

LONG_CONTEXT_WINDOW = 4096


def has_attention(cfg: ArchConfig) -> bool:
    return "a" in cfg.pattern


def adapt_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Per-shape architecture adaptation (the long_500k window override)."""
    if shape.name == "long_500k" and has_attention(cfg) and not cfg.attn_window:
        return cfg.with_overrides(attn_window=LONG_CONTEXT_WINDOW)
    return cfg


def _token_spec(cfg: ArchConfig, batch: int, seq: int):
    if cfg.n_codebooks > 1:
        return jax.ShapeDtypeStruct((batch, seq, cfg.n_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def _extras(cfg: ArchConfig, batch: int, seq: int, dtype) -> Dict:
    out = {}
    if cfg.frontend == "vision":
        nv = min(cfg.n_vision_tokens, seq)
        out["vision_embeds"] = jax.ShapeDtypeStruct((batch, nv, cfg.d_model),
                                                    dtype)
    if cfg.cross_attention:
        out["cond_memory"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_cond_tokens, cfg.d_model), dtype)
    return out


def _positions_spec(cfg: ArchConfig, batch: int, seq: int):
    if cfg.mrope_sections:
        return jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16, cache_dtype=None) -> Tuple[Dict, Dict]:
    """Returns (batch_specs, cache_specs). cache_specs is {} for train.
    cache_dtype overrides the KV-cache element type (fp8 cache variant)."""
    cache_dtype = cache_dtype or dtype
    cfg = adapt_config(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": _token_spec(cfg, B, S),
            "labels": _token_spec(cfg, B, S),
            **_extras(cfg, B, S, dtype),
        }
        if cfg.mrope_sections:
            batch["positions"] = _positions_spec(cfg, B, S)
        return batch, {}

    if shape.kind == "prefill":
        batch = {
            "tokens": _token_spec(cfg, B, S),
            **_extras(cfg, B, S, dtype),
        }
        if cfg.mrope_sections:
            batch["positions"] = _positions_spec(cfg, B, S)
        cache = make_cache(cfg, B, S, cache_dtype, spec_only=True)
        return batch, cache

    # decode: ONE new token against a cache of seq_len (ring-capped by window)
    extras = _extras(cfg, B, 1, dtype)
    if cfg.cross_kv_cache:
        extras.pop("cond_memory", None)  # served from the cached projections
    batch = {
        "tokens": _token_spec(cfg, B, 1),
        "positions": _positions_spec(cfg, B, 1),
        **extras,
    }
    cache = make_cache(cfg, B, S, cache_dtype, spec_only=True)
    return batch, cache


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
