"""Calibration entry point: traces in, fitted profile + residual report out.

Closes the ROADMAP's measurement loop from the command line:

  # fit from an existing JSONL trace (kernel_bench --out / dryrun --trace /
  # a ControlLoop's TraceStore file):
  PYTHONPATH=src python -m repro.launch.calibrate --trace traces.jsonl \\
      --out profile.json --report report.json

  # no hardware? fit against the seeded synthetic ground-truth fixture:
  PYTHONPATH=src python -m repro.launch.calibrate --synthetic --seed 0 \\
      --out profile.json --report report.json

The profile JSON round-trips through `CalibrationProfile.load`, ready for

  provider = CalibratedSignalProvider(CalibrationProfile.load("profile.json"))
  PGSAMOrchestrator(..., energy_model="v2", provider=provider)

so fitted coefficients and measured kernel duty cycles feed every subsequent
anneal, re-anneal and `plan_costs(model="v2")` call.
"""
from __future__ import annotations

import argparse

from repro.qeil2.telemetry import (CalibrationFitter, TraceStore,
                                   synthetic_trace_store)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fit DASI/CPQ/Phi coefficients from telemetry traces")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--trace", default=None,
                     help="JSONL trace file (TraceStore format)")
    src.add_argument("--synthetic", action="store_true",
                     help="fit against the seeded synthetic fixture")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bootstrap", type=int, default=200,
                    help="bootstrap resamples for the coefficient CIs")
    ap.add_argument("--out", default="calibration_profile.json")
    ap.add_argument("--report", default="calibration_report.json")
    args = ap.parse_args()

    if args.synthetic:
        store = synthetic_trace_store(seed=args.seed)
    else:
        store = TraceStore.load(args.trace)
    counts = store.counts()
    print(f"trace: {len(store)} records {counts}")

    fitter = CalibrationFitter(store, n_bootstrap=args.bootstrap,
                               seed=args.seed)
    profile, report = fitter.fit()
    profile.save(args.out)
    report.save(args.report)

    print(f"\n{'coefficient':<14} {'default':>9} {'fitted':>9} "
          f"{'ci 2.5%':>9} {'ci 97.5%':>9}")
    for name, row in report.coefficients.items():
        lo, hi = row["ci"]
        print(f"{name:<14} {row['default']:>9.4g} {row['fitted']:>9.4g} "
              f"{lo:>9.4g} {hi:>9.4g}")
    for name, row in report.kernel_eta.items():
        lo, hi = row["ci"]
        print(f"{'eta:' + name:<14} {1.0:>9.4g} {row['fitted']:>9.4g} "
              f"{lo:>9.4g} {hi:>9.4g}")
    print(f"\nlog-energy RMSE: defaults {report.rmse_default:.4f} -> "
          f"fitted {report.rmse_fitted:.4f} "
          f"({report.improvement_pct:.1f}% lower)")
    print(f"profile -> {args.out}\nreport  -> {args.report}")


if __name__ == "__main__":
    main()
