import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape) combination against the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — using
ShapeDtypeStruct inputs (no allocation). For each combination it records:

  * compiled.memory_analysis()  (bytes per device — proves it fits)
  * compiled.cost_analysis()    (FLOPs / bytes for the §Roofline terms)
  * collective bytes parsed from the optimized HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the roofline
table (benchmarks/roofline_table.py) and EXPERIMENTS.md §Dry-run read them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.roofline import collective_bytes_from_hlo
from repro.distributed import ShardingPolicy
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import adapt_config, get_shape, input_specs
from repro.models import Model
from repro.models.config import INPUT_SHAPES
from repro.training import AdamWConfig, make_train_step, opt_state_specs

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")


def _artifact_path(arch: str, shape: str, mesh_kind: str,
                   tag: str = "") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR,
                        f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def _prefill_step(model):
    def step(params, batch, cache):
        logits, cache, _ = model.forward(params, batch, cache)
        return logits[:, -1], cache
    return step


def _decode_step(model):
    def step(params, batch, cache):
        logits, cache, _ = model.forward(params, batch, cache)
        return logits[:, 0], cache
    return step


def lower_and_compile(arch: str, shape_name: str, mesh_kind: str = "single",
                      verbose: bool = True, fsdp: bool = True,
                      shard_hints: bool = False, mla_naive: bool = False,
                      ssm_split: bool = False, no_tp: bool = False,
                      microbatches: int = 1, cache_fp8: bool = False,
                      cross_cache: bool = False, moe_dense: bool = False,
                      dtype=jnp.bfloat16) -> Dict:
    """One (arch x shape x mesh) dry-run. Returns the artifact dict.

    Variant knobs for the §Perf hillclimbs:
      shard_hints — activation sharding constraints in the SSD block
      mla_naive   — decompressed (non-absorbed) MLA decode baseline
      fsdp=False  — tensor-parallel only (weights replicated over "data")
    """
    t0 = time.time()
    shape = get_shape(shape_name)
    cfg = adapt_config(get_config(arch), shape)
    if mla_naive:
        cfg = cfg.with_overrides(mla_absorbed=False)
    if ssm_split:
        cfg = cfg.with_overrides(ssm_split_proj=True)
    if cross_cache:
        cfg = cfg.with_overrides(cross_kv_cache=True)
    if moe_dense:
        cfg = cfg.with_overrides(moe_dense_decode=True)
    from repro.distributed import hints
    if shard_hints:
        hints.enable()
    else:
        hints.disable()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    policy = ShardingPolicy(mesh, fsdp_enabled=fsdp,
                            tensor_enabled=not no_tp)

    model = Model(cfg, dtype=dtype, remat=(shape.kind == "train"))
    p_specs = model.param_specs()
    p_shard = policy.param_shardings(p_specs)
    batch_specs, cache_specs = input_specs(
        cfg, shape, dtype,
        cache_dtype=jnp.float8_e4m3fn if cache_fp8 else None)
    b_shard = policy.batch_shardings(batch_specs)

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            train_step = make_train_step(model, opt_cfg,
                                         microbatches=microbatches)
            o_specs = opt_state_specs(p_specs)
            o_shard = policy.opt_state_shardings(p_specs)
            metrics_shard = {k: policy.scalar() for k in
                             ("lr", "grad_norm", "step", "loss")}
            jitted = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, metrics_shard))
            lowered = jitted.lower(p_specs, o_specs, batch_specs)
        else:
            c_shard = policy.cache_shardings(cache_specs)
            extra = 2 if cfg.n_codebooks > 1 else 1
            logits_shard = policy.named(policy.logits_spec(
                shape.global_batch, cfg.vocab_size, extra_dims=extra - 1))
            step = (_prefill_step(model) if shape.kind == "prefill"
                    else _decode_step(model))
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(logits_shard, c_shard))
            lowered = jitted.lower(p_specs, batch_specs, cache_specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- artifact assembly
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for key in ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes"):
            if hasattr(ma, key):
                mem[key] = int(getattr(ma, key))
        mem["repr"] = str(ma)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = repr(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": repr(e)}

    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes_from_hlo(hlo)

    artifact = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "param_count": Model(cfg).param_count(),
        "active_param_count": Model(cfg).active_param_count(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "attn_window": cfg.attn_window,
        "fsdp": fsdp, "shard_hints": shard_hints, "mla_naive": mla_naive,
        "ssm_split": ssm_split, "no_tp": no_tp,
        "microbatches": microbatches, "cache_fp8": cache_fp8,
        "cross_cache": cross_cache, "moe_dense": moe_dense,
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collective_bytes": coll,
        "hlo_bytes": len(hlo),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}"
              f" ({n_chips} chips): lower {t_lower:.1f}s compile"
              f" {t_compile:.1f}s flops={cost.get('flops', float('nan')):.3e}"
              f" coll_bytes={coll['total']:.3e}")
        print(f"  memory_analysis: {mem.get('repr', mem)}")
    return artifact


def run_one(arch: str, shape_name: str, mesh_kind: str,
            force: bool = False, tag: str = "", **kw) -> Dict:
    path = _artifact_path(arch, shape_name, mesh_kind, tag)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    try:
        artifact = lower_and_compile(arch, shape_name, mesh_kind, **kw)
    except Exception as e:
        artifact = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "error": repr(e), "traceback": traceback.format_exc()}
        print(f"[dryrun] FAILED {arch} x {shape_name} x {mesh_kind}: {e!r}")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ASSIGNED_ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--hints", action="store_true",
                    help="SSD activation-sharding constraints")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--mla-naive", action="store_true")
    ap.add_argument("--ssm-split", action="store_true")
    ap.add_argument("--no-tp", action="store_true",
                    help="pure data-parallel layout (model axis joins batch)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cache-fp8", action="store_true")
    ap.add_argument("--cross-cache", action="store_true")
    ap.add_argument("--moe-dense", action="store_true")
    ap.add_argument("--trace", default=None,
                    help="append each artifact's compiled.cost_analysis() "
                         "FLOP/byte counts to this JSONL telemetry trace "
                         "(repro.qeil2.telemetry.TraceStore)")
    args = ap.parse_args()

    trace_store = None
    if args.trace:
        from repro.qeil2.telemetry import TraceStore
        trace_store = TraceStore(path=args.trace)

    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                art = run_one(arch, shape_name, mesh_kind, force=args.force,
                              tag=args.tag, fsdp=not args.no_fsdp,
                              shard_hints=args.hints,
                              mla_naive=args.mla_naive,
                              ssm_split=args.ssm_split, no_tp=args.no_tp,
                              microbatches=args.microbatches,
                              cache_fp8=args.cache_fp8,
                              cross_cache=args.cross_cache,
                              moe_dense=args.moe_dense)
                if "error" in art:
                    failures.append((arch, shape_name, mesh_kind))
                elif trace_store is not None:
                    trace_store.ingest_dryrun_artifact(art)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print(f"\nall {len(archs) * len(shapes) * len(meshes)} dry-runs passed")


if __name__ == "__main__":
    main()
