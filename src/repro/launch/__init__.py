"""Launchers: mesh, dryrun, train, serve."""
