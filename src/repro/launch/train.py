"""Distributed training launcher.

Shards the same ``make_train_step`` the dry-run lowers across whatever mesh is
available. On this CPU container it runs real steps on a debug mesh with a
reduced config (``--smoke``); on a real TPU fleet the identical code path runs
the full config on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --smoke \
      --steps 20 --mesh debug
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data import DataConfig, data_iterator
from repro.distributed import ShardingPolicy
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import Model
from repro.training import (AdamWConfig, init_opt_state, make_train_step,
                            save_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="none", choices=["none", "debug",
                                                       "single", "multi"])
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, kind="markov",
                    n_codebooks=cfg.n_codebooks)
    data = data_iterator(dc)

    params = model.init(jax.random.key(0))
    opt_state = init_opt_state(params)
    step_fn = make_train_step(model, opt_cfg)

    if args.mesh == "none":
        jitted = jax.jit(step_fn)
        ctx = None
    else:
        mesh = (make_debug_mesh() if args.mesh == "debug" else
                make_production_mesh(multi_pod=(args.mesh == "multi")))
        policy = ShardingPolicy(mesh)
        p_sh = policy.param_shardings(model.param_specs())
        o_sh = policy.opt_state_shardings(model.param_specs())
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                         out_shardings=(p_sh, o_sh, None))
        ctx = mesh

    def run():
        nonlocal params, opt_state
        t0 = time.perf_counter()
        for step in range(args.steps):
            batch = next(data)
            if cfg.frontend == "vision":
                batch["vision_embeds"] = jnp.zeros(
                    (args.batch, 4, cfg.d_model), model.dtype)
            if cfg.cross_attention:
                batch["cond_memory"] = jnp.zeros(
                    (args.batch, cfg.n_cond_tokens, cfg.d_model), model.dtype)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t0
                print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt:.1f}s")

    if ctx is not None:
        with ctx:
            run()
    else:
        run()

    if args.checkpoint_dir:
        path = save_checkpoint(args.checkpoint_dir, args.steps, params)
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
