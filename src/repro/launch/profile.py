"""Per-kernel profiling: compiled cost analysis + timed reps for the four
Pallas kernels, emitted as TraceStore-ingestible ``kind="kernel"`` records.

Where ``benchmarks/kernel_bench.py`` stamps records with hand-derived
analytic FLOP/byte counts, this entry point asks the compiler: each kernel
wrapper is lowered and compiled, and ``compiled.cost_analysis()`` supplies
the flops / bytes-accessed terms (falling back to the analytic counts when
the backend doesn't report them — the ``cost_source`` field says which side
produced the numbers). Timed reps run under ``repro.obs.annotate`` so they
are attributable in a host profile, and the dequant records carry their
``quant`` stamp so the calibration fitter keys them as
``dequant_matmul:int8`` / ``dequant_matmul:int4`` (telemetry.fit._eta_key).

Usage:
  PYTHONPATH=src python -m repro.launch.profile --out traces/kernels.jsonl
  PYTHONPATH=src python -m repro.launch.profile --kernels flash_attention
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import TPU_V5E
from repro.obs.profiling import annotate, tpu_roofline_us


def _time_reps(fn, *args, n: int = 3, label: str = "kernel") -> List[float]:
    """Per-rep us/call, warm call excluded; each rep annotated for the host
    profiler so kernel time is attributable in a captured trace."""
    jax.block_until_ready(fn(*args))  # warm (compiles)
    out = []
    for _ in range(n):
        with annotate(f"profile/{label}"):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            out.append((time.perf_counter() - t0) * 1e6)
    return out


def _compiled_costs(fn, *args) -> Optional[Dict[str, float]]:
    """flops / bytes accessed from the compiled executable, or None when the
    backend reports neither (CPU builds often omit byte counters)."""
    try:
        ca = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        costs = {k: float(v) for k, v in ca.items()
                 if isinstance(v, (int, float))}
    except Exception:
        return None
    out = {}
    if costs.get("flops", 0.0) > 0.0:
        out["flops"] = costs["flops"]
    by = costs.get("bytes accessed", 0.0)
    if by > 0.0:
        out["bytes"] = by
    return out or None


def _records(kernel: str, reps: List[float], flops: float, bytes_moved: float,
             cost_source: str, quant: str = "fp32") -> List[dict]:
    roofline = tpu_roofline_us(flops, bytes_moved)
    backend = jax.default_backend()
    return [{"kind": "kernel", "kernel": kernel, "rep": i,
             "flops": flops, "bytes": bytes_moved,
             "measured_us": us, "roofline_us": roofline,
             "device": TPU_V5E.name,
             "backend": backend if backend == "tpu" else f"{backend}-interpret",
             "cost_source": cost_source, "quant": quant}
            for i, us in enumerate(reps)]


def _profile_one(name: str, fn: Callable, args: tuple, analytic_flops: float,
                 analytic_bytes: float, reps: int,
                 quant: str = "fp32") -> Tuple[List[dict], Dict]:
    """Time one kernel and stamp records with compiled costs when available."""
    costs = _compiled_costs(fn, *args)
    flops = analytic_flops
    bytes_moved = analytic_bytes
    source = "analytic"
    if costs is not None:
        # compiled counts only replace terms the backend actually reports;
        # a flops-only report keeps the analytic byte side (and vice versa)
        flops = costs.get("flops", flops)
        bytes_moved = costs.get("bytes", bytes_moved)
        source = ("compiled" if len(costs) == 2
                  else f"compiled-{next(iter(costs))}+analytic")
    timed = _time_reps(fn, *args, n=reps, label=name)
    recs = _records(name, timed, flops, bytes_moved, source, quant=quant)
    summary = {"kernel": name, "quant": quant, "cost_source": source,
               "flops": flops, "bytes": bytes_moved,
               "mean_us": float(np.mean(timed)),
               "roofline_us": recs[0]["roofline_us"]}
    return recs, summary


def run(verbose: bool = True, reps: int = 3,
        kernels: Optional[List[str]] = None) -> Dict:
    """Profile the Pallas kernel call sites at small fixed shapes (the same
    shapes benchmarks/kernel_bench.py times) and return TraceStore-ingestible
    records plus per-kernel summaries."""
    from repro.kernels.decode_attention.ops import decode_attention_cache
    from repro.kernels.dequant_matmul.ops import dequant_matmul
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_chunk
    from repro.quant import quantize_int4, quantize_int8

    ks = jax.random.split(jax.random.key(0), 3)
    jobs: List[Tuple[str, Callable, tuple, float, float, str]] = []

    # flash attention (causal prefill tile)
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    jobs.append(("flash_attention", flash_attention, (q, k, v),
                 4.0 * B * S * S / 2 * H * D, 4 * B * S * H * D * 4, "fp32"))

    # decode attention (cache streaming)
    W = 1024
    kc = jax.random.normal(ks[1], (2, W, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, W, 2, 64), jnp.float32)
    qd = jax.random.normal(ks[0], (2, 1, 4, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(W)[None], (2, W)).astype(jnp.int32)
    qpos = jnp.full((2,), W - 1, jnp.int32)
    jobs.append(("decode_attention", decode_attention_cache,
                 (qd, kc, vc, pos, qpos),
                 4.0 * 2 * W * 4 * 64, 2 * W * 2 * 64 * 2 * 4, "fp32"))

    # ssd chunked scan
    nc, Q, P, N = 4, 64, 32, 64
    x = jax.random.normal(ks[0], (2, nc, Q, 2, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, nc, Q, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)))
    dA = dt * A[None, None, None]
    dAcs = jnp.cumsum(dA, axis=2)
    Bm = jax.random.normal(ks[1], (2, nc, Q, 2, N), jnp.float32)
    Cm = jax.random.normal(ks[2], (2, nc, Q, 2, N), jnp.float32)
    jobs.append(("ssd_scan", ssd_chunk, (x, dt, dA, dAcs, Bm, Cm),
                 2 * nc * (2 * Q * Q * 2 * (P + N)),
                 2 * nc * Q * 2 * (P + 2 * N) * 4, "fp32"))

    # fused dequant-matmul, both serving formats (quant-stamped records)
    M, Kd, Nd = 8, 256, 256
    xq = jax.random.normal(ks[0], (M, Kd), jnp.float32)
    wq = jax.random.normal(ks[1], (Kd, Nd), jnp.float32)
    fl_q = 2.0 * M * Kd * Nd
    for fmt, (qw, sc), wbytes in (
            ("int8", quantize_int8(wq), Kd * Nd),
            ("int4", quantize_int4(wq, 32), Kd * Nd // 2)):
        by_q = wbytes + sc.size * 4 + (M * Kd + M * Nd) * 4
        jobs.append((f"dequant_matmul", dequant_matmul, (xq, qw, sc),
                     fl_q, by_q, fmt))

    results: Dict = {"records": [], "kernels": []}
    for name, fn, args, fl, by, quant in jobs:
        if kernels and name not in kernels:
            continue
        recs, summary = _profile_one(name, fn, args, fl, by, reps,
                                     quant=quant)
        results["records"] += recs
        results["kernels"].append(summary)
        if verbose:
            print(f"[profile] {name}"
                  f"{'[' + quant + ']' if quant != 'fp32' else '':8s} "
                  f"{summary['mean_us']:9.0f} us/call  "
                  f"roofline {summary['roofline_us']:8.2f} us  "
                  f"costs: {summary['cost_source']}")
    results["n_records"] = len(results["records"])
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="append kernel records to this JSONL trace")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--kernels", nargs="*", default=None,
                    help="subset of kernel names to profile")
    ap.add_argument("--profile-dir", default=None,
                    help="also capture a jax.profiler trace into this dir")
    args = ap.parse_args()

    if args.profile_dir:
        with jax.profiler.trace(args.profile_dir):
            results = run(verbose=True, reps=args.reps, kernels=args.kernels)
        print(f"[profile] jax.profiler trace -> {args.profile_dir}")
    else:
        results = run(verbose=True, reps=args.reps, kernels=args.kernels)

    if args.out:
        from repro.qeil2.telemetry import TraceStore
        store = TraceStore(path=args.out)
        n = store.ingest_many(results["records"])
        print(f"appended {n} kernel records -> {args.out}")


if __name__ == "__main__":
    main()
