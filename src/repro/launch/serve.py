"""Serving launcher: batched requests through the engine with QEIL
orchestration + safety monitoring in the loop.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m \
      --smoke --requests 8 --samples 4

``--router`` replaces the one-shot greedy plan with the scheduler-centric
runtime: a PGSAM anneal builds the non-dominated archive once, requests
enter tier-aware admission, and the continuous-batching scheduler forms
(optionally mixed-tier, with ``--mixed``) batches routed to shared
operating points off the archive (`repro.serving.scheduler` +
`repro.qeil2.runtime`). Without ``--router`` the v1 blocking engine path
runs unchanged as the baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import (Constraints, GreedyOrchestrator, SafetyMonitor,
                        Workload, EDGE_PLATFORM)
from repro.models import Model
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--samples", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--router", action="store_true",
                    help="scheduler-centric serving: tier-aware admission "
                         "+ continuous batching over the PGSAM archive")
    ap.add_argument("--tier", default="standard",
                    choices=["interactive", "standard", "economy"],
                    help="SLA tier to serve requests under (--router)")
    ap.add_argument("--mixed", action="store_true",
                    help="round-robin requests over all three tiers so "
                         "batches mix tiers (--router)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="scheduler batch size bound (--router)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged KV cache: block budget (prefix sharing "
                         "across repeated samples; supported archs only)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged KV cache: token slots per block")
    ap.add_argument("--kv-pool", action="store_true",
                    help="resident prefix pool (needs --kv-blocks): one "
                         "physical cache outlives batches, a radix trie "
                         "reuses cached full prefix blocks across the "
                         "stream, and admission prices requests at their "
                         "marginal (post-dedup) tail blocks")
    ap.add_argument("--pool-evict", default="lru", choices=["lru", "off"],
                    help="prefix-pool eviction of idle (zero-ref) trie "
                         "blocks: LRU on demand, or off (resident set only "
                         "grows; admission fails loudly when full)")
    ap.add_argument("--quant", default="bf16",
                    choices=["bf16", "int8", "int4"],
                    help="weight-only serving format (repro.quant): linear "
                         "layers run the fused dequant-matmul kernel")
    ap.add_argument("--group-size", type=int, default=32,
                    help="int4 quantization group size along d_in")
    ap.add_argument("--kv-int8", action="store_true",
                    help="store the paged KV cache int8 (needs --kv-blocks; "
                         "halves cache bytes per token slot)")
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "draft"],
                    help="speculative decode draft policy (repro.spec): "
                         "prompt-lookup n-grams or a small draft model; "
                         "with --router the SpecPlanner prices draft depth "
                         "per batch")
    ap.add_argument("--spec-n", type=int, default=4,
                    help="max draft tokens verified per decode step")
    ap.add_argument("--draft-model", default=None, choices=ASSIGNED_ARCHS,
                    help="arch whose reduced config serves as the draft "
                         "model (--spec draft; defaults to --arch reduced; "
                         "must share the target vocab)")
    ap.add_argument("--preempt", action="store_true",
                    help="decode-boundary preemption (--router): an "
                         "interactive arrival may cut a lower-tier "
                         "in-flight batch; with --kv-pool the victim's "
                         "blocks park in the trie and resume prefills "
                         "only the tail")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill (needs --kv-blocks): split "
                         "prefills into <= N-token slices interleaved "
                         "with decode steps (bit-identical output)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-tier deadline factor (--router): cancel a "
                         "queued request once it waits longer than "
                         "FACTOR x its tier's p99 latency cap")
    ap.add_argument("--chaos", default=None, metavar="PLAN.json",
                    help="fault-injection plan (repro.serving.chaos JSON) "
                         "replayed on the simulated clock through the "
                         "SafetyMonitor into the live scheduler")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot (JSON + .prom sibling) "
                         "here; with --router, refreshed periodically while "
                         "the scheduler drains")
    ap.add_argument("--metrics-interval", type=float, default=5.0,
                    help="simulated seconds between periodic metrics writes")
    ap.add_argument("--spans-out", default=None,
                    help="write request lifecycle spans (JSONL) here")
    args = ap.parse_args()

    from repro.obs import NULL_OBS, PeriodicReporter, make_observability
    obs = (make_observability() if args.metrics_out or args.spans_out
           else NULL_OBS)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.key(0))
    if args.quant != "bf16":
        from repro.quant import param_bytes, quantize_model
        before = param_bytes(params)
        params = quantize_model(params, args.quant, args.group_size)
        print(f"[quant] weights {args.quant}: {before / 1e6:.1f} MB -> "
              f"{param_bytes(params) / 1e6:.1f} MB")

    spec_policy = None
    if args.spec != "off":
        from repro.spec import (DEFAULT_ACCEPT_RATE, expected_tokens_per_step,
                                make_draft_policy, spec_supported)
        if not spec_supported(cfg):
            raise SystemExit(f"--spec: arch {cfg.name!r} unsupported "
                             "(needs uniform full attention, one codebook)")
        draft_model = draft_params = None
        if args.spec == "draft":
            dcfg = get_config(args.draft_model or args.arch).reduced()
            if dcfg.vocab_size != cfg.vocab_size:
                raise SystemExit(
                    f"--draft-model {dcfg.name!r} vocab {dcfg.vocab_size} != "
                    f"target vocab {cfg.vocab_size}")
            draft_model = Model(dcfg, dtype=model.dtype)
            draft_params = draft_model.init(jax.random.key(1))
        spec_policy = make_draft_policy(args.spec, draft_model=draft_model,
                                        draft_params=draft_params)
        print(f"[spec] policy {spec_policy.name} depth {args.spec_n}: "
              f"~{expected_tokens_per_step(args.spec_n, DEFAULT_ACCEPT_RATE):.2f} "
              f"tok/step at accept rate {DEFAULT_ACCEPT_RATE}")

    # --- QEIL plan for this workload (simulated edge platform profile)
    from repro.quant import quant_workload
    w = Workload(batch=args.requests, prompt_tokens=args.prompt_len,
                 decode_tokens=args.max_new, samples=args.samples)
    w = quant_workload(w, args.quant,
                       kv_format="int8" if args.kv_int8 else "bf16")
    router = None
    if args.router:
        from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                                 default_tiers)
        orch = PGSAMOrchestrator(
            EDGE_PLATFORM, Constraints(latency_budget_factor=None),
            config=PGSAMConfig(seed=0, incremental=True))
        frontier = orch.pareto_frontier(cfg, w)
        placed = [a for a in frontier if a.mapping]
        if not placed:
            # nothing fits the platform: degrade to the same infeasible-plan
            # report the non-router path gives instead of crashing
            print(f"[router] no placeable operating point: "
                  f"{'; '.join(frontier[0].violations)}")
            router, plan = None, frontier[0]
        else:
            base = min(a.latency_s for a in placed) / 0.9
            router = ParetoRouter(orch, cfg, w, tiers=default_tiers(base))
            print(f"[router] archive {len(placed)} operating points")
            for name, d in sorted(router.route_all().items()):
                print(f"[router] tier {name:12s} -> point {d.point_index:2d} "
                      f"E={d.energy_j:.2f} J T={d.latency_s * 1e3:.1f} ms "
                      f"P={d.avg_power_w:.1f} W caps_met={d.meets_caps}")
            plan = router.route(args.tier).assignment
    else:
        orch = GreedyOrchestrator(EDGE_PLATFORM,
                                  Constraints(latency_budget_factor=1.0))
        plan = orch.assign(cfg, w)
    print(f"[orchestrator] devices={plan.device_names()} "
          f"energy={plan.energy_j:.2f} J latency={plan.latency_s * 1e3:.1f} ms "
          f"feasible={plan.feasible}")

    safety = SafetyMonitor(EDGE_PLATFORM, max_seq_len=args.prompt_len * 4,
                           vocab_size=cfg.vocab_size)

    rng = np.random.default_rng(0)
    prompts = []
    for _ in range(args.requests):
        p = rng.integers(0, cfg.vocab_size,
                         size=(args.prompt_len,)).astype(np.int32)
        if cfg.n_codebooks > 1:
            p = np.stack([p] * cfg.n_codebooks, -1)
        check = safety.validator.validate(p if p.ndim == 1 else p[:, 0],
                                          now_s=time.time() % 1e6)
        if not check.ok:
            print("[safety] rejected request:", check.reason)
            continue
        prompts.append(p)

    extras = {}
    if cfg.frontend == "vision":
        extras["vision_embeds"] = jnp.zeros(
            (len(prompts), 4, cfg.d_model), model.dtype)
    if cfg.cross_attention:
        extras["cond_memory"] = jnp.zeros(
            (len(prompts), cfg.n_cond_tokens, cfg.d_model), model.dtype)

    backend = None
    if args.kv_int8 and args.kv_blocks is None:
        raise SystemExit("--kv-int8 requires --kv-blocks (paged cache)")
    if args.kv_pool and args.kv_blocks is None:
        raise SystemExit("--kv-pool requires --kv-blocks (paged cache)")
    if args.prefill_chunk is not None and args.kv_blocks is None:
        raise SystemExit("--prefill-chunk requires --kv-blocks (paged "
                         "cache)")
    if (args.chaos or args.preempt or args.deadline) and not args.router:
        raise SystemExit("--chaos/--preempt/--deadline need --router "
                         "(the continuous-batching scheduler)")
    spec_kwargs = ({"spec_policy": spec_policy, "spec_n": args.spec_n}
                   if spec_policy is not None else {})
    if args.kv_blocks is not None:
        from repro.models.cache import paged_supported
        from repro.serving import ExecutionBackend
        if paged_supported(cfg):
            kv_format = "int8" if args.kv_int8 else "bf16"
            backend = ExecutionBackend(model, params, kv_blocks=args.kv_blocks,
                                       kv_block_size=args.kv_block_size,
                                       kv_format=kv_format, obs=obs,
                                       kv_pool=args.kv_pool,
                                       pool_evict=args.pool_evict,
                                       prefill_chunk=args.prefill_chunk,
                                       **spec_kwargs)
            print(f"[kv] paged cache: {args.kv_blocks} blocks x "
                  f"{args.kv_block_size} slots ({kv_format}, "
                  f"{backend.kv_token_bytes} B/token)")
            if args.kv_pool:
                print(f"[kv] resident prefix pool: cross-batch block "
                      f"reuse, evict={args.pool_evict}")
            if args.prefill_chunk:
                print(f"[kv] chunked prefill: <= {args.prefill_chunk} "
                      "tokens per slice, interleaved with decode")
        else:
            if args.prefill_chunk:
                raise SystemExit("--prefill-chunk requires a "
                                 "paging-supported arch")
            print(f"[kv] arch {cfg.name!r} unsupported for paging; "
                  "dense cache")
    if backend is None and spec_policy is not None:
        # drafting rides the dense cache too: an explicit backend carries
        # the policy where the engine would otherwise build a plain one
        from repro.serving import ExecutionBackend
        backend = ExecutionBackend(model, params, obs=obs, **spec_kwargs)
    engine = ServingEngine(model, params, max_new_tokens=args.max_new,
                           backend=backend, obs=obs)
    t0 = time.perf_counter()
    if router is not None:
        from repro.serving import ContinuousBatchingScheduler, SchedulerConfig
        spec_planner = None
        if spec_policy is not None:
            from repro.spec import SpecPlanner
            depths = tuple(sorted({0, args.spec_n // 2, args.spec_n}))
            spec_planner = SpecPlanner(args.spec, depths=depths,
                                       model_name=cfg.name)
        sched = ContinuousBatchingScheduler(
            engine.backend, router,
            SchedulerConfig(max_batch_requests=args.max_batch,
                            max_new_tokens=args.max_new,
                            preempt=args.preempt,
                            deadline_factor=args.deadline), obs=obs,
            spec_planner=spec_planner)
        chaos = None
        if args.chaos:
            from repro.serving.chaos import FaultPlan, attach
            plan_doc = FaultPlan.load(args.chaos)
            chaos = attach(plan_doc, safety, sched)
            print(f"[chaos] plan seed={plan_doc.seed}: "
                  f"{len(plan_doc.actions)} actions")
        tiers = (["interactive", "standard", "economy"] if args.mixed
                 else [args.tier])
        ids = []
        for i, p in enumerate(prompts):
            row = {k: np.asarray(v)[i] for k, v in extras.items()} or None
            adm = sched.submit(p, tier=tiers[i % len(tiers)],
                               n_samples=args.samples, extras=row)
            if adm.admitted:
                ids.append(adm.request_id)
            else:
                print(f"[admission] rejected request {i}: {adm.reason}")
        if chaos is not None or (args.metrics_out and obs.metrics.enabled):
            # drain explicitly so the chaos plan fires on the simulated
            # clock / the reporter snapshots between steps
            reporter = (PeriodicReporter(obs.metrics, args.metrics_out,
                                         interval_s=args.metrics_interval)
                        if args.metrics_out and obs.metrics.enabled
                        else None)
            while sched.queue.pending or sched.inflight:
                if chaos is not None:
                    for act in chaos.apply_due(sched.clock):
                        print(f"[chaos] t={act.t_s:.2f}s {act.kind} "
                              f"{act.device or ''}".rstrip())
                if not sched.step():
                    break
                if reporter is not None:
                    reporter.maybe_write(sched.clock)
            done = sched.completed
        else:
            done = sched.run_until_idle()
        st = sched.stats()
        if st["preemptions_total"] or st["cancelled"]:
            print(f"[robustness] preemptions={st['preemptions']} "
                  f"deadline_misses={st['deadline_misses']} "
                  f"retries={st['retries_total']} shed={st['shed_total']} "
                  f"resume_tail/full={st['resume_tail_tokens']}/"
                  f"{st['resume_full_tokens']} tokens")
        for rec in sched.records:
            spec = ""
            if rec.spec_n:
                rate = (f" a={rec.spec_accept_rate:.2f}"
                        if rec.spec_accept_rate is not None else "")
                spec = f" spec={rec.spec_policy}:{rec.spec_n}{rate}"
            pool = ""
            if args.kv_pool:
                pool = (f" pool_hits={rec.pool_hit_blocks}"
                        f" evict={rec.pool_evictions}")
            print(f"[scheduler] batch {rec.batch_id}: "
                  f"{rec.n_requests} req ({rec.tier_mix}) -> point "
                  f"{rec.point_index} E={rec.energy_j * 1e3:.2f} mJ "
                  f"T={rec.latency_s * 1e3:.2f} ms "
                  f"queue={rec.queue_delay_s * 1e3:.2f} ms "
                  f"caps_met={rec.meets_caps}{spec}{pool}")
        if args.kv_pool and backend is not None and \
                backend.prefix_pool is not None:
            st = sched.stats()
            resident = backend.prefix_pool.blocks_resident
            cached = resident * args.kv_block_size * backend.kv_token_bytes
            print(f"[kv] prefix pool: {st['pool_hit_blocks']} hit blocks, "
                  f"{st['pool_evictions']} evictions, "
                  f"{st['prefill_bytes_saved'] / 1e3:.1f} kB prefill "
                  f"saved; {resident} blocks resident "
                  f"({cached / 1e3:.1f} kB cached)")
        # lifecycle policies may cancel (deadline/shed); report completions
        results = [done[i].result for i in ids if i in done]
    else:
        results = engine.generate(prompts, n_samples=args.samples,
                                  extras=extras)
    dt = time.perf_counter() - t0
    n_tok = sum(r.decode_tokens for r in results)
    print(f"[serve] {len(results)} requests x {args.samples} samples, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.0f} tok/s)")
    for i, r in enumerate(results[:3]):
        print(f"  req {i}: best logprob {max(r.logprobs):.3f}")

    if args.metrics_out and obs.metrics.enabled:
        obs.metrics.write(args.metrics_out)
        print(f"[obs] metrics snapshot -> {args.metrics_out} (+ .prom)")
    if args.spans_out and obs.tracer.enabled:
        obs.tracer.save(args.spans_out)
        print(f"[obs] {len(obs.tracer)} spans -> {args.spans_out}")


if __name__ == "__main__":
    main()
