"""Serving launcher: batched requests through the engine with QEIL
orchestration + safety monitoring in the loop.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m \
      --smoke --requests 8 --samples 4

``--router`` replaces the one-shot greedy plan with the Pareto-routed
runtime: a PGSAM anneal builds the non-dominated archive once, and each
``generate`` call is placed at the operating point its SLA tier scalarizes
out of the archive (`repro.qeil2.runtime`).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import (Constraints, GreedyOrchestrator, SafetyMonitor,
                        Workload, EDGE_PLATFORM)
from repro.models import Model
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--samples", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--router", action="store_true",
                    help="frontier-driven placement per request tier "
                         "(PGSAM archive + SLA router)")
    ap.add_argument("--tier", default="standard",
                    choices=["interactive", "standard", "economy"],
                    help="SLA tier to serve this batch under (--router)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.key(0))

    # --- QEIL plan for this workload (simulated edge platform profile)
    w = Workload(batch=args.requests, prompt_tokens=args.prompt_len,
                 decode_tokens=args.max_new, samples=args.samples)
    router = None
    if args.router:
        from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                                 default_tiers)
        orch = PGSAMOrchestrator(
            EDGE_PLATFORM, Constraints(latency_budget_factor=None),
            config=PGSAMConfig(seed=0, incremental=True))
        frontier = orch.pareto_frontier(cfg, w)
        placed = [a for a in frontier if a.mapping]
        if not placed:
            # nothing fits the platform: degrade to the same infeasible-plan
            # report the non-router path gives instead of crashing
            print(f"[router] no placeable operating point: "
                  f"{'; '.join(frontier[0].violations)}")
            router, plan = None, frontier[0]
        else:
            base = min(a.latency_s for a in placed) / 0.9
            router = ParetoRouter(orch, cfg, w, tiers=default_tiers(base))
            print(f"[router] archive {len(placed)} operating points")
            for name, d in sorted(router.route_all().items()):
                print(f"[router] tier {name:12s} -> point {d.point_index:2d} "
                      f"E={d.energy_j:.2f} J T={d.latency_s * 1e3:.1f} ms "
                      f"P={d.avg_power_w:.1f} W caps_met={d.meets_caps}")
            plan = router.route(args.tier).assignment
    else:
        orch = GreedyOrchestrator(EDGE_PLATFORM,
                                  Constraints(latency_budget_factor=1.0))
        plan = orch.assign(cfg, w)
    print(f"[orchestrator] devices={plan.device_names()} "
          f"energy={plan.energy_j:.2f} J latency={plan.latency_s * 1e3:.1f} ms "
          f"feasible={plan.feasible}")

    safety = SafetyMonitor(EDGE_PLATFORM, max_seq_len=args.prompt_len * 4,
                           vocab_size=cfg.vocab_size)

    rng = np.random.default_rng(0)
    prompts = []
    for _ in range(args.requests):
        p = rng.integers(0, cfg.vocab_size,
                         size=(args.prompt_len,)).astype(np.int32)
        if cfg.n_codebooks > 1:
            p = np.stack([p] * cfg.n_codebooks, -1)
        check = safety.validator.validate(p if p.ndim == 1 else p[:, 0],
                                          now_s=time.time() % 1e6)
        if not check.ok:
            print("[safety] rejected request:", check.reason)
            continue
        prompts.append(p)

    extras = {}
    if cfg.frontend == "vision":
        extras["vision_embeds"] = jnp.zeros(
            (len(prompts), 4, cfg.d_model), model.dtype)
    if cfg.cross_attention:
        extras["cond_memory"] = jnp.zeros(
            (len(prompts), cfg.n_cond_tokens, cfg.d_model), model.dtype)

    engine = ServingEngine(model, params, max_new_tokens=args.max_new)
    t0 = time.perf_counter()
    if router is not None:
        from repro.qeil2 import RoutedServingEngine
        routed = RoutedServingEngine(engine, router, default_tier=args.tier)
        results = routed.generate(prompts, n_samples=args.samples,
                                  extras=extras)
        d = routed.decisions[-1]
        print(f"[router] generate placed at point {d.point_index} "
              f"({d.tier.name}): {d.assignment.device_names()}")
    else:
        results = engine.generate(prompts, n_samples=args.samples,
                                  extras=extras)
    dt = time.perf_counter() - t0
    n_tok = sum(r.decode_tokens for r in results)
    print(f"[serve] {len(results)} requests x {args.samples} samples, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.0f} tok/s)")
    for i, r in enumerate(results[:3]):
        print(f"  req {i}: best logprob {max(r.logprobs):.3f}")


if __name__ == "__main__":
    main()
