"""Serving launcher: batched requests through the engine with QEIL
orchestration + safety monitoring in the loop.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m \
      --smoke --requests 8 --samples 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import (Constraints, GreedyOrchestrator, SafetyMonitor,
                        Workload, EDGE_PLATFORM)
from repro.models import Model
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--samples", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.key(0))

    # --- QEIL plan for this workload (simulated edge platform profile)
    w = Workload(batch=args.requests, prompt_tokens=args.prompt_len,
                 decode_tokens=args.max_new, samples=args.samples)
    orch = GreedyOrchestrator(EDGE_PLATFORM,
                              Constraints(latency_budget_factor=1.0))
    plan = orch.assign(cfg, w)
    print(f"[orchestrator] devices={plan.device_names()} "
          f"energy={plan.energy_j:.2f} J latency={plan.latency_s * 1e3:.1f} ms "
          f"feasible={plan.feasible}")

    safety = SafetyMonitor(EDGE_PLATFORM, max_seq_len=args.prompt_len * 4,
                           vocab_size=cfg.vocab_size)

    rng = np.random.default_rng(0)
    prompts = []
    for _ in range(args.requests):
        p = rng.integers(0, cfg.vocab_size,
                         size=(args.prompt_len,)).astype(np.int32)
        if cfg.n_codebooks > 1:
            p = np.stack([p] * cfg.n_codebooks, -1)
        check = safety.validator.validate(p if p.ndim == 1 else p[:, 0],
                                          now_s=time.time() % 1e6)
        if not check.ok:
            print("[safety] rejected request:", check.reason)
            continue
        prompts.append(p)

    extras = {}
    if cfg.frontend == "vision":
        extras["vision_embeds"] = jnp.zeros(
            (len(prompts), 4, cfg.d_model), model.dtype)
    if cfg.cross_attention:
        extras["cond_memory"] = jnp.zeros(
            (len(prompts), cfg.n_cond_tokens, cfg.d_model), model.dtype)

    engine = ServingEngine(model, params, max_new_tokens=args.max_new)
    t0 = time.perf_counter()
    results = engine.generate(prompts, n_samples=args.samples, extras=extras)
    dt = time.perf_counter() - t0
    n_tok = sum(r.decode_tokens for r in results)
    print(f"[serve] {len(results)} requests x {args.samples} samples, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.0f} tok/s)")
    for i, r in enumerate(results[:3]):
        print(f"  req {i}: best logprob {max(r.logprobs):.3f}")


if __name__ == "__main__":
    main()
