"""Metrics registry: counters / gauges / bucketed histograms with Prometheus
text exposition and a JSON snapshot.

Design constraints, in order:

* **Zero-cost-when-off.** Components resolve their metric handles once at
  construction (``registry.counter(...)`` is get-or-create) and guard hot
  paths on ``registry.enabled``; the `NullRegistry` hands back one shared
  no-op metric so an uninstrumented server pays a single attribute load per
  guarded site. A pinned test asserts serving output is bit-identical with
  metrics on vs. off — metrics are pure observers and never touch the rng
  stream.
* **No background machinery.** Nothing here spawns threads or reads clocks;
  the `PeriodicReporter` is driven by the serving loop (`launch/serve
  --metrics-out/--metrics-interval`) and writes both the JSON snapshot and
  the Prometheus text file (``<out>.prom``) whenever the caller's clock says
  the interval elapsed.
* **Prometheus-compatible exposition.** `MetricsRegistry.to_prometheus`
  renders the standard text format: ``# HELP`` / ``# TYPE`` headers,
  ``name{label="v"} value`` samples, histogram ``_bucket``/``_sum``/
  ``_count`` series with *cumulative* bucket counts and a ``+Inf`` bucket.
  Output is sorted (names, then label values) so two snapshots of the same
  state are byte-identical — the formatting tests pin exact text.

Histograms use fixed bucket edges chosen at creation (`DEFAULT_BUCKETS`
mirrors the Prometheus client default). ``quantile(q)`` interpolates within
the owning bucket, so estimates are always bounded by the bucket's edges —
the hypothesis invariant tests in ``tests/test_obs.py`` pin bucket-count
conservation, cumulative monotonicity and that bound.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

# prometheus client defaults: latency-flavored edges in seconds
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# edges for ratio-valued observations in [0, 1] (e.g. speculative-decode
# accept rates, per-batch prefix-pool hit ratios): uniform tenths, with the
# 1.0 edge catching exact unity
RATIO_BUCKETS: Tuple[float, ...] = (
    0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)


def _escape_label(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _fmt_value(v: float) -> str:
    """Prometheus sample value: integers render bare (``3`` not ``3.0``)."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Metric:
    """Shared label plumbing: one child value per label-value tuple."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], Any] = {}

    def _key(self, labels: Dict[str, Any]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def label_sets(self) -> List[Tuple[str, ...]]:
        return sorted(self._children)

    def _labels_dict(self, key: Tuple[str, ...]) -> Dict[str, str]:
        return dict(zip(self.labelnames, key))


class Counter(_Metric):
    """Monotonically increasing count. ``inc`` rejects negative amounts —
    a counter that can go down is a gauge wearing the wrong type."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc({amount}))")
        k = self._key(labels)
        self._children[k] = self._children.get(k, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return self._children.get(self._key(labels), 0.0)


class Gauge(_Metric):
    """Point-in-time value. ``set_max`` is the high-water helper (KV block
    peaks): keeps the running maximum of everything set through it."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._children[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = self._key(labels)
        self._children[k] = self._children.get(k, 0.0) + float(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def set_max(self, value: float, **labels) -> None:
        k = self._key(labels)
        self._children[k] = max(self._children.get(k, float("-inf")),
                                float(value))

    def value(self, **labels) -> float:
        return self._children.get(self._key(labels), 0.0)


class _HistChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1: overflow (+Inf) bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Fixed-bucket histogram: per-bucket counts + sum + count.

    ``buckets`` are the upper edges (strictly increasing); observations land
    in the first bucket whose edge is ``>= v``, or the implicit ``+Inf``
    overflow bucket. Designed for non-negative observations (latencies,
    sizes) — ``quantile`` treats 0 as the lower edge of the first bucket.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(float(b) for b in buckets)
        if not self.buckets or \
                any(a >= b for a, b in zip(self.buckets, self.buckets[1:])):
            raise ValueError(f"histogram {name!r} buckets must be non-empty "
                             f"and strictly increasing: {self.buckets}")

    def _child(self, labels: Dict[str, Any]) -> _HistChild:
        k = self._key(labels)
        child = self._children.get(k)
        if child is None:
            child = self._children[k] = _HistChild(len(self.buckets))
        return child

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        child = self._child(labels)
        i = len(self.buckets)                     # overflow by default
        for j, edge in enumerate(self.buckets):
            if v <= edge:
                i = j
                break
        child.counts[i] += 1
        child.sum += v
        child.count += 1

    # ------------------------------------------------------------- queries
    def bucket_counts(self, **labels) -> List[int]:
        """Per-bucket (non-cumulative) counts, overflow bucket last."""
        return list(self._child(labels).counts)

    def cumulative_counts(self, **labels) -> List[int]:
        out, acc = [], 0
        for c in self._child(labels).counts:
            acc += c
            out.append(acc)
        return out

    def total(self, **labels) -> int:
        return self._child(labels).count

    def sum_value(self, **labels) -> float:
        return self._child(labels).sum

    def quantile(self, q: float, **labels) -> float:
        """Bucket-interpolated quantile estimate (the classic Prometheus
        ``histogram_quantile``): linear within the owning bucket, clamped to
        the largest finite edge when the target rank falls in the overflow
        bucket. Returns nan for an empty series."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        child = self._child(labels)
        if child.count == 0:
            return float("nan")
        target = q * child.count
        acc = 0
        for i, c in enumerate(child.counts):
            prev = acc
            acc += c
            if acc >= target and c > 0:
                if i == len(self.buckets):        # overflow: no finite edge
                    return self.buckets[-1]
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i]
                return lo + (hi - lo) * (target - prev) / c
        return self.buckets[-1]


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Re-requesting a name returns the existing metric; re-requesting with a
    conflicting type or label set raises — two components disagreeing about
    a metric's shape is a bug, not a merge.
    """

    enabled = True

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Tuple[str, ...], **kw) -> _Metric:
        existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, cls) or \
                    existing.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind} with labels {existing.labelnames}")
            return existing
        metric = cls(name, help, tuple(labelnames), **kw)
        self._metrics[name] = metric
        return metric

    def counter(self, name: str, help: str = "",
                labelnames: Tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Tuple[str, ...] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ---------------------------------------------------------- exposition
    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key in m.label_sets():
                    base = m._labels_dict(key)
                    cum = 0
                    child = m._children[key]
                    for edge, c in zip(m.buckets + (float("inf"),),
                                       child.counts):
                        cum += c
                        lbl = {**base, "le": _fmt_value(edge)}
                        lines.append(f"{name}_bucket{_render_labels(lbl)} "
                                     f"{cum}")
                    lines.append(f"{name}_sum{_render_labels(base)} "
                                 f"{_fmt_value(child.sum)}")
                    lines.append(f"{name}_count{_render_labels(base)} "
                                 f"{child.count}")
            else:
                for key in m.label_sets():
                    lbl = m._labels_dict(key)
                    lines.append(f"{name}{_render_labels(lbl)} "
                                 f"{_fmt_value(m._children[key])}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable view of every metric and its label children."""
        out: Dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            entry: Dict[str, Any] = {"type": m.kind, "help": m.help,
                                     "labelnames": list(m.labelnames),
                                     "values": []}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
                for key in m.label_sets():
                    child = m._children[key]
                    entry["values"].append({
                        "labels": m._labels_dict(key),
                        "counts": list(child.counts),
                        "sum": child.sum, "count": child.count})
            else:
                for key in m.label_sets():
                    entry["values"].append({
                        "labels": m._labels_dict(key),
                        "value": m._children[key]})
            out[name] = entry
        return out

    def write(self, path: str) -> str:
        """Write the JSON snapshot to ``path`` and the Prometheus text to a
        ``.prom`` sibling; returns the sibling path."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
        prom = os.path.splitext(path)[0] + ".prom"
        with open(prom, "w") as f:
            f.write(self.to_prometheus())
        return prom


def _render_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _NullMetric:
    """One no-op stands in for every metric type when metrics are off."""

    def inc(self, *a, **k):
        pass

    def dec(self, *a, **k):
        pass

    def set(self, *a, **k):
        pass

    def set_max(self, *a, **k):
        pass

    def observe(self, *a, **k):
        pass

    def value(self, *a, **k) -> float:
        return 0.0


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Disabled registry: every factory returns the shared no-op metric.
    ``enabled`` is the guard components check before building label dicts or
    computing values on hot paths."""

    enabled = False

    def counter(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def snapshot(self) -> Dict[str, Any]:
        return {}

    def to_prometheus(self) -> str:
        return ""

    def write(self, path: str) -> str:
        raise RuntimeError("NullRegistry has nothing to write; construct a "
                           "MetricsRegistry (repro.obs.make_observability)")


class PeriodicReporter:
    """Interval-driven snapshot writer, clocked by the caller.

    The serving loop calls ``maybe_write(now)`` once per iteration; a write
    happens when ``interval_s`` elapsed since the last one (and always on
    the first call, so even a short run leaves a snapshot behind).
    """

    def __init__(self, registry: MetricsRegistry, path: str,
                 interval_s: float = 5.0):
        self.registry = registry
        self.path = path
        self.interval_s = float(interval_s)
        self._last: Optional[float] = None
        self.writes = 0

    def maybe_write(self, now_s: float) -> bool:
        if self._last is not None and now_s - self._last < self.interval_s:
            return False
        self.write()
        self._last = now_s
        return True

    def write(self) -> str:
        prom = self.registry.write(self.path)
        self.writes += 1
        return prom
