"""Request-span tracing for the serving pipeline.

A span is one named interval on an **explicit clock**: the emitter supplies
``t0_s``/``t1_s`` and says which clock they came from (``clock="sim"`` — the
scheduler's simulated pipeline clock the SLA caps are defined on — or
``clock="wall"`` for backend execution timings). The tracer never reads time
itself, so sim-clock and wall-clock spans coexist in one trace without
lying about comparability.

Lifecycle of one admitted request (sim clock unless noted)::

    admit ──► queue ──► [batch: schedule ──► prefill(wall) ──► decode*(wall)]
                   └──────────────────────► verify/early_stop? ──► release

``admit`` is the request's *root* span; later spans carrying the same
``request_id`` auto-parent under it, and batch-level spans (``schedule`` /
``prefill`` / ``decode``) attach to requests through ``batch_id`` — the
``queue`` span records which batch joined the request to its batch-level
children. `reconstruct_lifecycles` inverts this: given the emitted spans it
rebuilds every admitted request's admit→release chain and reports whether
the chain is complete and time-ordered (the serving bench gates on it).

Spans are JSONL-ready dicts (`Span.as_record`) with ``kind: "span"`` —
`TraceStore` validates and persists them next to kernel/energy/serve
records, so span traces ride the same files the `CalibrationFitter` reads.

`NullTracer` is the zero-cost default: ``enabled`` is False and ``emit`` is
a no-op, so instrumented hot paths guard on one attribute load. Emitting is
a pure observation — tracers never touch the rng stream; the obs on/off
bit-parity test pins that.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Union

#: canonical span names in lifecycle order (docs + lifecycle checker).
#: preempt/resume/cancel are the robustness detours: a preempted request
#: re-queues (original arrival kept) and later emits a resume point span
#: when its history re-enters service; cancel ends a request without a
#: release (deadline miss, load shed, retry budget exhausted).
LIFECYCLE = ("admit", "queue", "schedule", "prefill", "decode",
             "verify", "early_stop", "preempt", "resume", "cancel",
             "release")


@dataclass
class Span:
    span_id: int
    name: str
    t0_s: float
    t1_s: float
    clock: str = "sim"                 # "sim" | "wall"
    parent_id: Optional[int] = None
    request_id: Optional[int] = None
    batch_id: Optional[int] = None
    sample: Optional[int] = None       # sample index within the request
    attrs: Dict[str, Any] = field(default_factory=dict)

    def as_record(self) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"kind": "span", "span_id": self.span_id,
                               "name": self.name, "t0_s": self.t0_s,
                               "t1_s": self.t1_s, "clock": self.clock}
        for k in ("parent_id", "request_id", "batch_id", "sample"):
            v = getattr(self, k)
            if v is not None:
                rec[k] = v
        if self.attrs:
            rec["attrs"] = dict(self.attrs)
        return rec


class Tracer:
    """Collects spans; optionally mirrors them into a `TraceStore`.

    ``emit`` is the whole API: components report completed (or point)
    intervals with explicit timestamps. ``batch_context`` is scratch the
    scheduler sets around backend calls so backend-emitted wall-clock spans
    pick up the forming batch's id without widening the duck-typed backend
    signature.
    """

    enabled = True

    def __init__(self, store=None):
        self.spans: List[Span] = []
        self.store = store             # optional TraceStore mirror
        self.batch_context: Optional[int] = None
        self._roots: Dict[int, int] = {}   # request_id -> admit span_id
        self._next = 0

    def emit(self, name: str, t0_s: float, t1_s: Optional[float] = None,
             *, clock: str = "sim", request_id: Optional[int] = None,
             batch_id: Optional[int] = None, sample: Optional[int] = None,
             parent_id: Optional[int] = None, **attrs) -> int:
        """Record one span; returns its id. ``t1_s`` defaults to ``t0_s``
        (a point event). An ``admit`` span becomes its request's root;
        later spans with that ``request_id`` parent under it."""
        sid = self._next
        self._next += 1
        if batch_id is None:
            batch_id = self.batch_context
        if parent_id is None and request_id is not None:
            parent_id = self._roots.get(request_id)
        span = Span(sid, name, float(t0_s),
                    float(t1_s if t1_s is not None else t0_s),
                    clock=clock, parent_id=parent_id, request_id=request_id,
                    batch_id=batch_id, sample=sample, attrs=attrs)
        if name == "admit" and request_id is not None:
            self._roots[request_id] = sid
        self.spans.append(span)
        if self.store is not None:
            self.store.ingest(span.as_record())
        return sid

    def records(self) -> List[Dict[str, Any]]:
        return [s.as_record() for s in self.spans]

    def save(self, path: str) -> str:
        """Write every span as one JSON line (`TraceStore.load`-compatible)."""
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps(s.as_record()) + "\n")
        return path

    def __len__(self) -> int:
        return len(self.spans)


class NullTracer:
    """Disabled tracer: ``emit`` no-ops; hot paths guard on ``enabled``."""

    enabled = False

    def __init__(self):
        self.spans: List[Span] = []
        self.batch_context: Optional[int] = None

    def emit(self, *a, **k) -> int:
        return -1

    def records(self) -> List[Dict[str, Any]]:
        return []

    def save(self, path: str) -> str:
        raise RuntimeError("NullTracer has no spans to save; construct a "
                           "Tracer (repro.obs.make_observability)")

    def __len__(self) -> int:
        return 0


# ------------------------------------------------------- lifecycle checking

def _as_dicts(spans: Iterable[Union[Span, Dict[str, Any]]]
              ) -> List[Dict[str, Any]]:
    return [s.as_record() if isinstance(s, Span) else s for s in spans]


def reconstruct_lifecycles(spans: Iterable[Union[Span, Dict[str, Any]]]
                           ) -> Dict[int, Dict[str, Any]]:
    """Rebuild every admitted request's admit→release chain from a span set.

    Returns ``{request_id: {"complete": bool, "missing": [...],
    "batch_id": ..., "queue_delay_s": ..., "latency_s": ...}}``. A chain is
    complete when the request has admit, queue and release spans, its queue
    span names a batch that emitted schedule + prefill + >=1 decode span,
    and the sim-clock times are ordered (admit <= queue start <= queue end
    <= release). Rejected submissions (admit spans with no ``request_id``)
    are not lifecycles and are ignored.
    """
    recs = _as_dicts(spans)
    by_req: Dict[int, Dict[str, List[dict]]] = {}
    by_batch: Dict[int, Dict[str, List[dict]]] = {}
    for r in recs:
        if r.get("kind", "span") != "span":
            continue
        rid, bid = r.get("request_id"), r.get("batch_id")
        if rid is not None:
            by_req.setdefault(rid, {}).setdefault(r["name"], []).append(r)
        elif bid is not None:
            by_batch.setdefault(bid, {}).setdefault(r["name"], []).append(r)

    out: Dict[int, Dict[str, Any]] = {}
    for rid, named in sorted(by_req.items()):
        if "admit" not in named:
            continue
        missing = [n for n in ("admit", "queue", "release") if n not in named]
        admit = named["admit"][0]
        queue = named.get("queue", [{}])[0]
        release = named.get("release", [{}])[0]
        bid = queue.get("batch_id", release.get("batch_id"))
        batch = by_batch.get(bid, {})
        for n in ("schedule", "prefill", "decode"):
            if n not in batch and n not in named:
                missing.append(n)
        ordered = not missing and (
            admit["t0_s"] <= queue["t0_s"] <= queue["t1_s"]
            <= release["t1_s"])
        out[rid] = {
            "complete": not missing and ordered,
            "missing": missing,
            "batch_id": bid,
            "queue_delay_s": (queue["t1_s"] - queue["t0_s"]
                              if "queue" in named else None),
            "latency_s": (release["t1_s"] - admit["t0_s"]
                          if "release" in named else None),
        }
    return out


def lifecycles_complete(spans: Iterable[Union[Span, Dict[str, Any]]],
                        expect_requests: Optional[int] = None) -> bool:
    """True when every reconstructed lifecycle is complete (and, when
    ``expect_requests`` is given, exactly that many requests appear)."""
    lifecycles = reconstruct_lifecycles(spans)
    if expect_requests is not None and len(lifecycles) != expect_requests:
        return False
    return bool(lifecycles) and all(v["complete"]
                                    for v in lifecycles.values())
