"""Kernel profiling hooks: named scopes for the Pallas kernel call sites.

Two complementary annotations, both no-ops in cost when no profiler is
attached:

* `kernel_scope(name)` — wraps a kernel's ops-layer body in
  ``jax.named_scope``, so every HLO op the kernel lowers to carries
  ``repro.kernels/<name>`` metadata. This works *inside* jit (it annotates
  at trace time) and is how XLA profiles / ``jax.profiler`` traces
  attribute device time back to the kernel that produced it. A
  ``jax.profiler.TraceAnnotation`` is layered on when available: under jit
  it only brackets trace time, but the same ops wrappers are also called
  eagerly (interpret-mode tests, `launch/profile`), where it emits real
  host TraceMe events.
* `annotate(name)` — host-level ``TraceAnnotation`` alone, for timing loops
  that live outside jit (the `repro.launch.profile` rep timer).

`tpu_roofline_us` is the shared roofline-time helper (same formula as
``benchmarks/kernel_bench._tpu_roofline_us``) so profile records price
their flops/bytes against the identical modeled ceiling the calibration
fitter expects.

jax is imported lazily so ``repro.obs`` stays importable (metrics, tracer)
in tooling contexts without jax on the path.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

#: named_scope prefix for every instrumented kernel call site
SCOPE_PREFIX = "repro.kernels"


def _trace_annotation(label: str):
    """Host-level TraceMe context when the jax build has one, else a
    null context (older jax: TraceAnnotation lived elsewhere/not at all)."""
    import jax
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    return ta(label) if ta is not None else contextlib.nullcontext()


@contextlib.contextmanager
def kernel_scope(name: str) -> Iterator[None]:
    """Annotate one kernel call site: HLO metadata (named_scope) + host
    TraceMe. Wraps the ops-layer body, inside or outside jit."""
    import jax
    label = f"{SCOPE_PREFIX}/{name}"
    with jax.named_scope(label), _trace_annotation(label):
        yield


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Host-level profiler annotation only (timing loops outside jit)."""
    with _trace_annotation(f"{SCOPE_PREFIX}/{name}"):
        yield


def tpu_roofline_us(flops: float, bytes_moved: float) -> float:
    """Modeled TPU v5e roofline time for one kernel invocation, in us —
    the ceiling the per-kernel duty factor eta is fit against."""
    from repro.core.devices import TPU_V5E
    t = max(flops / (TPU_V5E.peak_flops * TPU_V5E.util),
            bytes_moved / (TPU_V5E.mem_bw * TPU_V5E.util))
    return t * 1e6
