"""Serving observability: span tracing + metrics + kernel profiling hooks.

One `Observability` bundle threads through the serving pipeline
(`RequestQueue` / `ContinuousBatchingScheduler` / `ExecutionBackend` /
`VerifierCascade` / `ControlLoop`): components take ``obs=None`` and fall
back to `NULL_OBS`, whose `NullTracer`/`NullRegistry` make every
instrumentation site a guarded no-op — serving output is bit-identical and
overhead is gated <5% on ``benchmarks/serving_schedule.py`` with the full
stack on.

    from repro.obs import make_observability
    obs = make_observability()                 # live tracer + registry
    sched = ContinuousBatchingScheduler(backend, router, cfg, obs=obs)
    ...
    obs.metrics.write("metrics.json")          # + metrics.prom sibling
    obs.tracer.save("spans.jsonl")             # TraceStore-compatible

This package is dependency-light by design: metrics and tracing are pure
python/stdlib; `profiling` imports jax lazily.
"""
from repro.obs.metrics import (Counter, DEFAULT_BUCKETS, Gauge, Histogram,
                               MetricsRegistry, NullRegistry,
                               PeriodicReporter)
from repro.obs.profiling import annotate, kernel_scope, tpu_roofline_us
from repro.obs.tracer import (LIFECYCLE, NullTracer, Span, Tracer,
                              lifecycles_complete, reconstruct_lifecycles)


class Observability:
    """The bundle components thread: a tracer and a metrics registry.
    ``enabled`` is True when either side is live."""

    def __init__(self, tracer, metrics):
        self.tracer = tracer
        self.metrics = metrics

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled or self.metrics.enabled


#: shared disabled bundle — the default for every ``obs=None`` component
NULL_OBS = Observability(NullTracer(), NullRegistry())


def make_observability(store=None) -> Observability:
    """A live bundle: fresh `Tracer` (optionally mirroring spans into a
    `TraceStore`) + fresh `MetricsRegistry`."""
    return Observability(Tracer(store=store), MetricsRegistry())


__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "LIFECYCLE",
    "MetricsRegistry", "NULL_OBS", "NullRegistry", "NullTracer",
    "Observability", "PeriodicReporter", "Span", "Tracer", "annotate",
    "kernel_scope", "lifecycles_complete", "make_observability",
    "reconstruct_lifecycles", "tpu_roofline_us",
]
