"""Unified model: config -> params / forward / prefill / decode.

One class serves all ten assigned architectures. The decoder stack is a
``jax.lax.scan`` over stacked super-block parameters (HLO size independent of depth);
non-uniform leading layers (deepseek's first dense layer) are unrolled as "prefix"
layers.

Modes:
  * train:   ``forward(params, batch)`` — full causal sequence, no cache.
  * prefill: ``forward(params, batch, cache=fresh_cache)`` — fills the cache.
  * decode:  ``forward(params, batch, cache=cache)`` with S==1 — serve_step.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models import cache as cache_mod
from repro.models.config import ArchConfig
from repro.models.layers import (embed, embed_init, embed_spec, lm_head,
                                 lm_head_init, lm_head_spec, rmsnorm,
                                 rmsnorm_init, rmsnorm_spec)


class Model:
    def __init__(self, cfg: ArchConfig, dtype=jnp.bfloat16,
                 remat: bool = False, use_kernel: bool = False):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.use_kernel = use_kernel

    # ------------------------------------------------------------------ params
    def _prefix_kinds(self):
        cfg = self.cfg
        period = len(cfg.pattern)
        return [(cfg.pattern[i % period],
                 "moe" if cfg.is_moe_layer(i) else "mlp")
                for i in range(cache_mod.n_prefix_layers(cfg))]

    def param_specs(self) -> Dict:
        cfg, dt = self.cfg, self.dtype
        n_prefix = cache_mod.n_prefix_layers(cfg)
        spec = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model, dt,
                                cfg.n_codebooks),
            "prefix": [blk._sublayer_spec(cfg, mx, ff, dt)
                       for mx, ff in self._prefix_kinds()],
            "blocks": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (cache_mod.n_scanned_super_blocks(cfg),) + s.shape, s.dtype),
                blk.super_block_spec(cfg, n_prefix, dt)),
            "final_norm": rmsnorm_spec(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = lm_head_spec(cfg.d_model, cfg.padded_vocab, dt,
                                           cfg.n_codebooks)
        return spec

    def init(self, rng) -> Dict:
        cfg, dt = self.cfg, self.dtype
        n_prefix = cache_mod.n_prefix_layers(cfg)
        n_super = cache_mod.n_scanned_super_blocks(cfg)
        k_embed, k_blocks, k_head, k_prefix = jax.random.split(rng, 4)
        block_keys = jax.random.split(k_blocks, n_super)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[blk.super_block_init(k, cfg, n_prefix, dt) for k in block_keys])
        prefix_keys = jax.random.split(k_prefix, max(n_prefix, 1))
        params = {
            "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt,
                                cfg.n_codebooks),
            "prefix": [blk._sublayer_init(prefix_keys[i], cfg, mx, ff, dt)
                       for i, (mx, ff) in enumerate(self._prefix_kinds())],
            "blocks": stacked,
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = lm_head_init(k_head, cfg.d_model,
                                             cfg.padded_vocab, dt,
                                             cfg.n_codebooks)
        return params

    def param_count(self) -> int:
        total = 0
        for leaf in jax.tree.leaves(self.param_specs()):
            n = 1
            for s in leaf.shape:
                n *= s
            total += n
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k + shared experts)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.moe is None:
            return total
        m = cfg.moe
        ff = cfg.expert_ff()
        per_expert = 3 * cfg.d_model * ff
        n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
        return total - inactive

    # ------------------------------------------------------------------ cache
    def init_cache(self, batch: int, cache_len: int, spec_only: bool = False):
        return cache_mod.make_cache(self.cfg, batch, cache_len, self.dtype,
                                    spec_only=spec_only)

    def init_paged_cache(self, n_blocks: int, block_size: int,
                         spec_only: bool = False, kv_dtype=None):
        """Block-pool cache (repro.models.cache paged layout); address it by
        passing ``batch["block_table"]`` (and a static ``kv_len``) to
        `forward`. ``kv_dtype=jnp.int8`` stores the pools quantized (half the
        bytes per token slot; scales ride alongside)."""
        return cache_mod.make_cache(
            self.cfg, 0, 0, self.dtype, spec_only=spec_only,
            paged=cache_mod.PagedLayout(n_blocks, block_size),
            kv_dtype=kv_dtype)

    # ------------------------------------------------------------------ forward
    def forward(self, params: Dict, batch: Dict,
                cache: Optional[Dict] = None,
                kv_len: Optional[int] = None,
                decode: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
        """Returns (logits, new_cache, aux_loss).

        ``batch["block_table"]`` switches attention caching to the paged
        layout (prefill: one row per unique prompt; decode: one row per
        sequence); ``kv_len`` is the static logical cache length the paged
        reference path slices the gathered pools to. ``decode=True`` forces
        the cache-attending decode branches even when S > 1 — the speculative
        verify step, where S = 1 + n drafted tokens score in one forward
        (`repro.spec`).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape[:2]
        block_table = batch.get("block_table")

        positions = batch.get("positions")
        if positions is None:
            base = batch.get("position_offset", 0)
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S)) + base
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions[..., None], (B, S, 3))

        h = embed(params["embed"], tokens)

        if cfg.rope_variant == "sinusoidal":  # musicgen-style additive positions
            half = cfg.d_model // 2
            freq = jnp.exp(-jnp.log(10000.0) *
                           jnp.arange(half, dtype=jnp.float32) / half)
            ang = positions[..., None].astype(jnp.float32) * freq
            h = h + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                                    axis=-1).astype(h.dtype)

        vision = batch.get("vision_embeds")
        if vision is not None and S > 1 and not decode:
            nv = min(vision.shape[1], S)
            h = h.at[:, :nv].set(vision[:, :nv].astype(h.dtype))

        memory = batch.get("cond_memory") if cfg.cross_attention else None

        aux_total = jnp.zeros((), jnp.float32)
        new_prefix = [] if cache is not None else None

        # ---- prefix layers (unrolled)
        for i, (mixer, _ffn) in enumerate(self._prefix_kinds()):
            sub_cache = cache["prefix"][i] if cache is not None else None
            h, nc, aux = blk.sublayer_forward(
                params["prefix"][i], cfg, h, positions, mixer, sub_cache,
                memory, self.use_kernel, block_table=block_table,
                kv_len=kv_len, decode=decode)
            aux_total = aux_total + aux
            if new_prefix is not None:
                new_prefix.append(nc)

        # ---- scanned super-blocks
        sb_fwd = functools.partial(blk.super_block_forward, cfg=cfg,
                                   positions=positions, memory=memory,
                                   use_kernel=self.use_kernel,
                                   block_table=block_table, kv_len=kv_len,
                                   decode=decode)
        if cache is None:
            def one(bp_, x_):
                x2_, _, a_ = sb_fwd(bp_, x=x_, cache=None)
                return x2_, a_

            if self.remat:
                one = jax.checkpoint(one)

            def body(carry, bp):
                x, aux = carry
                x2, a = one(bp, x)
                return (x2, aux + a), None

            (h, aux_s), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                         params["blocks"])
            new_cache = None
        else:
            def body(carry, inp):
                x, aux = carry
                bp, bc = inp
                x2, nc, a = sb_fwd(bp, x=x, cache=bc)
                return (x2, aux + a), nc

            (h, aux_s), new_blocks = jax.lax.scan(
                body, (h, jnp.zeros((), jnp.float32)),
                (params["blocks"], cache["blocks"]))
            new_cache = {"prefix": new_prefix, "blocks": new_blocks}

        aux_total = aux_total + aux_s
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        if cfg.tie_embeddings:
            table = params["embed"]["table"]
            logits = h @ table.T if table.ndim == 2 else jnp.einsum(
                "bsd,kvd->bskv", h, table)
        else:
            logits = lm_head(params["lm_head"], h)
        if cfg.padded_vocab != cfg.vocab_size:
            # mask pad columns: exact softmax/sampling over the true vocab
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype),
                               logits)
        return logits, new_cache, aux_total

    # ------------------------------------------------------------------ losses
    def loss(self, params: Dict, batch: Dict) -> jnp.ndarray:
        logits, _, aux = self.forward(params, batch)
        labels = batch["labels"]
        lf = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(lf, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return nll.mean() + aux
