"""Attention variants: GQA (llama/qwen/yi/chatglm), MLA (DeepSeek-V2), sliding-window,
cross-attention (musicgen), with unified train / prefill / decode entry points.

The jnp reference path here is the semantics oracle; the Pallas kernels in
``repro.kernels`` implement the same math for the TPU hot path (``use_kernel`` flag in
ops wrappers selects them; on CPU the reference path runs).

Cache layouts (see repro.models.cache):
  * GQA:    k,v            (B, S_cache, n_kv, hd)        ring-buffered when windowed
  * MLA:    c_kv           (B, S_cache, kv_lora)  + k_rope (B, S_cache, rope_hd)
  * cross:  precomputed k,v over conditioning memory (immutable)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import (Params, apply_rope, dense, dense_init,
                                 dense_spec)

NEG_INF = -1e30


# =============================================================================
# parameter specs / init
# =============================================================================

def attn_spec(cfg: ArchConfig, dtype) -> Params:
    if cfg.mla is not None:
        return _mla_spec(cfg, dtype)
    hd = cfg.hd
    return {
        "wq": dense_spec(cfg.d_model, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_spec(cfg.d_model, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_spec(cfg.d_model, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_spec(cfg.n_heads * hd, cfg.d_model, dtype),
    }


def attn_init(key, cfg: ArchConfig, dtype) -> Params:
    if cfg.mla is not None:
        return _mla_init(key, cfg, dtype)
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }


def _mla_spec(cfg: ArchConfig, dtype) -> Params:
    m = cfg.mla
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": dense_spec(cfg.d_model, cfg.n_heads * qd, dtype),
        "w_dkv": dense_spec(cfg.d_model, m.kv_lora_rank, dtype),
        "w_krope": dense_spec(cfg.d_model, m.qk_rope_head_dim, dtype),
        "w_uk": dense_spec(m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim, dtype),
        "w_uv": dense_spec(m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dtype),
        "wo": dense_spec(cfg.n_heads * m.v_head_dim, cfg.d_model, dtype),
    }


def _mla_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.mla
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * qd, dtype),
        "w_dkv": dense_init(ks[1], cfg.d_model, m.kv_lora_rank, dtype),
        "w_krope": dense_init(ks[2], cfg.d_model, m.qk_rope_head_dim, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], cfg.n_heads * m.v_head_dim, cfg.d_model, dtype),
    }


def cross_attn_spec(cfg: ArchConfig, dtype) -> Params:
    hd = cfg.hd
    return {
        "wq": dense_spec(cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_spec(cfg.d_model, cfg.n_heads * hd, dtype),
        "wv": dense_spec(cfg.d_model, cfg.n_heads * hd, dtype),
        "wo": dense_spec(cfg.n_heads * hd, cfg.d_model, dtype),
    }


cross_attn_init = attn_init  # same structure when n_kv == n_heads


# =============================================================================
# masking / core softmax attention
# =============================================================================

def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: Optional[int]) -> jnp.ndarray:
    """Boolean mask (..., Sq, Sk): True = attend. Supports sliding window."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    ok &= k_pos[..., None, :] >= 0  # left-padding uses negative positions
    if window is not None:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return ok


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D'), GQA by head-group broadcast."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(v.dtype)


# Above this many score elements per (batch, head), causal attention switches
# to the q-blocked path: O(S * block) memory instead of O(S^2), GSPMD-safe
# (pure jnp inside lax.map — XLA shards it like any other einsum chain).
BLOCKED_THRESHOLD = 4_194_304  # 2048^2
BLOCK_Q = 512


def sdpa_causal_blocked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        positions: jnp.ndarray, window: Optional[int],
                        scale: float, block_q: int = BLOCK_Q) -> jnp.ndarray:
    """Causal attention without materializing (Sq, Sk) scores.

    Iterates q blocks with lax.map (scan-lowered: XLA keeps one block's
    scores live at a time, and remat recomputes them on the backward pass).
    positions: (B, S) absolute positions shared by q and k.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    pad = (-S) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions_q = jnp.pad(positions, ((0, 0), (0, pad)),
                              constant_values=-(10 ** 9))
    else:
        positions_q = positions
    nb = q.shape[1] // block_q
    qb = q.reshape(B, nb, block_q, Hq, D)
    pq = positions_q.reshape(B, nb, block_q)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_block(args):
        qi, pqi = args                      # (B, bq, Hq, D), (B, bq)
        qg = qi.reshape(B, block_q, Hkv, g, D).astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale
        ok = positions[:, None, :] <= pqi[:, :, None]
        ok &= positions[:, None, :] >= 0
        if window is not None:
            ok &= positions[:, None, :] > pqi[:, :, None] - window
        s = jnp.where(ok[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
        return o.reshape(B, block_q, Hq, vf.shape[-1])

    out = jax.lax.map(one_block, (jnp.moveaxis(qb, 1, 0),
                                  jnp.moveaxis(pq, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb * block_q, Hq, vf.shape[-1])
    return out[:, :S].astype(v.dtype)


# =============================================================================
# GQA attention — train / prefill / decode
# =============================================================================

def gqa_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray,
                cache: Optional[Dict] = None,
                use_kernel: bool = False,
                block_table: Optional[jnp.ndarray] = None,
                kv_len: Optional[int] = None,
                decode: bool = False) -> Tuple[jnp.ndarray,
                                               Optional[Dict]]:
    """Unified GQA attention.

    train/prefill: x (B,S,D), positions (B,S[,3]); cache None (train) or an empty
      cache dict to fill (prefill).
    decode: x (B,1,D); cache holds k/v + per-slot absolute positions; ring-buffer
      writes when cfg.attn_window is set.
    paged: with ``block_table`` (B, n_blocks) the cache entries are block
      pools (repro.models.cache paged layout); position p lives in pool block
      ``table[b, p // bs]`` row ``p % bs``. ``kv_len`` statically bounds the
      logical sequence so the gathered reference path is element-for-element
      identical to the dense cache (bit-exact parity).
    speculative verify: ``decode=True`` forces the cache-attending decode
      branches even when S > 1 — the S query tokens (last committed token +
      drafts) are scattered into the cache and each attends over every cache
      position ``<=`` its own, which both decode branches already express
      position-generically. Only the S==1 fast kernels are gated off.
    """
    B, S, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)

    if cfg.rope_variant not in ("none", "sinusoidal"):
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction,
                       cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction,
                       cfg.mrope_sections)

    scale = 1.0 / np.sqrt(hd)
    pos1d = positions[..., 0] if positions.ndim == 3 else positions

    # Routing is static: S > 1 means train/prefill (fresh cache), S == 1 means a
    # decode step against the ring cache. Chunked prefill (S > 1 with a non-empty
    # cache) is intentionally unsupported — the engine always prefills whole
    # prompts (see repro/serving/engine.py). ``decode=True`` overrides the S > 1
    # heuristic for speculative verify steps (multiple query tokens against the
    # populated cache).
    if cache is None or (S > 1 and not decode):
        # ---- train / prefill over full (possibly windowed) sequence
        if use_kernel:
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, k, v, causal=True,
                                         window=cfg.attn_window, scale=scale)
        elif S * S > BLOCKED_THRESHOLD:
            out = sdpa_causal_blocked(q, k, v, pos1d, cfg.attn_window, scale)
        else:
            mask = causal_mask(pos1d, pos1d, cfg.attn_window)
            out = sdpa(q, k, v, mask, scale)
        new_cache = None
        if cache is not None:
            new_cache = (_fill_cache_paged(cache, k, v, pos1d, block_table)
                         if block_table is not None
                         else _fill_cache(cfg, cache, k, v, pos1d))
        y = dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
        return y, new_cache

    if block_table is not None:
        # ---- paged decode: write through the block table (same scatter as
        # prefill), attend over the gathered (reference) or table-indexed
        # (kernel) pools
        new_cache = _fill_cache_paged(cache, k, v, pos1d, block_table)
        ck, cv, cpos = new_cache["k"], new_cache["v"], new_cache["pos"]
        quantized = ck.dtype == jnp.int8
        if use_kernel and not quantized and S == 1:
            from repro.kernels.decode_attention import ops as da_ops
            out = da_ops.paged_decode_attention(q, ck, cv, cpos, block_table,
                                                pos1d[:, 0], scale=scale)
        else:
            # gather the sequence's blocks in logical order and slice to the
            # exact cache length: element-for-element the dense decode path
            # (int8 pools dequantize here; the table-indexed kernel reads
            # bf16 pools only, so quantized caches take this path)
            kc = ck[block_table].reshape(B, -1, *ck.shape[2:])
            vc = cv[block_table].reshape(B, -1, *cv.shape[2:])
            pc = cpos[block_table].reshape(B, -1)
            if quantized:
                ksc = new_cache["k_scale"][block_table].reshape(B, -1, ck.shape[2])
                vsc = new_cache["v_scale"][block_table].reshape(B, -1, cv.shape[2])
                kc = kc.astype(jnp.float32) * ksc[..., None]
                vc = vc.astype(jnp.float32) * vsc[..., None]
                kc, vc = kc.astype(q.dtype), vc.astype(q.dtype)
            if kv_len is not None:
                kc, vc, pc = kc[:, :kv_len], vc[:, :kv_len], pc[:, :kv_len]
            ok = (pc[:, None, :] >= 0) & (pc[:, None, :] <= pos1d[:, :, None])
            out = sdpa(q, kc, vc, ok, scale)
        y = dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
        return y, new_cache

    # ---- decode: single (or few) new tokens against the cache
    ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
    W = ck.shape[1]
    slot = (pos1d % W).astype(jnp.int32)  # (B, S)
    bidx = jnp.arange(B)[:, None]
    ck = ck.at[bidx, slot].set(k)
    cv = cv.at[bidx, slot].set(v)
    cpos = cpos.at[bidx, slot].set(pos1d.astype(jnp.int32))

    if use_kernel and S == 1:
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention_cache(q, ck, cv, cpos, pos1d[:, 0],
                                            scale=scale,
                                            window=cfg.attn_window)
    else:
        # mask over cache slots by absolute position validity
        ok = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= pos1d[:, :, None])
        if cfg.attn_window is not None:
            ok &= cpos[:, None, :] > pos1d[:, :, None] - cfg.attn_window
        out = sdpa(q, ck, cv, ok, scale)
    y = dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
    return y, {"k": ck, "v": cv, "pos": cpos}


def _fill_cache(cfg: ArchConfig, cache: Dict, k, v, pos1d) -> Dict:
    """Write prefill keys/values into an allocated cache (ring for windowed).

    When S > W only the last W tokens can survive, so slice before scattering —
    this keeps scatter indices unique (``.at[].set`` with duplicates is undefined).
    """
    B, S = pos1d.shape
    ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
    W = ck.shape[1]
    if S > W:
        k, v, pos1d = k[:, -W:], v[:, -W:], pos1d[:, -W:]
    slot = (pos1d % W).astype(jnp.int32)
    bidx = jnp.arange(B)[:, None]
    ck = ck.at[bidx, slot].set(k.astype(ck.dtype))
    cv = cv.at[bidx, slot].set(v.astype(cv.dtype))
    cpos = cpos.at[bidx, slot].set(pos1d.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cpos}


def _fill_cache_paged(cache: Dict, k, v, pos1d,
                      block_table: jnp.ndarray) -> Dict:
    """Write prefill keys/values through the block table into paged pools.

    ``block_table`` here is the *prefill* table (one row per unique prompt):
    position p lands in pool block ``table[b, p // bs]`` row ``p % bs``.
    Every row owns distinct blocks, so scatter indices stay unique.

    int8 pools (``cache["k"].dtype == int8``) quantize on fill: each written
    slot stores ``round(k / scale)`` per kv-head with ``scale = absmax / 127``
    scattered into ``k_scale`` / ``v_scale`` alongside.
    """
    ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
    bs = ck.shape[1]
    bidx = jnp.arange(pos1d.shape[0])[:, None]
    blk = block_table[bidx, pos1d // bs]
    row = (pos1d % bs).astype(jnp.int32)
    if ck.dtype == jnp.int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {"k": ck.at[blk, row].set(kq),
                "v": cv.at[blk, row].set(vq),
                "pos": cpos.at[blk, row].set(pos1d.astype(jnp.int32)),
                "k_scale": cache["k_scale"].at[blk, row].set(ks),
                "v_scale": cache["v_scale"].at[blk, row].set(vs)}
    ck = ck.at[blk, row].set(k.astype(ck.dtype))
    cv = cv.at[blk, row].set(v.astype(cv.dtype))
    cpos = cpos.at[blk, row].set(pos1d.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cpos}


def _quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 per-(token, kv-head) quantization over the head dim:
    x (B, S, n_kv, hd) -> (q int8, scale f32 (B, S, n_kv))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


# =============================================================================
# MLA attention (DeepSeek-V2): latent KV cache
# =============================================================================

def mla_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray,
                cache: Optional[Dict] = None,
                absorbed_decode: bool = True,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Multi-head Latent Attention.

    The cache stores only the compressed latent ``c_kv`` (rank kv_lora) plus the
    shared rope key — the paper-relevant decode-bytes optimization. In absorbed
    decode mode, scores are computed in latent space (W_uk folded into q), so the
    per-step bytes are O(S·(kv_lora + rope_hd)) instead of O(S·2·H·hd).
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / np.sqrt(nd + rd)
    pos1d = positions[..., 0] if positions.ndim == 3 else positions

    q = dense(p["wq"], x).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, pos1d, cfg.rope_theta)

    c_kv = dense(p["w_dkv"], x)                       # (B,S,r)
    k_rope = dense(p["w_krope"], x).reshape(B, S, 1, rd)
    k_rope = apply_rope(k_rope, pos1d, cfg.rope_theta)

    decoding = cache is not None and S == 1

    if decoding:
        cc, cr, cpos = cache["c_kv"], cache["k_rope"], cache["pos"]
        W = cc.shape[1]
        slot = (pos1d % W).astype(jnp.int32)
        bidx = jnp.arange(B)[:, None]
        cc = cc.at[bidx, slot].set(c_kv.astype(cc.dtype))
        cr = cr.at[bidx, slot].set(k_rope[:, :, 0].astype(cr.dtype))
        cpos = cpos.at[bidx, slot].set(pos1d.astype(jnp.int32))
        ok = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= pos1d[:, :, None])
        if cfg.attn_window is not None:
            ok &= cpos[:, None, :] > pos1d[:, :, None] - cfg.attn_window

        if absorbed_decode:
            # fold W_uk into q: q_lat (B,S,H,r)
            w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, H, nd)
            q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                               w_uk.astype(jnp.float32))
            scores = jnp.einsum("bshr,bkr->bhsk", q_lat,
                                cc.astype(jnp.float32))
            scores += jnp.einsum("bshr,bkr->bhsk", q_rope.astype(jnp.float32),
                                 cr.astype(jnp.float32))
            scores = jnp.where(ok[:, None], scores * scale, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            ctx_lat = jnp.einsum("bhsk,bkr->bshr", probs,
                                 cc.astype(jnp.float32))       # (B,S,H,r)
            w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, H, vd)
            out = jnp.einsum("bshr,rhv->bshv", ctx_lat,
                             w_uv.astype(jnp.float32)).astype(x.dtype)
        else:
            k_nope = dense(p["w_uk"], cc).reshape(B, -1, H, nd)
            vv = dense(p["w_uv"], cc).reshape(B, -1, H, vd)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(cr[:, :, None],
                                          (B, cc.shape[1], H, rd))], axis=-1)
            q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = sdpa(q_full, k_full, vv, ok, scale)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}
        y = dense(p["wo"], out.reshape(B, S, H * vd))
        return y, new_cache

    # ---- train / prefill: decompress (compute-bound, MXU-friendly)
    k_nope = dense(p["w_uk"], c_kv).reshape(B, S, H, nd)
    vv = dense(p["w_uv"], c_kv).reshape(B, S, H, vd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    if use_kernel:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q_full, k_full, vv, causal=True,
                                     window=cfg.attn_window, scale=scale)
    elif S * S > BLOCKED_THRESHOLD:
        out = sdpa_causal_blocked(q_full, k_full, vv, pos1d,
                                  cfg.attn_window, scale)
    else:
        mask = causal_mask(pos1d, pos1d, cfg.attn_window)
        out = sdpa(q_full, k_full, vv, mask, scale)
    new_cache = None
    if cache is not None:
        cc, cr, cpos = cache["c_kv"], cache["k_rope"], cache["pos"]
        W = cc.shape[1]
        c_w, kr_w, pos_w = c_kv, k_rope[:, :, 0], pos1d
        if S > W:
            c_w, kr_w, pos_w = c_w[:, -W:], kr_w[:, -W:], pos_w[:, -W:]
        slot = (pos_w % W).astype(jnp.int32)
        bidx = jnp.arange(B)[:, None]
        cc = cc.at[bidx, slot].set(c_w.astype(cc.dtype))
        cr = cr.at[bidx, slot].set(kr_w.astype(cr.dtype))
        cpos = cpos.at[bidx, slot].set(pos_w.astype(jnp.int32))
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}
    y = dense(p["wo"], out.reshape(B, S, H * vd))
    return y, new_cache


# =============================================================================
# Cross-attention (musicgen conditioning) — memory is static, cache-free
# =============================================================================

def cross_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                  memory: Optional[jnp.ndarray],
                  cached_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Cross-attention over the static conditioning memory.

    When ``cached_kv`` is provided (decode with cfg.cross_kv_cache), the
    memory projections are skipped entirely — the conditioning sequence never
    changes across decode steps, so re-projecting it every token is pure
    waste (§Perf beyond-paper; measured on musicgen decode_32k).
    """
    B, S, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    if cached_kv is not None:
        k, v = cached_kv
    else:
        Tm = memory.shape[1]
        k = dense(p["wk"], memory).reshape(B, Tm, cfg.n_heads, hd)
        v = dense(p["wv"], memory).reshape(B, Tm, cfg.n_heads, hd)
    out = sdpa(q, k, v, None, 1.0 / np.sqrt(hd))
    return dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd)), (k, v)
