"""Architecture configuration system.

Every assigned architecture (and the paper's own model families) is described by an
``ArchConfig``. Configs are pure data: the unified ``repro.models.model.Model`` turns a
config into parameter specs / init / forward / prefill / decode functions.

Design notes
------------
* ``layer_pattern`` drives hybrid architectures (jamba): the model stacks identical
  "super-blocks" (one period of the pattern) and scans over them, so HLO size is O(1)
  in depth for every architecture.
* ``attn_window`` enables the sliding-window variant used to run dense archs at the
  ``long_500k`` shape (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None  # V2-Lite has no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0              # shared (always-on) experts, DeepSeek-style
    d_expert: int = 0              # expert FFN hidden dim (0 -> use cfg.d_ff)
    moe_period: int = 1            # MoE every `period` layers (1 = every layer)
    first_dense: int = 0           # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention ---
    qkv_bias: bool = False
    attn_window: Optional[int] = None
    mla: Optional[MLAConfig] = None
    mla_absorbed: bool = True   # latent-space decode (paper-relevant bytes opt)
    # --- position encoding ---
    rope_variant: str = "rope"     # rope | partial | mrope | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # fraction of head_dim rotated ("partial"/chatglm)
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl, sums to rotary half-dim
    # --- FFN ---
    mlp_variant: str = "swiglu"    # swiglu | gelu
    moe: Optional[MoEConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    # §Perf pair-1 variant: separate z/xBC/dt projections instead of one fused
    # in_proj — each output is then independently tensor-sharded, eliminating
    # the shard-misaligned slice that forces activation all-gathers.
    ssm_split_proj: bool = False
    layer_pattern: Optional[Tuple[str, ...]] = None  # one period, e.g. 7*('m',)+('a',)
    # --- modality frontends (stubs per carve-out) ---
    frontend: Optional[str] = None  # vision | audio
    n_codebooks: int = 1            # musicgen EnCodec codebooks
    n_vision_tokens: int = 256      # stub patch-embedding count for vlm shapes
    n_cond_tokens: int = 64         # stub conditioning memory length (audio)
    cross_attention: bool = False
    # §Perf beyond-paper: cache the cross-attention K/V of the static
    # conditioning memory at prefill instead of re-projecting every decode step
    cross_kv_cache: bool = False
    # §Perf beyond-paper: dense all-experts MoE for small decode batches
    # (skips sort/scatter dispatch; exact — no capacity drops)
    moe_dense_decode: bool = False
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""               # citation

    # ------------------------------------------------------------------ helpers
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so logits shard on the tensor
        axis (MaxText-style); padded columns are masked to -inf so the loss
        and sampling are exact. Affects mamba2 (50280->50432) and granite
        (49155->49408) only."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.headdim

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Per-layer kinds for one super-block period."""
        if self.layer_pattern is not None:
            return self.layer_pattern
        if self.arch_type == "ssm":
            return ("m",)
        return ("a",)

    @property
    def n_super_blocks(self) -> int:
        period = len(self.pattern)
        assert self.n_layers % period == 0, (self.name, self.n_layers, period)
        return self.n_layers // period

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if layer_idx < self.moe.first_dense:
            return False
        return (layer_idx - self.moe.first_dense) % self.moe.moe_period == 0

    def expert_ff(self) -> int:
        assert self.moe is not None
        return self.moe.d_expert or self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (used by the scaling formalisms' N)."""
        from repro.models.model import Model  # local import to avoid cycle

        return Model(self).param_count()

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 super-blocks, d_model<=256, <=4 experts."""
        period = len(self.pattern)
        n_layers = period * min(2, self.n_super_blocks)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.hd >= 64 else self.hd,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                n_shared=min(1, self.moe.n_shared),
                d_expert=min(128, self.expert_ff()),
            )
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32)
            kw["head_dim"] = 0
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, headdim=32, chunk=32)
        if self.mrope_sections:
            # keep sections summing to rotary half-dim (hd=64 -> half=32)
            kw["mrope_sections"] = (8, 12, 12)
        return self.with_overrides(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
