"""Primitive layers: norms, MLPs, rotary position embeddings, embeddings.

All layers are pure functions over parameter pytrees (dicts of jnp arrays). Parameter
*specs* (shape/dtype, no allocation) are produced by the matching ``*_spec`` helpers so
the multi-pod dry-run can lower models without touching device memory.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jnp.ndarray]

# --------------------------------------------------------------------------- init utils

def _dense_spec(d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    spec = {"w": jax.ShapeDtypeStruct((d_in, d_out), dtype)}
    if bias:
        spec["b"] = jax.ShapeDtypeStruct((d_out,), dtype)
    return spec


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    scale = 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "qw" in p:
        # weight-only quantized layer (repro.quant): fused dequant-matmul
        from repro.quant.quantize import qdense
        return qdense(p, x)
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


dense_spec = _dense_spec


# --------------------------------------------------------------------------- norms

def rmsnorm_spec(d: int, dtype) -> Params:
    return {"scale": jax.ShapeDtypeStruct((d,), dtype)}


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- MLPs

def mlp_spec(d_model: int, d_ff: int, variant: str, dtype) -> Params:
    if variant == "swiglu":
        return {
            "gate": _dense_spec(d_model, d_ff, dtype),
            "up": _dense_spec(d_model, d_ff, dtype),
            "down": _dense_spec(d_ff, d_model, dtype),
        }
    return {
        "fc_in": _dense_spec(d_model, d_ff, dtype, bias=True),
        "fc_out": _dense_spec(d_ff, d_model, dtype, bias=True),
    }


def mlp_init(key, d_model: int, d_ff: int, variant: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if variant == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "fc_in": dense_init(ks[0], d_model, d_ff, dtype, bias=True),
        "fc_out": dense_init(ks[1], d_ff, d_model, dtype, bias=True),
    }


def mlp(p: Params, x: jnp.ndarray, variant: str) -> jnp.ndarray:
    if variant == "swiglu":
        return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))
    return dense(p["fc_out"], jax.nn.gelu(dense(p["fc_in"], x)))


# --------------------------------------------------------------------------- RoPE

def rope_freqs(rotary_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for half the rotary dim."""
    half = rotary_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rope_fraction: float = 1.0,
               mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """Rotate ``x`` (..., seq, heads, head_dim) by position embeddings.

    positions: (..., seq) int32 for standard rope, or (..., seq, 3) for M-RoPE
    (temporal/height/width coordinates, qwen2-vl style).
    """
    hd = x.shape[-1]
    rot = int(hd * rope_fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv = rope_freqs(rot, theta)  # (rot/2,)

    if mrope_sections:
        assert positions.shape[-1] == 3 and sum(mrope_sections) == rot // 2
        # each frequency f uses one of the 3 position kinds (t/h/w sections)
        sec_id = np.repeat(np.arange(3), np.asarray(mrope_sections))
        pos_sel = jnp.take(positions, jnp.asarray(sec_id), axis=-1)  # (..., seq, rot/2)
        ang = pos_sel.astype(jnp.float32) * inv
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, rot/2)

    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------- embeddings

def embed_spec(vocab: int, d_model: int, dtype, n_codebooks: int = 1) -> Params:
    if n_codebooks > 1:
        return {"table": jax.ShapeDtypeStruct((n_codebooks, vocab, d_model), dtype)}
    return {"table": jax.ShapeDtypeStruct((vocab, d_model), dtype)}


def embed_init(key, vocab: int, d_model: int, dtype, n_codebooks: int = 1) -> Params:
    shape = (n_codebooks, vocab, d_model) if n_codebooks > 1 else (vocab, d_model)
    return {"table": (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32, or (B, S, K) for multi-codebook (summed)."""
    table = p["table"]
    if table.ndim == 3:  # multi-codebook: sum_k table[k, tokens[...,k]]
        outs = [table[k][tokens[..., k]] for k in range(table.shape[0])]
        return sum(outs)
    return table[tokens]


def lm_head_spec(d_model: int, vocab: int, dtype, n_codebooks: int = 1) -> Params:
    if n_codebooks > 1:
        return {"w": jax.ShapeDtypeStruct((n_codebooks, d_model, vocab), dtype)}
    return {"w": jax.ShapeDtypeStruct((d_model, vocab), dtype)}


def lm_head_init(key, d_model: int, vocab: int, dtype, n_codebooks: int = 1) -> Params:
    shape = (n_codebooks, d_model, vocab) if n_codebooks > 1 else (d_model, vocab)
    scale = 1.0 / np.sqrt(d_model)
    return {"w": (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)}


def lm_head(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"]
    if w.ndim == 3:  # (K, D, V) -> logits (B,S,K,V)
        return jnp.einsum("bsd,kdv->bskv", x, w)
    return x @ w
