from repro.models.config import (ArchConfig, InputShape, MLAConfig, MoEConfig,
                                 SSMConfig, INPUT_SHAPES, TRAIN_4K,
                                 PREFILL_32K, DECODE_32K, LONG_500K)
from repro.models.model import Model

__all__ = [
    "ArchConfig", "InputShape", "MLAConfig", "MoEConfig", "SSMConfig",
    "Model", "INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K",
]
