"""Mamba-2 (State Space Duality) block.

TPU adaptation note (DESIGN.md §2): the SSD formulation is chosen *because* it casts
the selective-scan as chunked matmuls — MXU-friendly — instead of the GPU-style
hardware-aware parallel scan of Mamba-1. Intra-chunk work is dense einsums (the Pallas
``ssd_scan`` kernel tiles these into VMEM); the inter-chunk state carry is a
``jax.lax.scan`` with O(1) state.

Prefill/train path: chunked SSD. Decode path: exact O(1) recurrence
    state <- state * exp(dt*A) + dt * (B outer x);   y = <C, state> + D*x
which is the memory-bound stage the paper's orchestrator routes to bandwidth-optimal
devices (Formalism 5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import Params, dense, dense_init, dense_spec


# --------------------------------------------------------------------------- params

def ssm_spec(cfg: ArchConfig, dtype) -> Params:
    s = cfg.ssm
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    if cfg.ssm_split_proj:
        proj = {
            "in_proj_z": dense_spec(cfg.d_model, d_in, dtype),
            "in_proj_x": dense_spec(cfg.d_model, d_in, dtype),
            "in_proj_bc": dense_spec(cfg.d_model, 2 * s.n_groups * s.d_state,
                                     dtype),
            "in_proj_dt": dense_spec(cfg.d_model, H, dtype),
        }
    else:
        proj = {"in_proj": dense_spec(
            cfg.d_model, 2 * d_in + 2 * s.n_groups * s.d_state + H, dtype)}
    return {
        **proj,
        "conv_w": jax.ShapeDtypeStruct((s.d_conv, conv_ch), dtype),
        "conv_b": jax.ShapeDtypeStruct((conv_ch,), dtype),
        "A_log": jax.ShapeDtypeStruct((H,), jnp.float32),
        "dt_bias": jax.ShapeDtypeStruct((H,), jnp.float32),
        "D": jax.ShapeDtypeStruct((H,), jnp.float32),
        "norm_scale": jax.ShapeDtypeStruct((d_in,), dtype),
        "out_proj": dense_spec(d_in, cfg.d_model, dtype),
    }


def ssm_init(key, cfg: ArchConfig, dtype) -> Params:
    s = cfg.ssm
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,)) *
                 (np.log(s.dt_max) - np.log(s.dt_min)) + np.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    if cfg.ssm_split_proj:
        proj = {
            "in_proj_z": dense_init(ks[0], cfg.d_model, d_in, dtype),
            "in_proj_x": dense_init(ks[4], cfg.d_model, d_in, dtype),
            "in_proj_bc": dense_init(ks[5], cfg.d_model,
                                     2 * s.n_groups * s.d_state, dtype),
            "in_proj_dt": dense_init(ks[6], cfg.d_model, H, dtype),
        }
    else:
        proj = {"in_proj": dense_init(
            ks[0], cfg.d_model, 2 * d_in + 2 * s.n_groups * s.d_state + H,
            dtype)}
    return {
        **proj,
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   / np.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[3], d_in, cfg.d_model, dtype),
    }


# --------------------------------------------------------------------------- SSD core

def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., T) -> (..., T, T) where out[..., i, j] = sum_{j < k <= i} x[..., k].

    Lower-triangular cumulative segment sums (Mamba-2 paper's ``segsum``);
    out is -inf above the diagonal.
    """
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x  (B, L, H, P)    inputs per head
    dt (B, L, H)       positive step sizes (softplus applied by caller)
    A  (H,)            negative decay rates
    Bm (B, L, H, N)    input  projections (group-broadcast done by caller)
    Cm (B, L, H, N)    output projections
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = z(x), z(dt), z(Bm), z(Cm)
    Lp = x.shape[1]
    nc = Lp // chunk

    def to_chunks(a):
        return a.reshape((B, nc, chunk) + a.shape[2:])

    xc, dtc, Bc, Cc = map(to_chunks, (x, dt, Bm, Cm))
    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)                        # (B,nc,Q,H)

    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        Y_diag, chunk_states = ssd_ops.ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc)
    else:
        # intra-chunk (dual / quadratic form): decay matrix per chunk
        Lmat = jnp.exp(segsum(jnp.moveaxis(dA, 3, 2)))    # (B,nc,H,Q,Q)
        scores = jnp.einsum("bcqhn,bcshn->bchqs",
                            Cc.astype(jnp.float32), Bc.astype(jnp.float32))
        scores = scores * Lmat * jnp.moveaxis(dtc, 3, 2)[..., None, :]
        Y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores, xc.astype(jnp.float32))
        # states contributed by each chunk
        decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (B,nc,Q,H)
        chunk_states = jnp.einsum(
            "bcqhn,bcqh,bcqhp->bchpn", Bc.astype(jnp.float32),
            (decay * dtc).astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence (sequential over chunks, O(1) state)
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (B,nc,H)

    def step(state, inp):
        st_c, dec_c = inp                                  # (B,H,P,N), (B,H)
        out_prev = state                                   # state before this chunk
        new = state * dec_c[:, :, None, None] + st_c
        return new, out_prev

    final_state, prev_states = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,P,N)

    # contribution of the inherited state within each chunk
    instate_decay = jnp.exp(dA_cs)                         # (B,nc,Q,H)
    Y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Cc.astype(jnp.float32), prev_states, instate_decay)

    y = (Y_diag + Y_off).reshape(B, Lp, H, P)[:, :L]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, A, Bm, Cm, state):
    """Exact single-token recurrence.

    x (B,1,H,P), dt (B,1,H), Bm/Cm (B,1,H,N), state (B,H,P,N).
    """
    dA = jnp.exp(dt[..., 0, :] * A[None])                  # (B,H)
    dBx = jnp.einsum("bhn,bh,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                     dt[:, 0].astype(jnp.float32), x[:, 0].astype(jnp.float32))
    new_state = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), new_state)
    return y[:, None].astype(x.dtype), new_state


# --------------------------------------------------------------------------- block

def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv1d. xBC (B,S,Ch), w (K,Ch). Returns (y, new_state)."""
    K = w.shape[0]
    B, S, Ch = xBC.shape
    if conv_state is None:
        ctx = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    # y[t] = sum_k w[k] * ctx[t + k]
    y = sum(ctx[:, k:k + S] * w[k][None, None] for k in range(K)) + b
    new_state = ctx[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, Ch), xBC.dtype)
    return y, new_state


def ssm_forward(p: Params, cfg: ArchConfig, u: jnp.ndarray,
                cache: Optional[Dict] = None,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    cache (decode / carry-through): {"ssm": (B,H,P,N) f32, "conv": (B,K-1,Ch)}.
    """
    s = cfg.ssm
    B, S, _ = u.shape
    d_in, H, N, G = cfg.d_inner, cfg.ssm_heads, s.d_state, s.n_groups
    P = s.headdim

    if cfg.ssm_split_proj:
        z = dense(p["in_proj_z"], u)
        xBC = jnp.concatenate([dense(p["in_proj_x"], u),
                               dense(p["in_proj_bc"], u)], axis=-1)
        dt_raw = dense(p["in_proj_dt"], u)
    else:
        zxbcdt = dense(p["in_proj"], u)
        z = zxbcdt[..., :d_in]
        xBC = zxbcdt[..., d_in:d_in + d_in + 2 * G * N]
        dt_raw = zxbcdt[..., -H:]

    conv_state = cache.get("conv") if cache else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)

    x = xBC[..., :d_in].reshape(B, S, H, P)
    Bm = xBC[..., d_in:d_in + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_in + G * N:].reshape(B, S, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)

    # §Perf pair-1 hint: GSPMD cannot propagate head sharding through the
    # non-aligned slices above and replicates the SSD scan across "model";
    # pin the head dim explicitly (no-op unless hints.enable()d).
    from repro.distributed import hints
    if hints.enabled():
        x = hints.constrain(x, (None, None, "tensor", None), divisible_dim=2)
        Bm = hints.constrain(Bm, (None, None, "tensor", None), divisible_dim=2)
        Cm = hints.constrain(Cm, (None, None, "tensor", None), divisible_dim=2)
        dt_raw = hints.constrain(dt_raw, (None, None, "tensor"),
                                 divisible_dim=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    init_state = cache.get("ssm") if cache else None
    if S == 1 and init_state is not None:
        y, new_state = ssd_decode_step(x, dt, A, Bm, Cm, init_state)
    else:
        y, new_state = ssd_chunked(x, dt, A, Bm, Cm, s.chunk,
                                   init_state, use_kernel=use_kernel)

    y = y + x * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)

    # gated RMSNorm (mamba2)
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"].astype(jnp.float32)
    out = dense(p["out_proj"], g.astype(u.dtype))

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": new_state, "conv": new_conv}
    return out, new_cache
