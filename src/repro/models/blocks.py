"""Decoder blocks: pre-norm transformer / mamba / hybrid super-blocks.

A *super-block* is one period of ``cfg.pattern`` (e.g. jamba's 7 mamba + 1 attention
layers). The model stacks ``cfg.n_super_blocks`` identical super-blocks and scans over
them, so the lowered HLO is O(pattern length), not O(n_layers).

Per-layer FFN kind (dense MLP vs MoE) is decided by ``cfg.is_moe_layer(abs_idx)``;
because ``moe_period`` divides the pattern length for every assigned arch, the kind of
each slot is identical across super-blocks and the scan stays homogeneous.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig
from repro.models.layers import Params, mlp, mlp_init, mlp_spec, rmsnorm, rmsnorm_init, rmsnorm_spec


def _layer_kinds(cfg: ArchConfig, n_prefix: int):
    """[(mixer_kind, ffn_kind)] for one super-block, given prefix layer count."""
    out = []
    for i, mixer in enumerate(cfg.pattern):
        ffn = "moe" if cfg.is_moe_layer(n_prefix + i) else "mlp"
        out.append((mixer, ffn))
    return out


# --------------------------------------------------------------------------- specs

def _sublayer_spec(cfg: ArchConfig, mixer: str, ffn: str, dtype) -> Params:
    d = cfg.d_model
    spec: Params = {"ln1": rmsnorm_spec(d, dtype)}
    if mixer == "a":
        spec["attn"] = attn.attn_spec(cfg, dtype)
    else:
        spec["ssm"] = ssm_mod.ssm_spec(cfg, dtype)
    if cfg.cross_attention:
        spec["ln_x"] = rmsnorm_spec(d, dtype)
        spec["cross"] = attn.cross_attn_spec(cfg, dtype)
    if ffn == "moe":
        spec["ln2"] = rmsnorm_spec(d, dtype)
        spec["moe"] = moe_mod.moe_spec(cfg, dtype)
    elif cfg.d_ff > 0:  # pure mamba blocks (d_ff == 0) have no FFN
        spec["ln2"] = rmsnorm_spec(d, dtype)
        spec["mlp"] = mlp_spec(d, cfg.d_ff, cfg.mlp_variant, dtype)
    return spec


def super_block_spec(cfg: ArchConfig, n_prefix: int, dtype) -> Params:
    return {f"l{i}": _sublayer_spec(cfg, mx, ff, dtype)
            for i, (mx, ff) in enumerate(_layer_kinds(cfg, n_prefix))}


def _sublayer_init(key, cfg: ArchConfig, mixer: str, ffn: str, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(d, dtype)}
    if mixer == "a":
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
    if cfg.cross_attention:
        p["ln_x"] = rmsnorm_init(d, dtype)
        p["cross"] = attn.cross_attn_init(ks[3], cfg, dtype)
    if ffn == "moe":
        p["ln2"] = rmsnorm_init(d, dtype)
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff > 0:
        p["ln2"] = rmsnorm_init(d, dtype)
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_variant, dtype)
    return p


def super_block_init(key, cfg: ArchConfig, n_prefix: int, dtype) -> Params:
    kinds = _layer_kinds(cfg, n_prefix)
    keys = jax.random.split(key, len(kinds))
    return {f"l{i}": _sublayer_init(keys[i], cfg, mx, ff, dtype)
            for i, (mx, ff) in enumerate(kinds)}


# --------------------------------------------------------------------------- forward

def sublayer_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                     positions: jnp.ndarray, mixer: str,
                     cache: Optional[Dict], memory: Optional[jnp.ndarray],
                     use_kernel: bool,
                     block_table: Optional[jnp.ndarray] = None,
                     kv_len: Optional[int] = None,
                     decode: bool = False
                     ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    S = x.shape[1]
    # cross-attention K/V cache entries ride in the attention sub-cache; pull
    # them out before the self-attention call (which rebuilds its dict).
    cross_kv = None
    if cfg.cross_attention and cfg.cross_kv_cache and cache is not None \
            and (S == 1 or decode):
        cross_kv = (cache.get("xk"), cache.get("xv"))
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mixer == "a":
        if cfg.mla is not None:
            y, new_cache = attn.mla_forward(p["attn"], cfg, h, positions, cache,
                                            absorbed_decode=cfg.mla_absorbed,
                                            use_kernel=use_kernel)
        else:
            y, new_cache = attn.gqa_forward(p["attn"], cfg, h, positions, cache,
                                            use_kernel=use_kernel,
                                            block_table=block_table,
                                            kv_len=kv_len, decode=decode)
    else:
        y, new_cache = ssm_mod.ssm_forward(p["ssm"], cfg, h, cache,
                                           use_kernel=use_kernel)
    x = x + y
    if cfg.cross_attention and (memory is not None or cross_kv is not None):
        y, kv = attn.cross_forward(p["cross"], cfg,
                                   rmsnorm(p["ln_x"], x, cfg.norm_eps),
                                   memory, cached_kv=cross_kv)
        x = x + y
        if cfg.cross_kv_cache and new_cache is not None:
            new_cache["xk"], new_cache["xv"] = kv
    if "moe" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_forward(p["moe"], cfg, h, use_kernel=use_kernel)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_variant)
    return x, new_cache, aux


def super_block_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                        positions: jnp.ndarray,
                        cache: Optional[Dict], memory: Optional[jnp.ndarray],
                        use_kernel: bool,
                        block_table: Optional[jnp.ndarray] = None,
                        kv_len: Optional[int] = None,
                        decode: bool = False
                        ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """One period of the layer pattern. cache is {"l{i}": sub-cache} or None."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for i, mixer in enumerate(cfg.pattern):
        key = f"l{i}"
        sub_cache = cache.get(key) if cache is not None else None
        x, nc, aux = sublayer_forward(p[key], cfg, x, positions, mixer,
                                      sub_cache, memory, use_kernel,
                                      block_table=block_table, kv_len=kv_len,
                                      decode=decode)
        if new_cache is not None:
            new_cache[key] = nc
        aux_total = aux_total + aux
    return x, new_cache, aux_total
