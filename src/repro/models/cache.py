"""KV / state cache construction.

Cache kinds per sub-layer:
  * GQA attention:  k, v  (B, W, n_kv, hd) + per-slot absolute positions (B, W)
  * MLA attention:  c_kv (B, W, kv_lora) + k_rope (B, W, rope_hd) + pos (B, W)
  * Mamba-2 (SSM):  ssm state (B, H, P, N) f32 + conv tail (B, d_conv-1, Ch)

W = min(cache_len, cfg.attn_window or cache_len): a windowed arch never allocates
more than `window` slots — this is what makes long_500k decode sub-quadratic for
the sliding-window variants (DESIGN.md §4).

``spec_only=True`` mirrors the allocation with ShapeDtypeStructs for the dry-run.

Paged layout (``paged=PagedLayout(...)``): GQA entries become block *pools* —
k/v ``(n_blocks, block_size, n_kv, hd)`` plus per-slot positions
``(n_blocks, block_size)`` — addressed through a per-sequence block table the
serving backend builds (`repro.serving.backend.BlockAllocator`). One logical
block id addresses the same slot in every layer's pool, so a single table
serves the whole stack, and the k repeated samples of one prompt can share
physical prefix blocks (prefill once, copy-on-write at the first divergent
token). Paged caches are supported for pure-attention GQA stacks without a
sliding window (`paged_supported`); everything else keeps the dense layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclass(frozen=True)
class PagedLayout:
    """Physical geometry of a paged KV cache: ``n_blocks`` fixed-size blocks
    of ``block_size`` token slots, shared by every attention layer."""
    n_blocks: int
    block_size: int


def paged_supported(cfg: ArchConfig) -> bool:
    """Paged KV caching covers the GQA ring-free case: every mixer is
    attention, no MLA latent cache, no sliding window (the ring buffer's
    slot recycling conflicts with block-granular sharing), no cross-attention
    conditioning memory riding in the cache."""
    return (all(m == "a" for m in cfg.pattern)
            and cfg.mla is None
            and cfg.attn_window is None
            and not cfg.cross_attention)


def n_prefix_layers(cfg: ArchConfig) -> int:
    """Leading non-uniform layers excluded from the scan (e.g. deepseek's first
    dense layer before the MoE stack)."""
    if cfg.moe is not None and cfg.moe.first_dense:
        return cfg.moe.first_dense
    return 0


def n_scanned_super_blocks(cfg: ArchConfig) -> int:
    period = len(cfg.pattern)
    rest = cfg.n_layers - n_prefix_layers(cfg)
    assert rest % period == 0, (cfg.name, rest, period)
    return rest // period


def _attn_entry(cfg: ArchConfig, batch: int, cache_len: int, dtype, spec_only: bool,
                paged: Optional[PagedLayout] = None, kv_dtype=None):
    if paged is not None:
        el_dtype = dtype if kv_dtype is None else kv_dtype
        shapes = {
            "k": ((paged.n_blocks, paged.block_size, cfg.n_kv_heads, cfg.hd),
                  el_dtype),
            "v": ((paged.n_blocks, paged.block_size, cfg.n_kv_heads, cfg.hd),
                  el_dtype),
            "pos": ((paged.n_blocks, paged.block_size), jnp.int32),
        }
        if el_dtype == jnp.int8:
            # int8 KV: per-(block, slot, kv-head) dequant scales
            shapes["k_scale"] = ((paged.n_blocks, paged.block_size,
                                  cfg.n_kv_heads), jnp.float32)
            shapes["v_scale"] = ((paged.n_blocks, paged.block_size,
                                  cfg.n_kv_heads), jnp.float32)
        if spec_only:
            return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
        return {k: (jnp.full(s, -1, d) if k == "pos" else jnp.zeros(s, d))
                for k, (s, d) in shapes.items()}
    if kv_dtype is not None:
        raise ValueError("kv_dtype (quantized KV) requires the paged layout")
    W = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    if cfg.mla is not None:
        m = cfg.mla
        shapes = {
            "c_kv": ((batch, W, m.kv_lora_rank), dtype),
            "k_rope": ((batch, W, m.qk_rope_head_dim), dtype),
            "pos": ((batch, W), jnp.int32),
        }
    else:
        shapes = {
            "k": ((batch, W, cfg.n_kv_heads, cfg.hd), dtype),
            "v": ((batch, W, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": ((batch, W), jnp.int32),
        }
    if cfg.cross_attention and cfg.cross_kv_cache:
        shapes["xk"] = ((batch, cfg.n_cond_tokens, cfg.n_heads, cfg.hd), dtype)
        shapes["xv"] = ((batch, cfg.n_cond_tokens, cfg.n_heads, cfg.hd), dtype)
    if spec_only:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    out = {}
    for k, (s, d) in shapes.items():
        out[k] = jnp.full(s, -1, d) if k == "pos" else jnp.zeros(s, d)
    return out


def _ssm_entry(cfg: ArchConfig, batch: int, dtype, spec_only: bool):
    s = cfg.ssm
    conv_ch = cfg.d_inner + 2 * s.n_groups * s.d_state
    shapes = {
        "ssm": ((batch, cfg.ssm_heads, s.headdim, s.d_state), jnp.float32),
        "conv": ((batch, s.d_conv - 1, conv_ch), dtype),
    }
    if spec_only:
        return {k: jax.ShapeDtypeStruct(sh, d) for k, (sh, d) in shapes.items()}
    return {k: jnp.zeros(sh, d) for k, (sh, d) in shapes.items()}


def _entry(cfg: ArchConfig, mixer: str, batch: int, cache_len: int, dtype,
           spec_only: bool, paged: Optional[PagedLayout] = None, kv_dtype=None):
    if mixer == "a":
        return _attn_entry(cfg, batch, cache_len, dtype, spec_only, paged,
                           kv_dtype)
    return _ssm_entry(cfg, batch, dtype, spec_only)


def _super_block_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype,
                       spec_only: bool,
                       paged: Optional[PagedLayout] = None,
                       kv_dtype=None) -> Dict:
    return {f"l{i}": _entry(cfg, mixer, batch, cache_len, dtype, spec_only,
                            paged, kv_dtype)
            for i, mixer in enumerate(cfg.pattern)}


def _stack(tree, n: int, spec_only: bool):
    if spec_only:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)


def make_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
               spec_only: bool = False,
               paged: Optional[PagedLayout] = None, kv_dtype=None) -> Dict:
    """Full-model cache: {"prefix": [...], "blocks": (n_scanned, ...) stacked}.

    With ``paged`` the attention entries become block pools (see module
    docstring); ``batch``/``cache_len`` are then ignored — capacity lives in
    the block table the caller maintains. ``kv_dtype=jnp.int8`` stores paged
    k/v quantized (symmetric per-slot-per-head, scales in ``k_scale`` /
    ``v_scale``), halving pool bytes per token slot.
    """
    if paged is not None and not paged_supported(cfg):
        raise ValueError(f"paged KV cache unsupported for arch {cfg.name!r} "
                         "(needs all-attention pattern, no MLA, no window, "
                         "no cross-attention)")
    period = len(cfg.pattern)
    prefix = [
        _entry(cfg, cfg.pattern[i % period], batch, cache_len, dtype,
               spec_only, paged, kv_dtype)
        for i in range(n_prefix_layers(cfg))
    ]
    blocks = _stack(_super_block_cache(cfg, batch, cache_len, dtype, spec_only,
                                       paged, kv_dtype),
                    n_scanned_super_blocks(cfg), spec_only)
    return {"prefix": prefix, "blocks": blocks}


def copy_cache_blocks(cache: Dict, src: jnp.ndarray, dst: jnp.ndarray) -> Dict:
    """Physically copy pool blocks ``src[i] -> dst[i]`` in every attention
    pool: the copy-on-write fan-out of a shared, partially-filled prefix
    block (each repeated sample of a prompt gets a private copy of the block
    its first divergent token will land in). Only valid on paged caches
    (every entry is a GQA pool)."""
    def cp(entry: Dict, stacked: bool) -> Dict:
        # copy every pool leaf present — int8 pools carry k_scale/v_scale too
        out = dict(entry)
        for key, leaf in entry.items():
            out[key] = (leaf.at[:, dst].set(leaf[:, src]) if stacked
                        else leaf.at[dst].set(leaf[src]))
        return out

    return {"prefix": [cp(e, False) for e in cache["prefix"]],
            "blocks": {name: cp(e, True)
                       for name, e in cache["blocks"].items()}}


def reset_cache_block_positions(cache: Dict, gids: jnp.ndarray) -> Dict:
    """Invalidate the position slots of blocks ``gids`` in every attention
    pool. A resident pooled cache outlives batches, so a block returning
    from the free list still carries its previous occupant's positions —
    and a stale slot whose old position falls inside the new sequence's
    visible window would leak stale KV into attention (a partially filled
    tail block leaves exactly such slots). Only ``pos`` needs resetting:
    position ``-1`` masks the slot, so stale k/v bytes are unreachable."""
    def rp(entry: Dict, stacked: bool) -> Dict:
        out = dict(entry)
        pos = entry["pos"]
        out["pos"] = (pos.at[:, gids].set(-1) if stacked
                      else pos.at[gids].set(-1))
        return out

    return {"prefix": [rp(e, False) for e in cache["prefix"]],
            "blocks": {name: rp(e, True)
                       for name, e in cache["blocks"].items()}}


def kv_bytes_per_token(cfg: ArchConfig, bytes_per_el: int = 2) -> int:
    """KV-cache bytes one token position occupies across the whole stack
    (k + v + int32 position, summed over attention layers) — the unit that
    maps slot/block counts to real memory."""
    period = len(cfg.pattern)
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.pattern[i % period] == "a")
    if cfg.mla is not None:
        per_layer = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) \
            * bytes_per_el + 4
    else:
        per_layer = 2 * cfg.n_kv_heads * cfg.hd * bytes_per_el + 4
    return n_attn * per_layer


def paged_cache_bytes(cfg: ArchConfig, n_blocks: int, block_size: int,
                      bytes_per_el: int = 2) -> int:
    """Real memory of a paged pool: the block budget the serving admission
    control prices requests against."""
    return n_blocks * block_size * kv_bytes_per_token(cfg, bytes_per_el)


def prefix_pool_bytes(cfg: ArchConfig, n_resident: int, block_size: int,
                      bytes_per_el: int = 2) -> int:
    """Bytes of cached KV the resident prefix pool currently indexes
    (`repro.serving.prefix_pool.PrefixPool.blocks_resident` blocks). The
    physical array is `paged_cache_bytes` of the whole budget regardless —
    this prices what the trie's residency is *worth*: prefill bytes the next
    hit on each chain does not have to move."""
    return n_resident * block_size * kv_bytes_per_token(cfg, bytes_per_el)


def cache_bytes(cfg: ArchConfig, batch: int, cache_len: int,
                bytes_per_el: int = 2) -> int:
    """Analytic cache size (used by the orchestrator's memory constraint)."""
    specs = make_cache(cfg, batch, cache_len, spec_only=True)
    total = 0
    for leaf in jax.tree.leaves(specs):
        el = 4 if leaf.dtype in (jnp.int32, jnp.float32) else bytes_per_el
        n = 1
        for s in leaf.shape:
            n *= s
        total += n * el
    return total
