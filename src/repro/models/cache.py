"""KV / state cache construction.

Cache kinds per sub-layer:
  * GQA attention:  k, v  (B, W, n_kv, hd) + per-slot absolute positions (B, W)
  * MLA attention:  c_kv (B, W, kv_lora) + k_rope (B, W, rope_hd) + pos (B, W)
  * Mamba-2 (SSM):  ssm state (B, H, P, N) f32 + conv tail (B, d_conv-1, Ch)

W = min(cache_len, cfg.attn_window or cache_len): a windowed arch never allocates
more than `window` slots — this is what makes long_500k decode sub-quadratic for
the sliding-window variants (DESIGN.md §4).

``spec_only=True`` mirrors the allocation with ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def n_prefix_layers(cfg: ArchConfig) -> int:
    """Leading non-uniform layers excluded from the scan (e.g. deepseek's first
    dense layer before the MoE stack)."""
    if cfg.moe is not None and cfg.moe.first_dense:
        return cfg.moe.first_dense
    return 0


def n_scanned_super_blocks(cfg: ArchConfig) -> int:
    period = len(cfg.pattern)
    rest = cfg.n_layers - n_prefix_layers(cfg)
    assert rest % period == 0, (cfg.name, rest, period)
    return rest // period


def _attn_entry(cfg: ArchConfig, batch: int, cache_len: int, dtype, spec_only: bool):
    W = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    if cfg.mla is not None:
        m = cfg.mla
        shapes = {
            "c_kv": ((batch, W, m.kv_lora_rank), dtype),
            "k_rope": ((batch, W, m.qk_rope_head_dim), dtype),
            "pos": ((batch, W), jnp.int32),
        }
    else:
        shapes = {
            "k": ((batch, W, cfg.n_kv_heads, cfg.hd), dtype),
            "v": ((batch, W, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": ((batch, W), jnp.int32),
        }
    if cfg.cross_attention and cfg.cross_kv_cache:
        shapes["xk"] = ((batch, cfg.n_cond_tokens, cfg.n_heads, cfg.hd), dtype)
        shapes["xv"] = ((batch, cfg.n_cond_tokens, cfg.n_heads, cfg.hd), dtype)
    if spec_only:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    out = {}
    for k, (s, d) in shapes.items():
        out[k] = jnp.full(s, -1, d) if k == "pos" else jnp.zeros(s, d)
    return out


def _ssm_entry(cfg: ArchConfig, batch: int, dtype, spec_only: bool):
    s = cfg.ssm
    conv_ch = cfg.d_inner + 2 * s.n_groups * s.d_state
    shapes = {
        "ssm": ((batch, cfg.ssm_heads, s.headdim, s.d_state), jnp.float32),
        "conv": ((batch, s.d_conv - 1, conv_ch), dtype),
    }
    if spec_only:
        return {k: jax.ShapeDtypeStruct(sh, d) for k, (sh, d) in shapes.items()}
    return {k: jnp.zeros(sh, d) for k, (sh, d) in shapes.items()}


def _entry(cfg: ArchConfig, mixer: str, batch: int, cache_len: int, dtype,
           spec_only: bool):
    if mixer == "a":
        return _attn_entry(cfg, batch, cache_len, dtype, spec_only)
    return _ssm_entry(cfg, batch, dtype, spec_only)


def _super_block_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype,
                       spec_only: bool) -> Dict:
    return {f"l{i}": _entry(cfg, mixer, batch, cache_len, dtype, spec_only)
            for i, mixer in enumerate(cfg.pattern)}


def _stack(tree, n: int, spec_only: bool):
    if spec_only:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)


def make_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
               spec_only: bool = False) -> Dict:
    """Full-model cache: {"prefix": [...], "blocks": (n_scanned, ...) stacked}."""
    period = len(cfg.pattern)
    prefix = [
        _entry(cfg, cfg.pattern[i % period], batch, cache_len, dtype, spec_only)
        for i in range(n_prefix_layers(cfg))
    ]
    blocks = _stack(_super_block_cache(cfg, batch, cache_len, dtype, spec_only),
                    n_scanned_super_blocks(cfg), spec_only)
    return {"prefix": prefix, "blocks": blocks}


def cache_bytes(cfg: ArchConfig, batch: int, cache_len: int,
                bytes_per_el: int = 2) -> int:
    """Analytic cache size (used by the orchestrator's memory constraint)."""
    specs = make_cache(cfg, batch, cache_len, spec_only=True)
    total = 0
    for leaf in jax.tree.leaves(specs):
        el = 4 if leaf.dtype in (jnp.int32, jnp.float32) else bytes_per_el
        n = 1
        for s in leaf.shape:
            n *= s
        total += n * el
    return total
