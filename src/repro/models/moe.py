"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Why sort-based (vs. GShard one-hot einsum dispatch): the one-hot dispatch tensor is
O(tokens x experts x capacity), which is ~2e14 elements at prefill_32k on
deepseek-v2-lite. The sort-based path costs O(tokens log tokens) for routing plus the
unavoidable O(E x C x d x ff) expert compute, and shards cleanly with experts on the
"model" mesh axis (XLA inserts the all-to-all around the gather/scatter).

Load-balancing auxiliary loss (Switch-style) is returned for the training loop.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import Params, dense, dense_init, dense_spec, mlp, mlp_init, mlp_spec


def moe_spec(cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    d, ff = cfg.d_model, cfg.expert_ff()
    spec = {
        "router": dense_spec(d, m.n_experts, dtype),
        # stacked expert SwiGLU weights
        "gate": jax.ShapeDtypeStruct((m.n_experts, d, ff), dtype),
        "up": jax.ShapeDtypeStruct((m.n_experts, d, ff), dtype),
        "down": jax.ShapeDtypeStruct((m.n_experts, ff, d), dtype),
    }
    if m.n_shared:
        spec["shared"] = mlp_spec(d, ff * m.n_shared, "swiglu", dtype)
    return spec


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    d, ff = cfg.d_model, cfg.expert_ff()
    ks = jax.random.split(key, 5)
    s_in, s_ff = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    p = {
        "router": dense_init(ks[0], d, m.n_experts, dtype),
        "gate": (jax.random.normal(ks[1], (m.n_experts, d, ff), jnp.float32) * s_in).astype(dtype),
        "up": (jax.random.normal(ks[2], (m.n_experts, d, ff), jnp.float32) * s_in).astype(dtype),
        "down": (jax.random.normal(ks[3], (m.n_experts, ff, d), jnp.float32) * s_ff).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], d, ff * m.n_shared, "swiglu", dtype)
    return p


def moe_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    Routing: softmax router, top-k experts per token, sort-based dispatch with
    per-expert capacity C = ceil(T*k/E * capacity_factor); overflow tokens drop
    (standard capacity-based MoE semantics).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xf = x.reshape(T, d)

    logits = dense(p["router"], xf).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_dense_decode and T <= 512:
        # §Perf beyond-paper decode path: with a handful of tokens, running
        # every expert densely is cheaper than the sort/scatter dispatch
        # machinery (whose capacity padding dominates at T << E*C), and it
        # is exact — no capacity drops.
        h_all = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["gate"])) * \
            jnp.einsum("td,edf->tef", xf, p["up"])
        y_all = jnp.einsum("tef,efd->ted", h_all, p["down"])    # (T, E, d)
        weights = jnp.zeros((T, m.n_experts), jnp.float32)
        weights = weights.at[jnp.arange(T)[:, None], expert_idx].add(gate_vals)
        y = jnp.einsum("te,ted->td", weights, y_all.astype(jnp.float32))
        y = y.astype(x.dtype).reshape(B, S, d)
        me = probs.mean(axis=0)
        ce = jnp.zeros((m.n_experts,), jnp.float32).at[
            expert_idx.reshape(-1)].add(1.0) / (T * K)
        aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
        if m.n_shared:
            y = y + mlp(p["shared"], x, "swiglu")
        return y, aux

    # ---- Switch aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    # ---- sort-based dispatch
    TK = T * K
    flat_e = expert_idx.reshape(TK)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_gate = gate_vals.reshape(TK)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    gate_sorted = flat_gate[order]

    counts = jax.ops.segment_sum(jnp.ones((TK,), jnp.int32), flat_e, E)
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(TK, dtype=jnp.int32) - seg_start[e_sorted]

    C = int(np.ceil(TK / E * m.capacity_factor))
    C = max(C, K)  # degenerate tiny-shape guard
    keep = pos_in_e < C
    dest = jnp.where(keep, e_sorted * C + pos_in_e, E * C)       # drop slot at end

    # gather tokens into (E*C, d) buffer
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(xf[tok_sorted])
    xe = buf[: E * C].reshape(E, C, d)

    # ---- expert compute (stacked SwiGLU); the grouped-GEMM Pallas kernel
    # covers these three einsums on TPU (repro.kernels.moe_gemm)
    if use_kernel:
        from repro.kernels.moe_gemm import ops as mg_ops
        h = jax.nn.silu(mg_ops.moe_gemm(xe, p["gate"])) * \
            mg_ops.moe_gemm(xe, p["up"])
        ye = mg_ops.moe_gemm(h, p["down"])                       # (E, C, d)
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["up"])
        ye = jnp.einsum("ecf,efd->ecd", h, p["down"])            # (E, C, d)

    # ---- combine back
    ye_flat = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), ye.dtype)])
    contrib = ye_flat[jnp.where(keep, dest, E * C)] * gate_sorted[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), jnp.float32).at[tok_sorted].add(
        contrib.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, d)

    if m.n_shared:
        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux
