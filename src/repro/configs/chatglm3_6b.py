"""ChatGLM3-6B [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024; 2d RoPE (rotary applied to
half the head dim, chatglm convention); QKV bias.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    rope_variant="partial",
    rope_fraction=0.5,
    mlp_variant="swiglu",
    source="arXiv:2406.12793",
)
