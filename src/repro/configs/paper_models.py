"""The QEIL paper's own five model families (Table 16), as ArchConfigs.

These drive the paper-reproduction benchmarks (scaling-formalism fitting, the
heterogeneity ablation, the main results table) and the end-to-end serving example.
Geometries follow the public model cards; the reproduction benches mostly need the
parameter count N and the prefill/decode FLOP/byte profiles that the configs imply.
"""
from repro.models.config import ArchConfig

GPT2_125M = ArchConfig(
    name="gpt2-125m", arch_type="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=50257,
    mlp_variant="gelu", rope_variant="sinusoidal", tie_embeddings=True,
    source="paper (GPT-2 family)")

GRANITE_350M = ArchConfig(
    name="granite-350m", arch_type="dense", n_layers=28, d_model=1024,
    n_heads=16, n_kv_heads=4, d_ff=2048, vocab_size=49155,
    mlp_variant="swiglu", source="paper (Granite family)")

QWEN2_05B = ArchConfig(
    name="qwen2-0.5b", arch_type="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151936,
    qkv_bias=True, mlp_variant="swiglu", tie_embeddings=True,
    source="paper (Qwen2 family)")

LLAMA32_1B = ArchConfig(
    name="llama-3.2-1b", arch_type="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=128256,
    mlp_variant="swiglu", tie_embeddings=True,
    source="paper (Llama-3.2 family)")

LFM2_26B = ArchConfig(
    name="lfm2-2.6b", arch_type="dense", n_layers=32, d_model=2560,
    n_heads=20, n_kv_heads=4, d_ff=8960, vocab_size=65536,
    mlp_variant="swiglu", source="paper (LFM2 family)")

PAPER_MODELS = {m.name: m for m in
                (GPT2_125M, GRANITE_350M, QWEN2_05B, LLAMA32_1B, LFM2_26B)}
