"""DeepSeek-V2-Lite 16B [arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408(expert) vocab=102400; MLA kv_lora=512;
MoE: 64 routed top-6 + 2 shared experts (structured assignment field; the free-text
"160 routed" is full V2, not Lite — see DESIGN.md §4). First layer is dense.
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  moe_period=1, first_dense=1),
    mlp_variant="swiglu",
    source="arXiv:2405.04434",
)
