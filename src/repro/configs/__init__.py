"""Config registry: ``get_config("deepseek-v2-lite-16b")`` / ``--arch`` lookup."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

_ARCH_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "yi-34b": "yi_34b",
    "mamba2-370m": "mamba2_370m",
    "qwen2-72b": "qwen2_72b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "musicgen-medium": "musicgen_medium",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
        return mod.CONFIG
    from repro.configs.paper_models import PAPER_MODELS
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(f"unknown arch {name!r}; known: {ASSIGNED_ARCHS} + paper models")


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ASSIGNED_ARCHS}
