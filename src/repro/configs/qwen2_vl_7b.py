"""Qwen2-VL-7B [arXiv:2409.12191] — transformer backbone only (vision frontend is a
stub per the assignment carve-out: ``input_specs`` supplies patch embeddings).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064; M-RoPE with t/h/w sections
(16, 24, 24) over the 64 rotary half-dims; QKV bias.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),
    mlp_variant="swiglu",
    frontend="vision",
    n_vision_tokens=1024,
    source="arXiv:2409.12191",
)
