"""Yi-34B [arXiv:2403.04652] — llama-architecture GQA dense model.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_variant="swiglu",
    source="arXiv:2403.04652",
)
