"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144 vocab=2048 per codebook;
4 EnCodec codebooks (summed embeddings, 4 LM heads, delay pattern handled by
``repro.data.audio``); cross-attention to the (stubbed) text-conditioning memory;
sinusoidal positions (MusicGen convention).

The EnCodec audio codec itself is a stub per the assignment carve-out —
``input_specs`` supplies precomputed codebook token frames.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp_variant="gelu",
    rope_variant="sinusoidal",
    n_codebooks=4,
    cross_attention=True,
    frontend="audio",
    n_cond_tokens=64,
    source="arXiv:2306.05284",
)
