"""Jamba-v0.1 52B [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; Mamba+attention 1:7
interleave (attention at offset 3 of each 8-layer period); MoE 16 experts top-2
every 2nd layer.

TPU adaptation (DESIGN.md §2/§4): the Mamba layers use the Mamba-2 SSD formulation
(chunked matmuls -> MXU) instead of Jamba's original Mamba-1 selective scan; the
hybrid interleave, MoE placement and head geometry follow the assignment sheet.
"""
from repro.models.config import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("m", "m", "m", "a", "m", "m", "m", "m"),
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, moe_period=2, d_expert=14336),
    mlp_variant="swiglu",
    source="arXiv:2403.19887",
)
