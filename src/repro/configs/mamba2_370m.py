"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality).

48L d_model=1024 vocab=50280 ssm_state=128; expand=2 -> d_inner=2048, headdim=64
-> 32 SSD heads. No FFN (d_ff=0): pure Mamba-2 blocks.
"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("m",),
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=256),
    rope_variant="none",
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
