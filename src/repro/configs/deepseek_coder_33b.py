"""DeepSeek-Coder-33B [arXiv:2401.14196] — llama-architecture dense model.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    mlp_variant="swiglu",
    source="arXiv:2401.14196",
)
