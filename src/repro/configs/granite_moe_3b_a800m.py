"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base].

32L d_model=1536 24H (GQA kv=8) d_ff=512(expert) vocab=49155; MoE 40 experts
top-8 on every layer (structured assignment field "MoE 40e top-8"; the free-text
"32 experts" differs — we follow the structured field, see DESIGN.md §4).
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, moe_period=1),
    mlp_variant="swiglu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
