"""Quantized serving subsystem: weight-only int8/int4 + int8 paged KV.

See `repro.quant.quantize` for the weight-side API (`quantize_model`,
`qdense`, packing helpers), `repro.kernels.dequant_matmul` for the fused
kernel, and `repro.models.cache` / `repro.models.attention` for the int8
paged KV format (`kv_format="int8"` on `repro.serving.ExecutionBackend`).
"""
from repro.quant.quantize import (BYTES_PER_PARAM, DEFAULT_GROUP_SIZE,
                                  QUANT_FORMATS, QuantizedParams,
                                  bytes_per_param_for, dequantize_dense,
                                  dequantize_model, group_size_for,
                                  is_quantized_dense, pack_int4, param_bytes,
                                  params_quant_format, qdense, quant_workload,
                                  quantize_dense, quantize_int4,
                                  quantize_int8, quantize_model)

__all__ = ["BYTES_PER_PARAM", "DEFAULT_GROUP_SIZE", "QUANT_FORMATS",
           "QuantizedParams", "bytes_per_param_for", "dequantize_dense",
           "dequantize_model", "group_size_for", "is_quantized_dense",
           "pack_int4", "param_bytes", "params_quant_format", "qdense",
           "quant_workload", "quantize_dense", "quantize_int4",
           "quantize_int8", "quantize_model"]
