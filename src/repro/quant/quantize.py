"""Weight-only quantization: symmetric per-channel int8 + group-wise int4.

The paper's headline IPW (1.024 at 54.8 W) comes from 4-bit Llama-3.1-8B;
before this subsystem the repo priced that as an abstract ``quant_factor``
scalar while serving bf16 weights. Here the bytes become real:

* **int8** — symmetric per-out-channel: ``scale[n] = absmax(w[:, n]) / 127``,
  ``qw = round(w / scale)`` stored as int8 ``(K, N)`` + f32 ``(N,)`` scales.
* **int4** — symmetric group-wise along the input dim: groups of
  ``group_size`` consecutive rows share ``scale[g, n] = absmax / 7``; values
  in [-7, 7] pack two-per-byte into uint8 ``(K//2, N)`` + f32 ``(G, N)``
  scales (packing convention in `repro.kernels.dequant_matmul.ref`).

A quantized dense dict replaces ``"w"`` with ``"qw"`` + ``"scale"`` (bias
rides along untouched); the format is recoverable from ``qw.dtype`` alone
(int8 vs uint8), which keeps the pytree `jax.lax.scan`-compatible — stacked
super-block leaves quantize with their leading axis intact because every
routine here operates on the trailing two dims.

`repro.models.layers.dense` dispatches on the ``"qw"`` key, so every linear
layer (attention projections, MLPs, SSM projections) serves through the
fused dequant-matmul kernel with no call-site changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.decomposition import Workload

# a quantized Model params pytree: same nesting as Model.init's, with every
# quantized dense dict carrying "qw" + "scale" instead of "w"
QuantizedParams = Dict[str, Any]

EPS = 1e-8
DEFAULT_GROUP_SIZE = 32
QUANT_FORMATS = ("bf16", "int8", "int4")       # serving-path formats
BYTES_PER_PARAM = {"fp32": 4.0, "fp16": 2.0, "bf16": 2.0, "fp8": 1.0,
                   "int8": 1.0, "int4": 0.5}
# dense dicts whose raw "w" is read outside `dense` (MLA absorbed decode
# reshapes these directly) — they stay full-precision
RAW_WEIGHT_KEYS = frozenset({"w_uk", "w_uv"})


def _check_format(fmt: str) -> str:
    if fmt not in QUANT_FORMATS:
        raise ValueError(f"unknown quant format {fmt!r} "
                         f"(supported: {', '.join(QUANT_FORMATS)})")
    return fmt


# ============================================================== pack / unpack

def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """(..., K, N) ints in [-8, 7] -> (..., K//2, N) uint8; row ``r`` packs
    original row ``2r`` (low nibble) and ``2r + 1`` (high nibble)."""
    nib = q.astype(jnp.int32) & 0xF
    return (nib[..., 0::2, :] | (nib[..., 1::2, :] << 4)).astype(jnp.uint8)


def group_size_for(d_in: int, group_size: int) -> int:
    """Largest even divisor of ``d_in`` that is <= ``group_size`` — the
    group the int4 quantizer actually uses (packing needs pairs of rows)."""
    if d_in % 2:
        raise ValueError(f"int4 packing needs an even input dim (got {d_in})")
    gs = min(group_size, d_in)
    while d_in % gs or gs % 2:
        gs -= 1
    return gs


# ================================================================== quantize

def quantize_int8(w: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., K, N) float -> (qw int8 (..., K, N), scale f32 (..., N))."""
    wf = jnp.asarray(w, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2), EPS) / 127.0
    q = jnp.clip(jnp.round(wf / scale[..., None, :]), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_int4(w: jnp.ndarray,
                  group_size: int = DEFAULT_GROUP_SIZE
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., K, N) float -> (packed uint8 (..., K//2, N),
    scale f32 (..., G, N)) with G = K // adjusted group size."""
    wf = jnp.asarray(w, jnp.float32)
    K, N = wf.shape[-2], wf.shape[-1]
    gs = group_size_for(K, group_size)
    grouped = wf.reshape(*wf.shape[:-2], K // gs, gs, N)
    scale = jnp.maximum(jnp.max(jnp.abs(grouped), axis=-2), EPS) / 7.0
    q = jnp.clip(jnp.round(grouped / scale[..., :, None, :]), -7, 7)
    return pack_int4(q.reshape(*wf.shape[:-2], K, N)), scale


def quantize_dense(p: Dict, fmt: str,
                   group_size: int = DEFAULT_GROUP_SIZE) -> Dict:
    """Quantize one dense param dict: ``{"w", ["b"]}`` -> ``{"qw", "scale",
    ["b"]}``. The bias stays in the model dtype."""
    _check_format(fmt)
    out = {k: v for k, v in p.items() if k != "w"}
    if fmt == "int8":
        out["qw"], out["scale"] = quantize_int8(p["w"])
    elif fmt == "int4":
        out["qw"], out["scale"] = quantize_int4(p["w"], group_size)
    else:
        return dict(p)                       # bf16: identity
    return out


def dequantize_dense(p: Dict, dtype=jnp.float32) -> Dict:
    """Inverse of `quantize_dense` (lossy): ``{"qw", "scale"}`` -> ``{"w"}``.
    The reconstruction uses the same dequantize math as the matmul oracle,
    so ``dense(dequantize_dense(qp), x)`` == ``qdense(qp, x)`` bit-for-bit
    on the reference path."""
    from repro.kernels.dequant_matmul.ref import (dequantize_int4,
                                                  dequantize_int8)
    w = (dequantize_int4(p["qw"], p["scale"]) if p["qw"].dtype == jnp.uint8
         else dequantize_int8(p["qw"], p["scale"]))
    out = {k: v for k, v in p.items() if k not in ("qw", "scale")}
    out["w"] = w.astype(dtype)
    return out


def is_quantized_dense(p: Any) -> bool:
    return isinstance(p, dict) and "qw" in p


def qdense(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Quantized counterpart of `repro.models.layers.dense`: fused
    dequant-matmul plus the (full-precision) bias."""
    from repro.kernels.dequant_matmul import ops as dq_ops
    y = dq_ops.dequant_matmul(x, p["qw"], p["scale"])
    if "b" in p:
        y = y + p["b"]
    return y


# ============================================================ whole-model API

def _walk(node: Any, fmt: str, group_size: int) -> Any:
    if isinstance(node, dict):
        if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
            if fmt == "int4" and node["w"].shape[-2] % 2:
                return dict(node)            # unpackable odd input dim
            return quantize_dense(node, fmt, group_size)
        return {k: (dict(v) if isinstance(v, dict) and k in RAW_WEIGHT_KEYS
                    else _walk(v, fmt, group_size))
                for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_walk(v, fmt, group_size) for v in node)
    return node


def quantize_model(params: Dict, fmt: str = "int8",
                   group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedParams:
    """Quantize every dense weight in a Model params tree.

    Embedding table, lm_head and norms stay full-precision (standard
    weight-only practice: they are small and quantization-sensitive), as do
    the MLA latent decompression weights the absorbed-decode path reads raw
    (`RAW_WEIGHT_KEYS`). Stacked scanned super-blocks quantize in place —
    the leading stack axis broadcasts through the per-layer math.
    """
    if _check_format(fmt) == "bf16":
        return params
    keep = {"embed", "lm_head", "final_norm"}
    return {k: (v if k in keep else _walk(v, fmt, group_size))
            for k, v in params.items()}


def dequantize_model(params: QuantizedParams, dtype=jnp.float32) -> Dict:
    """Reconstruct a full-precision params tree (lossy — quantization error
    is baked in). Used by the bit-parity tests and quality probes."""
    def walk(node: Any) -> Any:
        if is_quantized_dense(node):
            return dequantize_dense(node, dtype)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(params)


# ======================================================= accounting / routing

def params_quant_format(params: Dict) -> str:
    """Recover the serving format from a params tree ("bf16" when no leaf is
    quantized) — backends stamp telemetry records with this."""
    fmt = "bf16"
    for leaf in jax.tree.leaves(params):
        if leaf.dtype == jnp.uint8:
            return "int4"
        if leaf.dtype == jnp.int8:
            fmt = "int8"
    return fmt


def param_bytes(params: Dict) -> int:
    """Actual resident weight bytes of a (possibly quantized) params tree —
    the measured side of the bytes->energy coupling."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def bytes_per_param_for(fmt: str) -> float:
    try:
        return BYTES_PER_PARAM[fmt.lower()]
    except KeyError:
        raise ValueError(f"unknown quant format {fmt!r} "
                         f"(supported: {', '.join(sorted(BYTES_PER_PARAM))})")


def quant_workload(w: Workload, fmt: str,
                   kv_format: str = "bf16") -> Workload:
    """Re-price a `Workload` for a quantized serving variant: weight bytes
    from the weight format, KV-cache bytes from the cache format — the
    knobs `repro.core.decomposition` turns into DASI/CPQ shifts and
    ``plan_costs(model="v2")`` turns into energy."""
    return dataclasses.replace(
        w, bytes_per_param=bytes_per_param_for(fmt),
        bytes_per_kv=1.0 if kv_format == "int8" else None)
