"""Pure-jnp oracle for the grouped expert GEMM."""
import jax.numpy as jnp


def moe_gemm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (E, C, d), w (E, d, f) -> (E, C, f) with f32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
