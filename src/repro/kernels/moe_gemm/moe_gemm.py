"""Grouped expert GEMM Pallas TPU kernel (MoE hot spot).

Computes y[e] = x[e] @ w[e] for E experts in one launch — the dense half of
the capacity-based MoE layer ((E, C, d) x (E, d, f) -> (E, C, f)), which is
the arithmetic core of deepseek-v2-lite / granite / jamba prefill.

Tiling: grid (E, C/bc, f/bf, d/bd) with the contraction dim innermost so the
f32 accumulator lives in VMEM scratch across d-steps; bc/bf/bd default to
128 (MXU-aligned). One expert's (bc x bd) x (bd x bf) working set plus the
accumulator is ~192 KB at defaults — far under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)     # (bc, bd)
    w = w_ref[0].astype(jnp.float32)     # (bd, bf)
    acc_ref[...] += jax.lax.dot(x, w)

    @pl.when(di == nd - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm_pallas(x: jnp.ndarray, w: jnp.ndarray, *,
                    block_c: int = 128, block_f: int = 128,
                    block_d: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x (E, C, d), w (E, d, f) -> (E, C, f)."""
    E, C, D = x.shape
    _, _, F = w.shape
    bc = min(block_c, max(C, 8))
    bf = min(block_f, max(F, 8))
    bd = min(block_d, max(D, 8))
    pc, pf, pd = (-C) % bc, (-F) % bf, (-D) % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    Cp, Dp, Fp = x.shape[1], x.shape[2], w.shape[2]

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(E, Cp // bc, Fp // bf, Dp // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]
