from repro.kernels.moe_gemm.ops import moe_gemm
from repro.kernels.moe_gemm.ref import moe_gemm_ref
