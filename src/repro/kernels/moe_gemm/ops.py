"""Jitted wrapper for the grouped expert GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.moe_gemm import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref
from repro.obs.profiling import kernel_scope


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def moe_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
             block_d: int = 128) -> jnp.ndarray:
    with kernel_scope("moe_gemm"):
        return moe_gemm_pallas(x, w, block_c=block_c, block_f=block_f,
                               block_d=block_d, interpret=not _on_tpu())


__all__ = ["moe_gemm", "moe_gemm_ref"]
