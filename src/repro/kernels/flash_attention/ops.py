"""Jitted public wrapper for the flash attention kernel.

On CPU the Pallas kernel executes in interpret mode (the kernel body runs in
Python/XLA for correctness validation); on TPU the same call compiles to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.obs.profiling import kernel_scope


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    with kernel_scope("flash_attention"):
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, block_q=block_q,
                                      block_k=block_k,
                                      interpret=not _on_tpu())


__all__ = ["flash_attention", "flash_attention_ref"]
