"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,Dv) — materialized-softmax reference."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    if scale is None:
        scale = D ** -0.5
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)
