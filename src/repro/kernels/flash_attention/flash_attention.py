"""Flash attention Pallas TPU kernel (prefill hot path).

Design (TPU-native, DESIGN.md §6):
  * grid (batch, q_heads, q_blocks, k_blocks); the k dimension is innermost so
    the online-softmax running state (m, l, acc) lives in VMEM scratch and is
    carried across k steps — the canonical TPU flash pattern.
  * BlockSpec tiles: q (1, 1, block_q, head_dim), k/v (1, 1, block_k, head_dim)
    with the GQA head group folded into the kv index_map (head h reads kv head
    h // group). block_q = block_k = 128 keeps the MXU matmuls 128-aligned and
    the working set (2 tiles + f32 accumulators) well under VMEM.
  * causal + sliding-window masks are applied per-tile from absolute positions.

Numerics: scores and the softmax state are f32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_len                          # padded tail
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                             # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))

    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q (B, Sq, H, D); k, v (B, Sk, Hkv, D'); returns (B, Sq, H, Dv).

    Sq/Sk are padded to the block sizes internally; GQA via kv-head indexing.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    assert H % Hkv == 0
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qt = jnp.moveaxis(q, 2, 1)                      # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = qt.shape[2], kt.shape[2]

    grid = (B, H, Sqp // bq, Skp // bk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, block_q=bq, block_k=bk,
                               seq_len=Sk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, Dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)[:, :Sq]
