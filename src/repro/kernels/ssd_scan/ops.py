"""Jitted wrapper bridging the model layout (B, nc, Q, H, ...) to the kernel
layout (B*H, nc, Q, ...)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_chunk_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_chunk_pallas
from repro.obs.profiling import kernel_scope


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc):
    """Model layout: xc (B,nc,Q,H,P); dtc/dA/dA_cs (B,nc,Q,H);
    Bc/Cc (B,nc,Q,H,N). Returns (Y_diag (B,nc,Q,H,P), states (B,nc,H,P,N))."""
    B, nc, Q, H, P = xc.shape
    N = Bc.shape[-1]

    def to_bh(a, width):
        a = jnp.moveaxis(a, 3, 1)                 # (B,H,nc,Q,...)
        return a.reshape((B * H, nc, Q, width))

    x_k = to_bh(xc, P)
    dt_k = to_bh(dtc[..., None], 1)
    dA_k = to_bh(dA[..., None], 1)
    cs_k = to_bh(dA_cs[..., None], 1)
    b_k = to_bh(Bc, N)
    c_k = to_bh(Cc, N)

    with kernel_scope("ssd_scan"):
        y, st = ssd_chunk_pallas(x_k, dt_k, dA_k, cs_k, b_k, c_k,
                                 interpret=not _on_tpu())
    y = jnp.moveaxis(y.reshape(B, H, nc, Q, P), 1, 3)        # (B,nc,Q,H,P)
    st = st.reshape(B, H, nc, P, N).transpose(0, 2, 1, 3, 4)  # (B,nc,H,P,N)
    return y, st


__all__ = ["ssd_chunk", "ssd_chunk_ref"]
