from repro.kernels.ssd_scan.ops import ssd_chunk
from repro.kernels.ssd_scan.ref import ssd_chunk_ref
