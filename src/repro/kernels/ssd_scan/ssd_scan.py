"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch*head, chunk) grid cell, the two dense-matmul halves of the
state-space-dual form (DESIGN.md §6 — the MXU-friendly reformulation that
replaces Mamba-1's GPU-style parallel scan):

  Y_diag[q, p]  = sum_{s<=q} (C[q]·B[s]) * exp(segsum(dA))[q,s] * dt[s] * x[s, p]
  state[p, n]   = sum_q  B[q, n] * exp(dAcs[-1] - dAcs[q]) * dt[q] * x[q, p]

The inter-chunk O(1) recurrence stays a lax.scan outside the kernel (it is a
latency-trivial carry; fusing it would serialize the grid).

Block layout: one (chunk Q x headdim P) x (Q x N) working set per grid cell —
Q=128/256, P=64, N=128 keeps everything comfortably in VMEM and the three
matmuls (C@B^T: QxNxQ, scores@x: QxQxP, (x*w)^T@B: PxQxN) MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(x_ref, dt_ref, dA_ref, dAcs_ref, b_ref, c_ref,
                      y_ref, st_ref):
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    dA = dA_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    dAcs = dAcs_ref[0, 0].astype(jnp.float32)  # (Q, 1)
    B = b_ref[0, 0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)        # (Q, N)
    Q = x.shape[0]

    # decay matrix L[q, s] = exp(sum_{s<k<=q} dA[k]) for s<=q, else 0
    cs = dAcs[:, 0]
    diff = cs[:, None] - cs[None, :]           # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (Q, Q)
    scores = scores * L * dt[None, :, 0]
    y_ref[0, 0] = jax.lax.dot(scores, x).astype(y_ref.dtype)      # (Q, P)

    decay = jnp.exp(cs[-1] - cs)[:, None] * dt                    # (Q, 1)
    xw = x * decay                                                # (Q, P)
    st = jax.lax.dot_general(xw, B, (((0,), (0,)), ((), ())))     # (P, N)
    st_ref[0, 0] = st.astype(st_ref.dtype)


def ssd_chunk_pallas(x, dt, dA, dAcs, B, C, *, interpret: bool = True):
    """All inputs laid out (BH, nc, Q, ...): x (BH,nc,Q,P); dt/dA/dAcs
    (BH,nc,Q,1); B/C (BH,nc,Q,N). Returns (Y_diag (BH,nc,Q,P),
    states (BH,nc,P,N)) in f32."""
    BH, nc, Q, P = x.shape
    N = B.shape[-1]
    grid = (BH, nc)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, c: (i, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, dA, dAcs, B, C)
    return y, st
