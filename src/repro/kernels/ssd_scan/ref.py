"""Pure-jnp oracle for the SSD chunk kernel (mirrors repro.models.ssm math)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import segsum


def ssd_chunk_ref(x, dt, dA, dAcs, B, C):
    """Inputs (BH, nc, Q, ...) as in ssd_chunk_pallas; returns (Y_diag, states)."""
    Lmat = jnp.exp(segsum(dA[..., 0]))                       # (BH,nc,Q,Q)
    scores = jnp.einsum("icqn,icsn->icqs", C.astype(jnp.float32),
                        B.astype(jnp.float32))
    scores = scores * Lmat * dt[..., 0][:, :, None, :]
    y = jnp.einsum("icqs,icsp->icqp", scores, x.astype(jnp.float32))
    decay = jnp.exp(dAcs[:, :, -1:] - dAcs) * dt             # (BH,nc,Q,1)
    states = jnp.einsum("icqn,icqp->icpn", B.astype(jnp.float32),
                        (x * decay).astype(jnp.float32))
    return y, states
