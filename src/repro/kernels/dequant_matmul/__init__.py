from repro.kernels.dequant_matmul.dequant_matmul import (
    dequant_matmul_int4_pallas, dequant_matmul_int8_pallas)
from repro.kernels.dequant_matmul.ops import dequant_matmul
from repro.kernels.dequant_matmul.ref import (dequant_matmul_int4_ref,
                                              dequant_matmul_int8_ref,
                                              dequantize_int4,
                                              dequantize_int8, unpack_int4)

__all__ = ["dequant_matmul", "dequant_matmul_int8_pallas",
           "dequant_matmul_int4_pallas", "dequant_matmul_int8_ref",
           "dequant_matmul_int4_ref", "dequantize_int8", "dequantize_int4",
           "unpack_int4"]
