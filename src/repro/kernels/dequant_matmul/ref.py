"""jnp oracle for the fused dequant-matmul kernel.

Semantics contract: **dequantize, then matmul, in f32**. The order matters —
``(x @ qw) * scale`` rounds differently from ``x @ (qw * scale)``, and the
serving bit-parity test (quantized generate vs generate over dequantized f32
params) pins the latter. The Pallas kernels in ``dequant_matmul.py`` are held
to numerical tolerance against this oracle, not bitwise.

Packing convention (shared with `repro.quant.quantize.pack_int4`): two
consecutive input rows per byte — packed row ``r`` holds original row ``2r``
in the low nibble and row ``2r + 1`` in the high nibble, values sign-extended
from [-8, 7] two's complement.
"""
from __future__ import annotations

import jax.numpy as jnp


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., K//2, N) uint8 -> (..., K, N) int8 in [-8, 7]."""
    lo = ((packed & 0xF).astype(jnp.int32) ^ 8) - 8
    hi = ((packed >> 4).astype(jnp.int32) ^ 8) - 8
    q = jnp.stack([lo, hi], axis=-2)            # (..., K//2, 2, N)
    return q.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                     packed.shape[-1]).astype(jnp.int8)


def dequantize_int8(qw: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Per-out-channel int8 -> f32: ``w[k, n] = qw[k, n] * scale[n]``."""
    return qw.astype(jnp.float32) * scale[..., None, :].astype(jnp.float32)


def dequantize_int4(packed: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Group-wise packed int4 -> f32. ``scale`` (..., G, N) covers groups of
    ``K // G`` consecutive input rows."""
    q = unpack_int4(packed).astype(jnp.float32)   # (..., K, N)
    K, N = q.shape[-2], q.shape[-1]
    G = scale.shape[-2]
    grouped = q.reshape(*q.shape[:-2], G, K // G, N)
    w = grouped * scale[..., :, None, :].astype(jnp.float32)
    return w.reshape(q.shape)


def dequant_matmul_int8_ref(x: jnp.ndarray, qw: jnp.ndarray,
                            scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., K) @ dequantize_int8(qw (K, N), scale (N,)) -> (..., N)."""
    w = dequantize_int8(qw, scale)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def dequant_matmul_int4_ref(x: jnp.ndarray, packed: jnp.ndarray,
                            scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., K) @ dequantize_int4(packed (K//2, N), scale (G, N))."""
    w = dequantize_int4(packed, scale)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
