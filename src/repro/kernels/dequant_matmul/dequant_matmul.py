"""Fused dequant-matmul Pallas TPU kernels (weight-only int8 / packed int4).

HBM traffic for the weight operand is the *packed* bytes: the kernel reads
int8 (or nibble-packed uint8) tiles plus their scales and dequantizes
in-register, so decode weight streaming moves 2x (int8) or 4x (int4) fewer
bytes than bf16 — exactly the byte reduction the paper's 4-bit IPW headline
rides on.

Both kernels run a (m, n, k) grid with the contraction innermost (the
`repro.kernels.moe_gemm` pattern): an f32 VMEM accumulator is zeroed at
``k == 0`` and written out at the last k step.

* int8 (per-out-channel scales): the scale folds out of the k-sum exactly, so
  raw integer products accumulate and one multiply by ``scale[n]`` happens at
  write-out.
* int4 (group-wise scales): ``block_k`` equals the quantization group size,
  so each grid step covers exactly one scale group. The packed rows stay
  packed — the even input rows multiply the low nibbles and the odd rows the
  high nibbles, which avoids materializing an interleaved unpacked tile:

      acc += (x_even @ lo + x_odd @ hi) * scale[g]

Inputs are padded to block multiples (outputs sliced back); K never needs
padding for int4 because quantization guarantees ``K % group_size == 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_kernel(x_ref, qw_ref, scale_ref, o_ref, acc_ref):
    k, nk = pl.program_id(2), pl.num_programs(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk)
    w = qw_ref[...].astype(jnp.float32)           # (bk, bn) dequant sans scale
    acc_ref[...] += jax.lax.dot(x, w)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def dequant_matmul_int8_pallas(x: jnp.ndarray, qw: jnp.ndarray,
                               scale: jnp.ndarray, *, block_m: int = 128,
                               block_n: int = 128, block_k: int = 128,
                               interpret: bool = False) -> jnp.ndarray:
    """x (M, K) float, qw (K, N) int8, scale (N,) f32 -> (M, N) in x.dtype."""
    M, K = x.shape
    N = qw.shape[1]
    bm = min(block_m, max(M, 8))
    bn = min(block_n, max(N, 128))
    bk = min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    qwp = jnp.pad(qw, ((0, pk), (0, pn)))
    sp = jnp.pad(scale.astype(jnp.float32), (0, pn)).reshape(1, -1)
    grid = ((M + pm) // bm, (N + pn) // bn, (K + pk) // bk)
    out = pl.pallas_call(
        _int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, qwp, sp)
    return out[:M, :N]


def _int4_kernel(x_ref, qw_ref, scale_ref, o_ref, acc_ref):
    g, ng = pl.program_id(2), pl.num_programs(2)

    @pl.when(g == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, gs)
    bm, gs = x.shape
    xt = x.reshape(bm, gs // 2, 2)                # even/odd input rows
    p = qw_ref[...]                               # (gs//2, bn) packed uint8
    lo = (((p & 0xF).astype(jnp.int32) ^ 8) - 8).astype(jnp.float32)
    hi = (((p >> 4).astype(jnp.int32) ^ 8) - 8).astype(jnp.float32)
    part = jax.lax.dot(xt[:, :, 0], lo) + jax.lax.dot(xt[:, :, 1], hi)
    acc_ref[...] += part * scale_ref[...]         # one scale group per step

    @pl.when(g == ng - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def dequant_matmul_int4_pallas(x: jnp.ndarray, packed: jnp.ndarray,
                               scale: jnp.ndarray, *, block_m: int = 128,
                               block_n: int = 128,
                               interpret: bool = False) -> jnp.ndarray:
    """x (M, K) float, packed (K//2, N) uint8, scale (G, N) f32 -> (M, N).

    The group size ``K // G`` is implied by the shapes; it must be even (the
    quantizer guarantees this — two rows pack per byte).
    """
    M, K = x.shape
    N = packed.shape[1]
    G = scale.shape[0]
    gs = K // G
    bm = min(block_m, max(M, 8))
    bn = min(block_n, max(N, 128))
    pm, pn = (-M) % bm, (-N) % bn
    xp = jnp.pad(x, ((0, pm), (0, 0)))
    # zero nibbles decode to 0 ((0 ^ 8) - 8 == 0), so N-padding is inert
    qp = jnp.pad(packed, ((0, 0), (0, pn)))
    sp = jnp.pad(scale.astype(jnp.float32), ((0, 0), (0, pn)))
    grid = ((M + pm) // bm, (N + pn) // bn, G)
    out = pl.pallas_call(
        _int4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, gs), lambda i, j, g: (i, g)),
            pl.BlockSpec((gs // 2, bn), lambda i, j, g: (g, j)),
            pl.BlockSpec((1, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, qp, sp)
    return out[:M, :N]
