"""Jitted dispatch for the fused dequant-matmul.

Format is discriminated by the quantized-weight dtype (no side metadata, so
the dispatch survives `jax.lax.scan` over stacked per-layer params): ``int8``
means per-out-channel int8, ``uint8`` means nibble-packed group-wise int4.

On TPU the fused Pallas kernel runs (HBM moves the packed bytes); everywhere
else the jnp oracle runs directly — unlike the attention ops wrappers this
does *not* interpret the kernel on CPU, because the oracle's dequantize-
then-matmul rounding is the semantics the serving bit-parity test pins and
interpret-mode parity is covered by ``tests/test_quant.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dequant_matmul.dequant_matmul import (
    dequant_matmul_int4_pallas, dequant_matmul_int8_pallas)
from repro.kernels.dequant_matmul.ref import (dequant_matmul_int4_ref,
                                              dequant_matmul_int8_ref,
                                              dequantize_int4,
                                              dequantize_int8, unpack_int4)
from repro.obs.profiling import kernel_scope


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def dequant_matmul(x: jnp.ndarray, qw: jnp.ndarray,
                   scale: jnp.ndarray) -> jnp.ndarray:
    """``x (..., K) @ dequantize(qw, scale) -> (..., N)`` in x.dtype."""
    lead = x.shape[:-1]
    with kernel_scope("dequant_matmul"):
        if qw.dtype == jnp.uint8:
            if _on_tpu():
                y = dequant_matmul_int4_pallas(x.reshape(-1, x.shape[-1]),
                                               qw, scale)
                return y.reshape(*lead, y.shape[-1])
            return dequant_matmul_int4_ref(x, qw, scale)
        if _on_tpu():
            y = dequant_matmul_int8_pallas(x.reshape(-1, x.shape[-1]),
                                           qw, scale)
            return y.reshape(*lead, y.shape[-1])
        return dequant_matmul_int8_ref(x, qw, scale)


__all__ = ["dequant_matmul", "dequant_matmul_int8_pallas",
           "dequant_matmul_int4_pallas", "dequant_matmul_int8_ref",
           "dequant_matmul_int4_ref", "dequantize_int8", "dequantize_int4",
           "unpack_int4"]
