"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, pos: jnp.ndarray,
                         q_pos: jnp.ndarray, *,
                         scale: Optional[float] = None,
                         window: Optional[int] = None) -> jnp.ndarray:
    """q (B,1,H,D); caches (B,W,Hkv,Dv); pos (B,W); q_pos (B,)."""
    B, _, H, D = q.shape
    _, W, Hkv, Dv = v_cache.shape
    if scale is None:
        scale = D ** -0.5
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                   k_cache.astype(jnp.float32)) * scale
    valid = (pos >= 0) & (pos <= q_pos[:, None])
    if window is not None:
        valid &= pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, Dv).astype(q.dtype)


def paged_decode_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, pos_pool: jnp.ndarray,
                               block_table: jnp.ndarray,
                               q_pos: jnp.ndarray, *,
                               scale: Optional[float] = None,
                               kv_len: Optional[int] = None) -> jnp.ndarray:
    """Oracle for the paged kernel: gather each sequence's pool blocks in
    logical order into a dense (B, nb*bs) cache view, then run the dense
    decode oracle. pools (P, bs, Hkv, D[v]); block_table (B, nb)."""
    B = q.shape[0]
    kc = k_pool[block_table].reshape(B, -1, *k_pool.shape[2:])
    vc = v_pool[block_table].reshape(B, -1, *v_pool.shape[2:])
    pc = pos_pool[block_table].reshape(B, -1)
    if kv_len is not None:
        kc, vc, pc = kc[:, :kv_len], vc[:, :kv_len], pc[:, :kv_len]
    return decode_attention_ref(q, kc, vc, pc, q_pos, scale=scale)
