"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, pos: jnp.ndarray,
                         q_pos: jnp.ndarray, *,
                         scale: Optional[float] = None,
                         window: Optional[int] = None) -> jnp.ndarray:
    """q (B,1,H,D); caches (B,W,Hkv,Dv); pos (B,W); q_pos (B,)."""
    B, _, H, D = q.shape
    _, W, Hkv, Dv = v_cache.shape
    if scale is None:
        scale = D ** -0.5
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                   k_cache.astype(jnp.float32)) * scale
    valid = (pos >= 0) & (pos <= q_pos[:, None])
    if window is not None:
        valid &= pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, Dv).astype(q.dtype)
