"""Decode attention Pallas TPU kernel (single-token query vs. KV cache).

This is the paper's memory-bound hot spot (Formalism 5: decode intensity ~1,
route to bandwidth-optimal hardware). The kernel streams the KV cache exactly
once per step — the bytes term the MLA latent cache and sliding-window variants
shrink in the §Perf hillclimbs.

Design:
  * grid (batch, q_heads, kv_blocks); kv innermost so the flash-style running
    (m, l, acc) scratch carries across cache tiles.
  * BlockSpec tiles: cache k/v (1, block_k, 1, head_dim) per (batch, kv-head);
    the per-slot validity mask comes from the absolute-position array the ring
    cache maintains (pos >= 0, pos <= q_pos, window).
  * q is tiny (1 row per head) — broadcast from VMEM; accumulation in f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   window: Optional[int], block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32)          # (1, hd) single q row
    k = k_ref[0, :, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)          # (bk, hd)
    slot_pos = pos_ref[0]                            # (bk,) absolute positions
    q_pos = qpos_ref[0]                              # scalar in (1,) block

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1, bk)
    valid = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        valid &= slot_pos > q_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(valid[None, :], jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def decode_attention_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, pos: jnp.ndarray,
                            q_pos: jnp.ndarray, *,
                            scale: Optional[float] = None,
                            window: Optional[int] = None,
                            block_k: int = 128,
                            interpret: bool = True) -> jnp.ndarray:
    """q (B, 1, H, D); k_cache/v_cache (B, W, Hkv, D); pos (B, W) absolute
    positions per cache slot (-1 = empty); q_pos (B,) current positions.
    Returns (B, 1, H, Dv)."""
    B, S1, H, D = q.shape
    assert S1 == 1, "decode kernel is single-token"
    _, W, Hkv, Dv = v_cache.shape
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    bk = min(block_k, max(W, 8))
    pad = (-W) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    Wp = k_cache.shape[1]

    grid = (B, H, Wp // bk)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, 0, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, j, g=group: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dv),
                         lambda b, h, j, g=group: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dv), lambda b, h, j: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, pos.astype(jnp.int32), q_pos.astype(jnp.int32))
    return out


def _paged_decode_kernel(tab_ref, q_ref, k_ref, v_ref, pos_ref, qpos_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale: float):
    """Block-table variant: the grid's kv axis walks a sequence's *logical*
    blocks and the scalar-prefetched table redirects each BlockSpec fetch to
    the physical pool block — the k repeats of one prompt stream their shared
    prefix blocks from the same HBM locations. Math is identical to
    `_decode_kernel` (flash-style running (m, l, acc) over kv tiles)."""
    del tab_ref                       # consumed by the index_maps
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32)           # (1, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, hd) one pool block
    v = v_ref[0, :, 0].astype(jnp.float32)
    slot_pos = pos_ref[0]                             # (bs,) absolute positions
    q_pos = qpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    valid = (slot_pos >= 0) & (slot_pos <= q_pos)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(valid[None, :], jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                                  v_pool: jnp.ndarray, pos_pool: jnp.ndarray,
                                  block_table: jnp.ndarray,
                                  q_pos: jnp.ndarray, *,
                                  scale: Optional[float] = None,
                                  interpret: bool = True) -> jnp.ndarray:
    """Paged decode attention: q (B, 1, H, D); pools (P, bs, Hkv, D[v]) of
    fixed-size KV blocks; pos_pool (P, bs) absolute positions per pool slot
    (-1 = empty); block_table (B, nb) physical block per logical block;
    q_pos (B,). Returns (B, 1, H, Dv).

    The table rides in as a scalar-prefetch operand
    (`pltpu.PrefetchScalarGridSpec`) so the index_maps — which run ahead of
    the kernel body to schedule DMA — can do the gather; no dense (B, W)
    copy of the cache is ever materialized."""
    B, S1, H, D = q.shape
    assert S1 == 1, "decode kernel is single-token"
    _, bs, Hkv, Dv = v_pool.shape
    nb = block_table.shape[1]
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, tab: (b, 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tab, g=group: (tab[b, j], 0,
                                                        h // g, 0)),
            pl.BlockSpec((1, bs, 1, Dv),
                         lambda b, h, j, tab, g=group: (tab[b, j], 0,
                                                        h // g, 0)),
            pl.BlockSpec((1, bs), lambda b, h, j, tab: (tab[b, j], 0)),
            pl.BlockSpec((1,), lambda b, h, j, tab: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dv), lambda b, h, j, tab: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, H, Dv), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q, k_pool, v_pool,
      pos_pool.astype(jnp.int32), q_pos.astype(jnp.int32))
    return out
