"""Jitted wrapper for decode attention. The model-level entry point accepts the
boolean mask the reference attention uses and converts to the kernel's
(pos, q_pos) form when the caller has them; the direct (pos, q_pos) API is the
efficient path used by the serving engine."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.obs.profiling import kernel_scope


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_k"))
def decode_attention_cache(q, k_cache, v_cache, pos, q_pos, *,
                           scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_k: int = 128) -> jnp.ndarray:
    with kernel_scope("decode_attention"):
        return decode_attention_pallas(q, k_cache, v_cache, pos, q_pos,
                                       scale=scale, window=window,
                                       block_k=block_k,
                                       interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("scale",))
def paged_decode_attention(q, k_pool, v_pool, pos_pool, block_table, q_pos, *,
                           scale: Optional[float] = None) -> jnp.ndarray:
    """Paged (block-table) decode attention over fixed-size KV pools — the
    serving hot path when the backend runs a paged cache."""
    with kernel_scope("paged_decode_attention"):
        return paged_decode_attention_pallas(q, k_pool, v_pool, pos_pool,
                                             block_table, q_pos, scale=scale,
                                             interpret=not _on_tpu())


def decode_attention(q, k_cache, v_cache, mask, *, scale=None):
    """Mask-based compatibility shim for repro.models.attention: falls back to
    the reference math (the mask already encodes positions/window)."""
    import numpy as np
    from repro.models.attention import sdpa
    return sdpa(q, k_cache, v_cache, mask,
                scale if scale is not None else q.shape[-1] ** -0.5)


__all__ = ["decode_attention_cache", "decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref"]
