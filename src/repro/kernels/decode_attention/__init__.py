from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_cache,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
