from repro.distributed.sharding import ShardingPolicy

__all__ = ["ShardingPolicy"]
