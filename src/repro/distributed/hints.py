"""Activation sharding hints (§Perf optimization, off by default).

GSPMD infers most internal shardings from the jit-boundary constraints, but
the Mamba-2 SSD block defeats it: B/C come from slicing a tensor-sharded
projection at non-shard-aligned offsets, so propagation gives up and
replicates the whole chunked scan over the "model" axis (measured: per-device
HLO FLOPs ~16x the sharded ideal, see EXPERIMENTS.md §Perf pair 1).

``constrain(x, dim_axes)`` inserts a with_sharding_constraint pinning chosen
dims to mesh axes while leaving the rest unconstrained. Enabled globally via
``enable()`` (the dry-run's --hints flag) so the baseline stays measurable.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_ENABLED = False
_TENSOR_AXIS = "model"


def enable(tensor_axis: str = "model") -> None:
    global _ENABLED, _TENSOR_AXIS
    _ENABLED = True
    _TENSOR_AXIS = tensor_axis


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def constrain(x: jax.Array, dim_axes: Tuple[Optional[str], ...],
              divisible_dim: Optional[int] = None) -> jax.Array:
    """Pin dims named "tensor" to the tensor axis; None dims unconstrained.

    divisible_dim: index whose size must divide the axis (skip hint if not).
    """
    if not _ENABLED:
        return x
    try:
        mesh_size = None
        env = jax.sharding.get_abstract_mesh()
        if env is not None and _TENSOR_AXIS in getattr(env, "shape", {}):
            mesh_size = env.shape[_TENSOR_AXIS]
    except Exception:
        mesh_size = None
    spec = []
    for i, a in enumerate(dim_axes):
        if a == "tensor":
            if mesh_size is not None and x.shape[i] % mesh_size != 0:
                return x  # not divisible: skip the hint entirely
            spec.append(_TENSOR_AXIS)
        else:
            spec.append(P.UNCONSTRAINED)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
