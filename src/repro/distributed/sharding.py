"""Divisibility-aware sharding policy.

Strategy (DESIGN.md §5): constrain only the jit boundary — parameters, inputs,
caches, outputs — and let GSPMD propagate the interior. Every PartitionSpec
this policy emits is checked for divisibility, so ``jax.jit(...).lower()``
never fails on uneven shards (e.g. mamba2's vocab 50280 or granite's 49155
simply stay unsharded on that dim).

Parameter rules:
  * stacked decoder blocks lead with a layer axis (never sharded);
  * the last dim goes to the tensor axis ("model"), the second-to-last to the
    FSDP axis ("data") — 2-D sharded weights a la MaxText;
  * MoE expert stacks (..., E, d, ff) put E on "model" (expert parallelism)
    when divisible, falling back to tensor-parallel ff;
  * 1-D params (norm scales, biases) replicate.

Batch rules: batch dim over ("pod", "data") when divisible (pods are pure data
parallel), else over ("data",), else replicated (long_500k's batch of 1). Cache
rules: batch -> data axes, per-head/feature dim -> "model" when divisible.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardingPolicy:
    @classmethod
    def auto(cls, mesh: Mesh, cfg, global_batch: int,
             tp_threshold_params: float = 2e9) -> "ShardingPolicy":
        """Beyond-paper (§Perf P1): size-aware layout selection.

        Sub-`tp_threshold` models on a 16-wide tensor axis are
        communication-dominated (measured on mamba2-370m: DP-only cut bytes
        83% and collectives 80%); use the pure data-parallel layout whenever
        the model is small AND the global batch can fill the whole mesh.
        """
        from repro.models.model import Model
        n_params = Model(cfg).param_count()
        n_dev = mesh.devices.size
        tensor = not (n_params < tp_threshold_params and
                      global_batch % n_dev == 0 and global_batch >= n_dev)
        return cls(mesh, tensor_enabled=tensor)

    def __init__(self, mesh: Mesh, fsdp_axis: str = "data",
                 tensor_axis: str = "model",
                 dp_axes: Optional[Tuple[str, ...]] = None,
                 fsdp_enabled: bool = True,
                 tensor_enabled: bool = True):
        """tensor_enabled=False: pure data-parallel layout — the "model" axis
        joins the batch axes and weights shard over FSDP only. The right
        choice for small archs (mamba2-370m) where 16-way tensor parallelism
        makes every matmul collective-bound (§Perf pair 1)."""
        self.mesh = mesh
        self.fsdp_axis = fsdp_axis
        self.tensor_axis = tensor_axis if tensor_enabled else None
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if dp_axes is None:
            dp_axes = tuple(a for a in ("pod", "data") if a in self.axis_sizes)
            if not tensor_enabled and "model" in self.axis_sizes:
                dp_axes = dp_axes + ("model",)
        self.dp_axes = dp_axes
        self.fsdp_enabled = fsdp_enabled

    # ------------------------------------------------------------- helpers
    def _fits(self, dim: int, axis) -> bool:
        if axis is None:
            return True
        if isinstance(axis, tuple):
            n = int(np.prod([self.axis_sizes[a] for a in axis]))
        else:
            n = self.axis_sizes[axis]
        return dim % n == 0 and dim >= n

    def _maybe(self, dim: int, axis):
        return axis if self._fits(dim, axis) else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------- params
    def param_spec(self, path: Tuple, leaf) -> P:
        """PartitionSpec for one parameter, from its pytree path + shape."""
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape = leaf.shape
        nd = len(shape)
        stacked = "blocks" in keys          # leading layer-stack axis
        lead = 1 if stacked else 0
        fsdp = self.fsdp_axis if self.fsdp_enabled else None

        if nd - lead <= 1:                  # scales, biases, A_log, ...
            return P(*([None] * nd))

        # MoE expert stacks: (..., E, d_model, d_ff) under gate/up/down
        if any(k in ("gate", "up", "down") for k in keys) and nd - lead == 3:
            E, d_in, d_out = shape[lead:]
            e_ax = self._maybe(E, self.tensor_axis)
            if e_ax is not None:            # expert parallelism
                spec = [None] * lead + [e_ax, self._maybe(d_in, fsdp), None]
            else:                           # fallback: tensor-parallel ff
                ff_ax = self._maybe(d_out, self.tensor_axis)
                spec = [None] * lead + [None, self._maybe(d_in, fsdp), ff_ax]
            return P(*spec)

        # generic >=2-D weights: last dim -> tensor, second-to-last -> fsdp
        spec = [None] * nd
        spec[-1] = self._maybe(shape[-1], self.tensor_axis)
        fs = self._maybe(shape[-2], fsdp)
        # avoid double-assigning the same axis
        if fs != spec[-1]:
            spec[-2] = fs
        return P(*spec)

    def param_shardings(self, param_specs: Any) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.named(self.param_spec(path, leaf)),
            param_specs)

    # ------------------------------------------------------------- batch
    def batch_axes(self, batch_size: int):
        """Largest prefix of dp axes that divides the batch."""
        for axes in (self.dp_axes, self.dp_axes[:1], ()):
            if not axes:
                return None
            n = int(np.prod([self.axis_sizes[a] for a in axes]))
            if batch_size % n == 0 and batch_size >= n:
                return axes if len(axes) > 1 else axes[0]
        return None

    def data_spec(self, path: Tuple, leaf) -> P:
        """Sharding for batch dict entries (tokens, labels, positions, ...)."""
        shape = leaf.shape
        ba = self.batch_axes(shape[0]) if shape else None
        spec = [ba] + [None] * (len(shape) - 1)
        # embeddings-like entries (B, T, d_model): shard feature dim too
        if len(shape) == 3 and shape[-1] >= 128:
            spec[-1] = self._maybe(shape[-1], self.tensor_axis)
        return P(*spec)

    def batch_shardings(self, batch_specs: Dict) -> Dict:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.named(self.data_spec(path, leaf)),
            batch_specs)

    # ------------------------------------------------------------- cache
    def cache_spec(self, path: Tuple, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape = leaf.shape
        stacked = "blocks" in keys
        lead = 1 if stacked else 0
        rest = shape[lead:]
        spec = [None] * len(shape)
        if not rest:
            return P(*spec)
        ba = self.batch_axes(rest[0])
        spec[lead] = ba
        name = keys[-1]
        if name in ("k", "v"):              # (B, W, kv, hd): shard hd
            spec[lead + 3] = self._maybe(rest[3], self.tensor_axis)
        elif name in ("c_kv", "k_rope"):    # (B, W, r): shard latent dim
            spec[lead + 2] = self._maybe(rest[2], self.tensor_axis)
        elif name == "ssm":                 # (B, H, P, N): shard heads
            spec[lead + 1] = self._maybe(rest[1], self.tensor_axis)
        elif name == "conv":                # (B, K-1, Ch): shard channels
            spec[lead + 2] = self._maybe(rest[2], self.tensor_axis)
        # "pos": batch-sharded only
        return P(*spec)

    def cache_shardings(self, cache_specs: Any) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.named(self.cache_spec(path, leaf)),
            cache_specs)

    # ------------------------------------------------------------- outputs
    def logits_spec(self, batch_size: int, vocab: int,
                    extra_dims: int = 1) -> P:
        ba = self.batch_axes(batch_size)
        return P(*([ba] + [None] * extra_dims +
                   [self._maybe(vocab, self.tensor_axis)]))

    def opt_state_shardings(self, param_specs: Any) -> Dict:
        ps = self.param_shardings(param_specs)
        return {"m": ps, "v": ps,
                "step": self.named(P())}

    def scalar(self) -> NamedSharding:
        return self.named(P())
