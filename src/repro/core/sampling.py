"""Repeated-sampling inference with a quality-verification cascade.

Implements the paper's inference-time scaling loop (Brown et al.-style repeated
sampling, Section 2.1) on top of the serving engine, plus the quality-verification
cascade the orchestration is entangled with: a cheap verifier (sequence logprob /
self-consistency screening) gates which candidates reach the expensive exact
verifier, so verification cost scales with the *surviving* candidate count.

Two modes:
  * ``run_pass_at_k`` — real sampling with a trained model on verifiable tasks
    (the arith generator), producing true pass@k outcome matrices for the
    formalism fits.
  * ``simulate_outcomes`` — Bernoulli simulation from Formalism 1 (used by the
    paper-scale benches where running a 2.6B model is not possible here).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fitting import empirical_coverage
from repro.core.formalisms import CoverageParams, coverage


# ------------------------------------------------------------------ cascade

@dataclass
class CascadeStats:
    candidates: int = 0
    cheap_passed: int = 0
    exact_checked: int = 0
    exact_passed: int = 0
    skipped: int = 0          # CSVET: exact checks cut short by early stopping

    @property
    def verification_savings(self) -> float:
        """Fraction of exact-verifier calls avoided by the cheap screen."""
        if self.candidates == 0:
            return 0.0
        return 1.0 - self.exact_checked / self.candidates


class VerifierCascade:
    """cheap screen (logprob threshold + majority clustering) -> exact check.

    With ``early_stop`` (CSVET — cheap-score verified early termination), exact
    verification runs in descending cheap-score order and halts once a verified
    pass is found: the pass@k outcome for the batch is ``any(pass)``, so once
    one candidate passes, the remaining candidates — whose cheap-score upper
    bound is at most the already-passing candidate's — cannot change it.
    """

    def __init__(self, exact_verify: Callable[[np.ndarray], bool],
                 logprob_quantile: float = 0.5,
                 always_check_top: int = 1,
                 early_stop: bool = False, obs=None):
        self.exact_verify = exact_verify
        self.q = logprob_quantile
        self.always_check_top = always_check_top
        self.early_stop = early_stop
        self.stats = CascadeStats()
        # optional repro.obs bundle: per-exact-check "verify" spans (wall
        # clock — the exact verifier is real host work) + cascade counters
        from repro.obs import NULL_OBS
        self.obs = obs if obs is not None else NULL_OBS
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "candidates": reg.counter(
                    "cascade_candidates_total",
                    "Samples entering the verification cascade"),
                "exact_checked": reg.counter(
                    "cascade_exact_checked_total",
                    "Exact-verifier invocations"),
                "exact_passed": reg.counter(
                    "cascade_exact_passed_total",
                    "Exact-verifier passes"),
                "skipped": reg.counter(
                    "cascade_skipped_total",
                    "Exact checks avoided by CSVET early stopping"),
            }

    def verify(self, samples: Sequence[np.ndarray],
               logprobs: Sequence[float],
               request_id: Optional[int] = None) -> List[bool]:
        """``request_id`` (optional) stamps the emitted verify/early_stop
        spans so verification time attributes to the serving request."""
        n = len(samples)
        self.stats.candidates += n
        if self._m is not None:
            self._m["candidates"].inc(n)
        lp = np.asarray(logprobs, float)
        thresh = np.quantile(lp, self.q) if n > 1 else -np.inf
        order = np.argsort(-lp)
        survivors = set(np.nonzero(lp >= thresh)[0].tolist())
        survivors |= set(order[: self.always_check_top].tolist())
        self.stats.cheap_passed += len(survivors)

        # early_stop checks best-cheap-score first so a pass is found with as
        # few exact calls as possible; without it, keep the original index
        # order (order is observable only through the verifier's side effects).
        check_order = [i for i in order.tolist() if i in survivors] \
            if self.early_stop else [i for i in range(n) if i in survivors]
        out = [False] * n
        found_pass = False
        tracer = self.obs.tracer
        for pos, i in enumerate(check_order):
            if found_pass:
                skipped = len(check_order) - pos
                self.stats.skipped += skipped
                if self._m is not None:
                    self._m["skipped"].inc(skipped)
                if tracer.enabled:
                    import time
                    tracer.emit("early_stop", time.perf_counter(),
                                clock="wall", request_id=request_id,
                                skipped=skipped)
                break
            self.stats.exact_checked += 1
            if tracer.enabled:
                import time
                t0 = time.perf_counter()
                out[i] = bool(self.exact_verify(samples[i]))
                tracer.emit("verify", t0, time.perf_counter(), clock="wall",
                            request_id=request_id, sample=i, passed=out[i])
            else:
                out[i] = bool(self.exact_verify(samples[i]))
            self.stats.exact_passed += int(out[i])
            if self._m is not None:
                self._m["exact_checked"].inc()
                self._m["exact_passed"].inc(int(out[i]))
            if out[i] and self.early_stop:
                found_pass = True
        return out


# ------------------------------------------------------------------ real runs

@dataclass
class PassAtKResult:
    outcomes: np.ndarray              # (n_tasks, n_samples) bool
    coverage_by_k: Dict[int, float]
    cascade: CascadeStats
    decode_tokens: int
    prefill_tokens: int


def run_pass_at_k(engine, tasks: Sequence[Tuple[np.ndarray, Callable]],
                  n_samples: int, rng=None,
                  budgets: Sequence[int] = (1, 2, 5, 10, 20),
                  logprob_quantile: float = 0.5) -> PassAtKResult:
    """tasks: (prompt, exact_verifier) pairs. Samples n_samples per task with
    the engine, verifies through the cascade, returns pass@k estimates."""
    import jax
    rng = rng if rng is not None else jax.random.key(0)
    prompts = [t[0] for t in tasks]
    results = engine.generate(prompts, n_samples=n_samples, rng=rng)
    outcomes = np.zeros((len(tasks), n_samples), bool)
    stats = CascadeStats()
    dec_toks = pre_toks = 0
    for i, ((_, verify), res) in enumerate(zip(tasks, results)):
        cascade = VerifierCascade(verify, logprob_quantile)
        flags = cascade.verify(res.samples, res.logprobs)
        outcomes[i] = flags
        s = cascade.stats
        for f in dataclasses.fields(CascadeStats):
            setattr(stats, f.name,
                    getattr(stats, f.name) + getattr(s, f.name))
        dec_toks += res.decode_tokens
        pre_toks += res.prefill_tokens
    cov = empirical_coverage(outcomes, budgets)
    return PassAtKResult(outcomes, cov, stats, dec_toks, pre_toks)


# ------------------------------------------------------------------ simulate

DIFFICULTY_SIGMA = 1.4   # lognormal spread calibrated so fitted beta ~ 0.70


def rate_for_target(target_cov: float, S_ref: int = 20,
                    sigma: float = DIFFICULTY_SIGMA,
                    n_mc: int = 200_000, seed: int = 123) -> float:
    """Solve for the per-sample base rate giving pass@S_ref == target_cov under
    lognormal task-difficulty heterogeneity (deterministic MC + bisection)."""
    rng = np.random.default_rng(seed)
    diff = rng.lognormal(mean=-sigma ** 2 / 2, sigma=sigma, size=n_mc)

    def cov_at(rate1: float) -> float:
        q = 1.0 - np.exp(-rate1 * diff)
        return float(np.mean(1.0 - (1.0 - q) ** S_ref))

    lo, hi = 1e-6, 50.0
    for _ in range(80):
        mid = np.sqrt(lo * hi)
        if cov_at(mid) < target_cov:
            lo = mid
        else:
            hi = mid
    return float(np.sqrt(lo * hi))


def simulate_outcomes(n_tasks: int, n_samples: int,
                      target_cov: float = 0.70, S_ref: int = 20,
                      sigma: float = DIFFICULTY_SIGMA,
                      seed: int = 0) -> np.ndarray:
    """Bernoulli outcome matrix whose pass@k tracks Formalism 1.

    Heavy-tailed (lognormal) per-task difficulty is what bends the coverage
    curve from beta=1 (homogeneous Bernoulli) to the paper's beta ~ 0.7; sigma
    is calibrated so the fitted exponent lands in the paper's [0.66, 0.76]
    band while pass@S_ref hits ``target_cov``.
    """
    rng = np.random.default_rng(seed)
    rate1 = rate_for_target(target_cov, S_ref, sigma)
    diff = rng.lognormal(mean=-sigma ** 2 / 2, sigma=sigma, size=n_tasks)
    q = 1.0 - np.exp(-rate1 * diff)
    return rng.random((n_tasks, n_samples)) < q[:, None]


def adaptive_sample_budget(N_millions: float, T: float, target_cov: float,
                           max_samples: int = 64,
                           p: CoverageParams = CoverageParams()) -> int:
    """Paper's 'adaptive sample budget' component: smallest S hitting the
    coverage target (inverse of Formalism 1), capped."""
    from repro.core.formalisms import samples_for_coverage
    s = samples_for_coverage(target_cov, N_millions, T, p)
    return int(min(max(np.ceil(s), 1), max_samples))
