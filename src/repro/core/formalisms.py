"""The paper's five inference-time scaling formalisms (Section 3.3), as code.

All functions are closed-form and pure; the *fitted* variants (exponents estimated
from observed coverage curves) live in ``repro.core.fitting``. Default constants are
the paper's reported values: beta_N = beta_S = 0.7, delta = 0.2, alpha ~= 1e-4,
gamma_E = 0.9, f(FP16)=1.0, f(FP8)=0.65.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.devices import DeviceProfile


# =========================================================================== F1
@dataclass(frozen=True)
class CoverageParams:
    alpha: float = 1.67e-3
    beta_N: float = 0.7
    beta_S: float = 0.7
    delta: float = 0.2

    @classmethod
    def calibrated(cls, N_millions: float, target_cov: float = 0.70,
                   S: float = 20.0, T: float = 256.0,
                   beta_N: float = 0.7, beta_S: float = 0.7,
                   delta: float = 0.2) -> "CoverageParams":
        """alpha(N) such that C(S, N, T) == target_cov.

        The paper calls alpha "model-dependent" (Formalism 1.1) and its quoted
        alpha ~ 1e-4 is not consistent with its own coverage tables under any
        unit for N; we therefore treat alpha as the per-model calibration knob
        (exactly its declared role) and fix it from the Table 16 pass@k.
        """
        rate = -math.log(1.0 - target_cov)
        alpha = rate / ((N_millions ** beta_N) * (S ** beta_S) * (T ** delta))
        return cls(alpha=alpha, beta_N=beta_N, beta_S=beta_S, delta=delta)


def coverage(S: float, N: float, T: float,
             p: CoverageParams = CoverageParams()) -> float:
    """Formalism 1.1: C(S,N,T) = 1 - exp(-alpha * N^bN * S^bS * T^delta).

    N in parameters, S samples, T tokens/sample. N is fed in units of millions
    of parameters (the paper's alpha ~ 1e-4 calibration regime: GPT-2 at N=125,
    S=20, T=256 gives C ~ 0.70, matching Table 16).
    """
    rate = p.alpha * (N ** p.beta_N) * (S ** p.beta_S) * (T ** p.delta)
    return 1.0 - math.exp(-rate)


def samples_for_coverage(C_target: float, N: float, T: float,
                         p: CoverageParams = CoverageParams()) -> float:
    """Invert F1 for S — 'how many samples to hit the coverage SLA'."""
    if not 0 < C_target < 1:
        raise ValueError("target coverage must be in (0,1)")
    rate = -math.log(1.0 - C_target)
    denom = p.alpha * (N ** p.beta_N) * (T ** p.delta)
    return (rate / denom) ** (1.0 / p.beta_S)


# =========================================================================== F2
GAMMA_E = 0.9


QUANT_FACTORS = {"fp32": 1.35, "fp16": 1.0, "bf16": 1.0, "fp8": 0.65,
                 "int8": 0.65, "int4": 0.45}


def quant_factor(q: str) -> float:
    try:
        return QUANT_FACTORS[q.lower()]
    except KeyError:
        raise ValueError(
            f"unknown quantization format {q!r} "
            f"(supported: {', '.join(sorted(QUANT_FACTORS))})") from None


def energy_total(S: float, N: float, T: float, q: str,
                 device: DeviceProfile, e0_coeff: float = 2.8e-10) -> float:
    """Formalism 2.1: E = E0(N) * f(Q) * P_i * gamma_util * lambda_i * T * S.

    E0(N) = c1 * N^gamma_E with N in millions of parameters; e0_coeff is
    calibrated so GPT-2 (N=125) standard execution at S=20, T=256 on the edge
    GPU profile lands at the paper's 43.1 kJ (Table 16).
    """
    e0 = e0_coeff * (N ** GAMMA_E)
    return (e0 * quant_factor(q) * device.power_peak * device.util *
            device.lambda_eff * T * S)


# =========================================================================== F3
@dataclass(frozen=True)
class LatencyBreakdown:
    prefill_s: float
    decode_s: float
    io_s: float
    overhead_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.io_s + self.overhead_s

    def as_dict(self) -> Dict[str, float]:
        return {"prefill_s": self.prefill_s, "decode_s": self.decode_s,
                "io_s": self.io_s, "overhead_s": self.overhead_s,
                "total_s": self.total_s}


B0_REFERENCE_BW = 30e9  # paper's CPU-class reference bandwidth (30 GB/s)


def latency(S: float, T: float, N: float, device: DeviceProfile,
            io_bytes: float = 0.0, io_bw: Optional[float] = None,
            heterogeneous: bool = False,
            overhead_const_s: float = 2e-4,
            overhead_log_coeff: float = 5e-5) -> LatencyBreakdown:
    """Formalism 3.1. N in parameters (not millions) here: FLOPs/token ~ 2N.

    prefill: compute-bound at device frequency-scaled peak;
    decode: memory-bound, scaled by bandwidth advantage B_i/B_0;
    io: explicit transfer bytes / interconnect bandwidth;
    overhead: const + a*log(S), heterogeneous orchestration only.
    """
    flops_per_token = 2.0 * N
    t_prefill = T * flops_per_token / (device.peak_flops * device.util)
    bw_ratio = device.mem_bw / B0_REFERENCE_BW
    t_decode = ((S - 1) * T * flops_per_token /
                (device.peak_flops * device.util * bw_ratio)) if S > 1 else 0.0
    t_io = io_bytes / (io_bw or device.link_bw) if io_bytes else 0.0
    t_over = overhead_const_s + (overhead_log_coeff * math.log(max(S, 1))
                                 if heterogeneous else 0.0)
    return LatencyBreakdown(t_prefill, t_decode, t_io, t_over)


# =========================================================================== F4
def cost_total(S: float, energy_joules: float, device: DeviceProfile,
               price_kwh: float = 0.15) -> Dict[str, float]:
    """Formalism 4.1: amortization + energy + maintenance (per-workload USD)."""
    amort = device.hw_cost_usd / device.lifetime_ops * S
    energy_cost = energy_joules / 3.6e6 * price_kwh
    maint = device.maint_per_op * S
    return {"amortization": amort, "energy": energy_cost,
            "maintenance": maint,
            "total": amort + energy_cost + maint}


# =========================================================================== F5
def device_task_match(intensity: float, device: DeviceProfile) -> str:
    """Formalism 5.1: memory-bound iff I < C/B (Eq. 7)."""
    return "memory-bound" if intensity < device.ridge_point else "compute-bound"


def best_device_for_intensity(intensity: float, devices) -> DeviceProfile:
    """Pick the device whose ridge point best matches the task intensity:
    memory-bound tasks -> highest bandwidth-per-watt; compute-bound ->
    highest FLOPs-per-watt. This is F5 turned into a routing rule."""
    mem_bound = [d for d in devices if intensity < d.ridge_point]
    if mem_bound:
        return max(mem_bound, key=lambda d: d.mem_bw / d.power_peak)
    return max(devices, key=lambda d: d.peak_flops / d.power_peak)
