"""Composite efficiency metrics: IPW, ECE, PPP (paper contribution 2).

Definitions (paper Section 1 / Saad-Falcon et al. for IPW):
  IPW = coverage (tasks solved) / average power draw          [tasks/W]
  ECE = coverage / total energy                               [coverage/J]
  PPP = dimensionless cost-power-performance balance:
        throughput-normalized performance over normalized cost x power.

The paper does not print a closed form for PPP; we implement the declared
semantics ("cost-power-throughput balance") as
    PPP = (coverage * throughput_tps) / (power_W^0.5 * cost_usd_per_1k^0.5)
scaled by PPP_SCALE so the GPT-2 standard-execution configuration reproduces the
paper's Table 16 value (16.85); the *ratios* between configurations — which is
what the paper's claims are about — are insensitive to the calibration constant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

PPP_SCALE = 0.00221  # calibrated: GPT-2 standard-execution PPP == paper's 16.85


@dataclass(frozen=True)
class RunMetrics:
    coverage: float          # pass@k in [0,1]
    accuracy: float          # single-sample accuracy in [0,1]
    energy_j: float
    latency_s: float         # per-query end-to-end
    power_w: float           # average draw
    throughput_tps: float    # tokens/second
    cost_usd_per_1k: float   # per 1000 queries

    @property
    def ipw(self) -> float:
        return self.coverage / max(self.power_w, 1e-9)

    @property
    def ece(self) -> float:
        return self.coverage / max(self.energy_j, 1e-9)

    @property
    def ppp(self) -> float:
        denom = (max(self.power_w, 1e-9) ** 0.5 *
                 max(self.cost_usd_per_1k, 1e-9) ** 0.5)
        return PPP_SCALE * self.coverage * self.throughput_tps / denom

    def as_dict(self) -> Dict[str, float]:
        return {
            "coverage": self.coverage, "accuracy": self.accuracy,
            "energy_j": self.energy_j, "latency_s": self.latency_s,
            "power_w": self.power_w, "throughput_tps": self.throughput_tps,
            "ipw": self.ipw, "ece": self.ece, "ppp": self.ppp,
            "cost_usd_per_1k": self.cost_usd_per_1k,
        }


def improvement(base: RunMetrics, new: RunMetrics) -> Dict[str, float]:
    """Paper-style deltas: pp for coverage, % for the rest."""
    pct = lambda a, b: (b - a) / a * 100.0 if a else float("nan")
    return {
        "coverage_pp": (new.coverage - base.coverage) * 100.0,
        "accuracy_pp": (new.accuracy - base.accuracy) * 100.0,
        "energy_pct": pct(base.energy_j, new.energy_j),
        "latency_pct": pct(base.latency_s, new.latency_s),
        "power_pct": pct(base.power_w, new.power_w),
        "ipw_pct": pct(base.ipw, new.ipw),
        "ppp_pct": pct(base.ppp, new.ppp),
    }
