"""Roofline machinery (paper Formalism 5 + deliverable (g)).

Two consumers:
  1. The orchestrator: per-stage device-task matching via arithmetic intensity
     ``I`` vs. ridge point ``C/B`` (Eq. 7).
  2. The dry-run analysis: the three roofline terms per (arch x mesh) derived
     from compiled HLO (FLOPs / bytes from ``cost_analysis()``, collective bytes
     from the HLO text), against TPU v5e constants.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.devices import DeviceProfile, TPU_V5E


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float        # HLO_FLOPs / (chips * peak)
    memory_s: float         # HLO_bytes / (chips * HBM bw)
    collective_s: float     # collective_bytes / (chips * link bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def overlap_time_s(self) -> float:
        """Lower bound assuming perfect compute/memory/collective overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_time_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant}


def arithmetic_intensity(flops: float, bytes_moved: float) -> float:
    return flops / max(bytes_moved, 1.0)


def is_memory_bound(flops: float, bytes_moved: float,
                    device: DeviceProfile) -> bool:
    """Eq. 7: task memory-bound iff I < C/B."""
    return arithmetic_intensity(flops, bytes_moved) < device.ridge_point


def stage_time(flops: float, bytes_moved: float,
               device: DeviceProfile) -> float:
    """Roofline execution time on one device: max of compute & memory terms."""
    return max(flops / (device.peak_flops * device.util),
               bytes_moved / (device.mem_bw * device.util))


def terms_from_counts(flops: float, bytes_moved: float,
                      collective_bytes: float, n_chips: int,
                      device: DeviceProfile = TPU_V5E) -> RooflineTerms:
    """The deliverable-(g) three-term roofline.

    ``flops``/``bytes_moved`` are whole-program HLO totals (all chips), so each
    is divided by aggregate fleet capability; ``collective_bytes`` is the total
    over all collective ops, moved at per-chip link bandwidth.
    """
    return RooflineTerms(
        compute_s=flops / (n_chips * device.peak_flops),
        memory_s=bytes_moved / (n_chips * device.mem_bw),
        collective_s=collective_bytes / (n_chips * device.link_bw),
    )


# --------------------------------------------------------------------------- HLO
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
    re.MULTILINE)

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s32|u32|s64|u64|"
                       r"s16|u16|s8|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in an HLO dump.

    Returns per-kind byte totals plus "total". Shapes are the *op result*
    shapes — the data each collective materializes, the standard proxy for
    wire bytes (exact wire traffic additionally depends on the algorithm, e.g.
    ring all-reduce moves 2(n-1)/n of the payload; we report payload bytes and
    keep the convention fixed across experiments so ratios are meaningful).
    """
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    count = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["n_ops"] = count
    return out
