"""Pareto-front utilities for multi-objective orchestration (v2 title).

All objectives are minimized; negate maximization objectives before calling.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a dominates b: <= in every objective, < in at least one."""
    le = all(x <= y for x, y in zip(a, b))
    lt = any(x < y for x, y in zip(a, b))
    return le and lt


def pareto_front(points: Sequence[Sequence[float]]) -> List[int]:
    """Indices of the non-dominated points (O(n^2), fine for config sweeps)."""
    n = len(points)
    out = []
    for i in range(n):
        if not any(dominates(points[j], points[i])
                   for j in range(n) if j != i):
            out.append(i)
    return out


def hypervolume_2d(points: Sequence[Tuple[float, float]],
                   ref: Tuple[float, float]) -> float:
    """2-D hypervolume (minimization) w.r.t. reference point — the scalar
    'did the frontier move' metric used in EXPERIMENTS.md §Perf."""
    front = sorted({tuple(points[i]) for i in pareto_front(points)
                    if points[i][0] < ref[0] and points[i][1] < ref[1]})
    hv = 0.0
    for i, (x, y) in enumerate(front):
        next_x = front[i + 1][0] if i + 1 < len(front) else ref[0]
        hv += (next_x - x) * (ref[1] - y)
    return hv
