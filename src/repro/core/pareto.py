"""Pareto-front utilities for multi-objective orchestration (v2 title).

All objectives are minimized; negate maximization objectives before calling.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a dominates b: <= in every objective, < in at least one.

    Single-pass with early exit — this sits on the annealer's per-candidate
    archive path, so generator-pair elegance costs real wall-clock.
    """
    lt = False
    for x, y in zip(a, b):
        if x > y:
            return False
        if x < y:
            lt = True
    return lt


def pareto_front(points: Sequence[Sequence[float]]) -> List[int]:
    """Indices of the non-dominated points (O(n^2), fine for config sweeps)."""
    n = len(points)
    out = []
    for i in range(n):
        if not any(dominates(points[j], points[i])
                   for j in range(n) if j != i):
            out.append(i)
    return out


def hypervolume_2d(points: Sequence[Tuple[float, float]],
                   ref: Tuple[float, float]) -> float:
    """2-D hypervolume (minimization) w.r.t. reference point — the scalar
    'did the frontier move' metric used in EXPERIMENTS.md §Perf.

    The 2-D non-dominated subset falls out of one sort + sweep (ascending x,
    keep strictly-improving y) in O(n log n) — PGSAM calls this on every
    convergence check, where the generic O(n^2) `pareto_front` dominated the
    anneal's profile.
    """
    pts = sorted({(x, y) for x, y in points if x < ref[0] and y < ref[1]})
    front = []
    best_y = float("inf")
    for x, y in pts:
        if y < best_y:
            front.append((x, y))
            best_y = y
    hv = 0.0
    for i, (x, y) in enumerate(front):
        next_x = front[i + 1][0] if i + 1 < len(front) else ref[0]
        hv += (next_x - x) * (ref[1] - y)
    return hv
