"""Device capability model (paper Eq. 10) and registry.

Two families of profiles:

* **Edge profiles** — the paper's experimental platform (Section 3.7 / Eq. 12):
  Intel Core Ultra 9 285HX CPU, Intel AI Boost NPU, NVIDIA RTX PRO 5000 GPU,
  Intel Graphics GPU. Used by the paper-reproduction benchmarks.
* **TPU profile** — v5e, the real deployment target of this framework; its
  constants also feed the roofline analysis of the dry-run artifacts
  (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

The capability vector follows Eq. 10:
    d_i = (M_max, B, f, P, n_cores, lambda, type, T_max, priority)
extended with idle power, thermal RC constants, and economics (Eq. 5-6 inputs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    kind: str                    # cpu | gpu | npu | tpu
    vendor: str
    peak_flops: float            # FLOP/s (fp16/bf16 unless noted)
    mem_bw: float                # bytes/s
    mem_cap: float               # bytes
    link_bw: float               # bytes/s per interconnect link
    power_peak: float            # W
    power_idle: float            # W
    lambda_eff: float            # paper's device efficiency multiplier
    util: float                  # gamma_util in (0, 1]
    freq_ghz: float
    n_cores: int
    t_max: float                 # max junction temperature, degC
    t_ambient: float = 25.0
    thermal_r: float = 0.25      # degC per W (RC model)
    thermal_tau: float = 30.0    # seconds
    priority: int = 0
    hw_cost_usd: float = 1000.0
    lifetime_ops: float = 1e8    # queries over device lifetime (Eq. 6)
    maint_per_op: float = 1e-6

    @property
    def ridge_point(self) -> float:
        """FLOP/byte where the device transitions memory- to compute-bound."""
        return self.peak_flops / self.mem_bw

    def energy_efficiency(self) -> float:
        """Paper Eq. 11: FLOPs per joule."""
        return self.peak_flops / self.power_peak

    def with_overrides(self, **kw) -> "DeviceProfile":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------- edge (paper)
# Constants from the paper: Eq. 12 memory/bandwidth/power caps, Section 3.3.2
# lambda multipliers (CPU 1.0, GPU 0.3-0.5, NPU 0.1-0.2), gamma_util 0.6-0.9.

EDGE_CPU = DeviceProfile(
    name="intel-core-ultra9-285hx", kind="cpu", vendor="intel",
    peak_flops=1.4e12, mem_bw=100e9, mem_cap=127e9, link_bw=32e9,
    power_peak=45.0, power_idle=8.0, lambda_eff=1.0, util=0.8,
    freq_ghz=2.8, n_cores=8, t_max=105.0, thermal_r=1.2, thermal_tau=25.0,
    priority=2, hw_cost_usd=600.0)

EDGE_NPU = DeviceProfile(
    name="intel-ai-boost-npu", kind="npu", vendor="intel",
    peak_flops=13e12, mem_bw=50e9, mem_cap=20e9, link_bw=32e9,
    power_peak=25.0, power_idle=2.0, lambda_eff=0.15, util=0.85,
    freq_ghz=1.4, n_cores=2, t_max=100.0, thermal_r=1.5, thermal_tau=20.0,
    priority=1, hw_cost_usd=250.0)

EDGE_GPU_NVIDIA = DeviceProfile(
    name="nvidia-rtx-pro-5000", kind="gpu", vendor="nvidia",
    peak_flops=120e12, mem_bw=900e9, mem_cap=96.2e9, link_bw=32e9,
    power_peak=300.0, power_idle=25.0, lambda_eff=0.4, util=0.75,
    freq_ghz=2.2, n_cores=12800, t_max=90.0, thermal_r=0.21, thermal_tau=45.0,
    priority=0, hw_cost_usd=4500.0)

EDGE_GPU_INTEL = DeviceProfile(
    name="intel-graphics-gpu", kind="gpu", vendor="intel",
    peak_flops=18e12, mem_bw=90e9, mem_cap=72.7e9, link_bw=32e9,
    power_peak=120.0, power_idle=12.0, lambda_eff=0.45, util=0.7,
    freq_ghz=1.8, n_cores=1024, t_max=95.0, thermal_r=0.5, thermal_tau=35.0,
    priority=3, hw_cost_usd=0.0)   # integrated: amortized with CPU

EDGE_PLATFORM: List[DeviceProfile] = [
    EDGE_CPU, EDGE_NPU, EDGE_GPU_NVIDIA, EDGE_GPU_INTEL]

# --------------------------------------------------------------------- cloud ref
CLOUD_GPU = DeviceProfile(
    name="datacenter-h100-like", kind="gpu", vendor="nvidia",
    peak_flops=900e12, mem_bw=3.0e12, mem_cap=80e9, link_bw=450e9,
    power_peak=700.0, power_idle=80.0, lambda_eff=0.35, util=0.8,
    freq_ghz=1.8, n_cores=16896, t_max=90.0, thermal_r=0.05, thermal_tau=60.0,
    priority=0, hw_cost_usd=30000.0)

# --------------------------------------------------------------------- TPU target
TPU_V5E = DeviceProfile(
    name="tpu-v5e", kind="tpu", vendor="google",
    peak_flops=197e12, mem_bw=819e9, mem_cap=16e9, link_bw=50e9,
    power_peak=170.0, power_idle=35.0, lambda_eff=0.25, util=0.8,
    freq_ghz=1.7, n_cores=1, t_max=95.0, thermal_r=0.1, thermal_tau=50.0,
    priority=0, hw_cost_usd=5000.0)

REGISTRY: Dict[str, DeviceProfile] = {
    d.name: d for d in EDGE_PLATFORM + [CLOUD_GPU, TPU_V5E]}


def get_device(name: str) -> DeviceProfile:
    return REGISTRY[name]
