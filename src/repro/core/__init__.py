"""QEIL core: the paper's contribution as composable modules.

formalisms  — the five inference-time scaling formalisms (closed forms)
fitting     — scaling-exponent estimation with bootstrap CIs (Tables 1-2)
metrics     — IPW / ECE / PPP composite efficiency metrics
devices     — capability vectors (paper's edge platform + TPU v5e target)
decomposition — energy-aware task decomposition (stage FLOPs/bytes)
roofline    — Formalism 5 + the dry-run three-term roofline analysis
energy      — roofline-derived energy model ("v2")
orchestrator — greedy layer assignment (Eq. 12) + exhaustive oracle + Pareto
pareto      — non-dominated set utilities
safety      — thermal / fault-tolerance / adversarial robustness (Section 3.4)
sampling    — repeated sampling + quality-verification cascade
"""
from repro.core.devices import (DeviceProfile, EDGE_CPU, EDGE_GPU_INTEL,
                                EDGE_GPU_NVIDIA, EDGE_NPU, EDGE_PLATFORM,
                                CLOUD_GPU, TPU_V5E, get_device)
from repro.core.decomposition import Stage, Workload, decompose, phase_totals
from repro.core.formalisms import (CoverageParams, coverage, cost_total,
                                   device_task_match, energy_total, latency,
                                   quant_factor, samples_for_coverage)
from repro.core.fitting import (JointFit, PowerLawFit, empirical_coverage,
                                fit_coverage_joint, fit_power_law)
from repro.core.metrics import RunMetrics, improvement
from repro.core.roofline import (RooflineTerms, arithmetic_intensity,
                                 collective_bytes_from_hlo, is_memory_bound,
                                 stage_time, terms_from_counts)
from repro.core.energy import (PlanCosts, StageExecution, execute_stage,
                               homogeneous_assignment, plan_costs)
from repro.core.orchestrator import (Assignment, Constraints,
                                     GreedyOrchestrator, ParetoOrchestrator,
                                     exhaustive_oracle)
from repro.core.pareto import dominates, hypervolume_2d, pareto_front
from repro.core.safety import (DriftEvent, FaultEvent, Health, HealthMonitor,
                               InputValidator, OutputSanitizer, SafetyMonitor,
                               ThermalModel, THETA_THROTTLE)
from repro.core.sampling import (CascadeStats, PassAtKResult, VerifierCascade,
                                 adaptive_sample_budget, run_pass_at_k,
                                 simulate_outcomes)
