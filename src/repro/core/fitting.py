"""Scaling-exponent estimation (paper Section 4.1, Tables 1-2).

The coverage law C(S) = 1 - exp(-alpha * S^beta) linearizes exactly:
    log(-log(1 - C)) = log(alpha) + beta * log(S)
so the primary fit is ordinary least squares in transformed space; the joint
(N, S) fit adds a beta_N column. Confidence intervals come from bootstrap
resampling (1000 iterations, as in the paper's Table 1), resampling either
observed coverage points or per-problem Bernoulli outcomes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclass
class PowerLawFit:
    alpha: float
    beta: float
    r2: float
    beta_ci: Tuple[float, float]
    n_points: int

    def predict(self, S: np.ndarray) -> np.ndarray:
        return 1.0 - np.exp(-self.alpha * np.asarray(S, float) ** self.beta)


def _transform(S: np.ndarray, C: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    C = np.clip(np.asarray(C, float), 1e-6, 1 - 1e-6)
    return np.log(np.asarray(S, float)), np.log(-np.log(1.0 - C))


def fit_power_law(S: Sequence[float], C: Sequence[float],
                  n_bootstrap: int = 1000, seed: int = 0) -> PowerLawFit:
    """Fit C(S) = 1 - exp(-alpha S^beta) with bootstrap CI on beta."""
    S = np.asarray(S, float)
    C = np.asarray(C, float)
    x, y = _transform(S, C)
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    log_alpha, beta = coef

    yhat = A @ coef
    ss_res = float(np.sum((y - yhat) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0

    rng = np.random.default_rng(seed)
    betas = []
    n = len(S)
    for _ in range(n_bootstrap):
        idx = rng.integers(0, n, n)
        if len(np.unique(x[idx])) < 2:
            continue
        Ab = np.stack([np.ones(n), x[idx]], axis=1)
        cb, *_ = np.linalg.lstsq(Ab, y[idx], rcond=None)
        betas.append(cb[1])
    lo, hi = (np.percentile(betas, [2.5, 97.5]) if betas
              else (beta, beta))
    return PowerLawFit(alpha=float(np.exp(log_alpha)), beta=float(beta),
                       r2=float(r2), beta_ci=(float(lo), float(hi)),
                       n_points=n)


@dataclass
class JointFit:
    alpha: float
    beta_N: float
    beta_S: float
    r2: float

    def predict(self, N: np.ndarray, S: np.ndarray) -> np.ndarray:
        rate = self.alpha * np.asarray(N, float) ** self.beta_N * \
            np.asarray(S, float) ** self.beta_S
        return 1.0 - np.exp(-rate)


def fit_coverage_joint(N: Sequence[float], S: Sequence[float],
                       C: Sequence[float]) -> JointFit:
    """Joint fit over (model size, sample budget) grids — Formalism 1.1's
    separate beta_N / beta_S characterization."""
    N = np.asarray(N, float)
    S = np.asarray(S, float)
    C = np.clip(np.asarray(C, float), 1e-6, 1 - 1e-6)
    y = np.log(-np.log(1.0 - C))
    A = np.stack([np.ones_like(y), np.log(N), np.log(S)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    yhat = A @ coef
    ss_res = float(np.sum((y - yhat) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    return JointFit(alpha=float(np.exp(coef[0])), beta_N=float(coef[1]),
                    beta_S=float(coef[2]),
                    r2=1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0)


def empirical_coverage(outcomes: np.ndarray,
                       sample_budgets: Sequence[int],
                       n_bootstrap: int = 0, seed: int = 0
                       ) -> Dict[int, float]:
    """pass@k estimator over a (problems x max_samples) boolean outcome matrix.

    Uses the unbiased pass@k estimator: 1 - C(n-c, k)/C(n, k) averaged over
    problems (Chen et al. 2021), matching how the paper measures coverage.
    """
    outcomes = np.asarray(outcomes, bool)
    n_prob, n_max = outcomes.shape
    c = outcomes.sum(axis=1)               # successes per problem
    out = {}
    for k in sample_budgets:
        k = min(k, n_max)
        # pass@k = 1 - prod_{i=0..k-1} (n - c - i) / (n - i)
        vals = np.ones(n_prob)
        for i in range(k):
            vals *= np.clip((n_max - c - i), 0, None) / (n_max - i)
        out[k] = float(np.mean(1.0 - vals))
    return out
