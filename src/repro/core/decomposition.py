"""Energy-aware task decomposition (paper Section 3.5, Eq. 9).

Inference = Embedding + Decoder Layers + LM Head, with each decoder layer further
split into its prefill (compute-bound) and decode (memory-bound) phases. Each
stage carries analytic FLOP and byte counts derived from the ArchConfig, so the
orchestrator can compute arithmetic intensity, roofline time, and energy per
candidate device — this is the "granular operations with distinct hardware
sensitivity" decomposition the paper inherits from Asgar et al.

Byte-accounting conventions:
* prefill — weights stream once per pass; activations 3x d_model per token.
* decode — weights re-stream every autoregressive step (the memory-bound
  regime, paper Formalism 3's B_i/B_0 term), plus per-token KV/state reads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.models.config import ArchConfig


@dataclass(frozen=True)
class Workload:
    batch: int = 1
    prompt_tokens: int = 128      # T_in per sample
    decode_tokens: int = 128      # T_out per sample
    samples: int = 1              # S (repeated sampling)
    bytes_per_param: float = 2.0  # quantization: 2=bf16, 1=fp8/int8, 0.5=int4
    bytes_per_act: float = 2.0
    bytes_per_kv: Optional[float] = None  # KV-cache element bytes (int8 KV=1);
                                          # None -> bytes_per_act
    # Speculative decode (repro.spec): one verify forward scores
    # ``spec_queries_per_step`` tokens (1 + draft depth) and commits
    # ``spec_tokens_per_step`` in expectation — so weights re-stream once per
    # ``spec_tokens_per_step`` committed tokens while per-query compute and
    # activation/KV traffic scale with the scored queries. 1.0/1.0 = off.
    spec_tokens_per_step: float = 1.0
    spec_queries_per_step: float = 1.0

    @property
    def kv_bytes_per_el(self) -> float:
        return self.bytes_per_act if self.bytes_per_kv is None \
            else self.bytes_per_kv

    @property
    def spec_query_factor(self) -> float:
        """Scored query tokens per committed decode token (>= 1 when
        drafting; the compute-side price speculation pays for fewer weight
        re-streams)."""
        return self.spec_queries_per_step / max(self.spec_tokens_per_step,
                                                1e-9)

    @property
    def quant_factor(self) -> float:
        """Paper's f(Q): FP16 -> 1.0, FP8/INT8 -> 0.65, INT4 -> 0.45."""
        if self.bytes_per_param >= 2.0:
            return 1.0
        return 0.65 if self.bytes_per_param >= 1.0 else 0.45

    @property
    def n_prefill_tokens(self) -> int:
        return self.batch * self.samples * self.prompt_tokens

    @property
    def n_decode_tokens(self) -> int:
        return self.batch * self.samples * self.decode_tokens


@dataclass
class Stage:
    name: str                 # e.g. "layer12.attn.decode"
    phase: str                # embed | prefill | decode | head
    layer: int                # -1 for embed, n_layers for head
    flops: float
    bytes_moved: float
    param_bytes: float        # resident weights for this stage
    width: int = 0            # boundary tensor width (d_model elements/token)

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


# ------------------------------------------------------------------ per-token
# Each helper returns (flops_per_token, act_bytes_per_token, param_bytes).

def _attn_counts(cfg: ArchConfig, w: Workload, decode: bool
                 ) -> Tuple[float, float, float]:
    d, H, hd, kv = cfg.d_model, cfg.n_heads, cfg.hd, cfg.n_kv_heads
    bpa, bpp = w.bytes_per_act, w.bytes_per_param
    # average attended context length
    ctx = (w.prompt_tokens + w.decode_tokens / 2) if decode \
        else w.prompt_tokens / 2
    if cfg.attn_window:
        ctx = min(ctx, cfg.attn_window)

    if cfg.mla is not None:
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = 2 * d * H * qd + 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
        out = 2 * H * m.v_head_dim * d
        pbytes = (d * H * qd + d * (m.kv_lora_rank + m.qk_rope_head_dim) +
                  m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim) +
                  H * m.v_head_dim * d) * bpp
        if decode:   # absorbed: scores + context in latent space
            absorb = 2 * H * m.qk_nope_head_dim * m.kv_lora_rank * 2
            attn = 2 * H * ctx * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
            flops = proj + absorb + attn + out
            cache = ctx * (m.kv_lora_rank + m.qk_rope_head_dim) * w.kv_bytes_per_el
        else:        # decompressed (MXU-friendly)
            dec = 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            attn = 2 * H * ctx * (qd + m.v_head_dim)
            flops = proj + dec + attn + out
            cache = 0.0
    else:
        proj = 2 * d * hd * (H + 2 * kv) + 2 * H * hd * d
        attn = 2 * H * ctx * hd * 2
        flops = proj + attn
        cache = (ctx * 2 * kv * hd * w.kv_bytes_per_el) if decode else 0.0
        pbytes = (d * hd * (H + 2 * kv) + H * hd * d) * bpp

    if cfg.cross_attention:
        flops += 4 * d * H * hd + 2 * H * cfg.n_cond_tokens * hd * 2
        pbytes += 4 * d * H * hd * bpp

    return flops, 3 * d * bpa + cache, pbytes


def _ffn_counts(cfg: ArchConfig, w: Workload, layer_idx: int
                ) -> Tuple[float, float, float, float]:
    """Returns (flops/token, act bytes/token, active param bytes, total param bytes)."""
    d = cfg.d_model
    bpa, bpp = w.bytes_per_act, w.bytes_per_param
    if cfg.is_moe_layer(layer_idx):
        m = cfg.moe
        ff = cfg.expert_ff()
        active = m.top_k + m.n_shared
        flops = 2 * 3 * d * ff * active + 2 * d * m.n_experts
        p_active = (3 * d * ff * active + d * m.n_experts) * bpp
        p_total = (3 * d * ff * (m.n_experts + m.n_shared) +
                   d * m.n_experts) * bpp
    elif cfg.d_ff > 0:
        mult = 3 if cfg.mlp_variant == "swiglu" else 2
        flops = 2 * mult * d * cfg.d_ff
        p_active = p_total = mult * d * cfg.d_ff * bpp
    else:
        return 0.0, 0.0, 0.0, 0.0
    return flops, 3 * d * bpa, p_active, p_total


def _ssm_counts(cfg: ArchConfig, w: Workload, decode: bool
                ) -> Tuple[float, float, float]:
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H, P, N, G = cfg.ssm_heads, s.headdim, s.d_state, s.n_groups
    bpa, bpp = w.bytes_per_act, w.bytes_per_param
    proj = 2 * d * (2 * di + 2 * G * N + H) + 2 * di * d
    conv = 2 * s.d_conv * (di + 2 * G * N)
    if decode:
        ssd = 2 * H * P * N * 3                 # state update + readout
        state = H * P * N * 4 * 2               # f32 state read+write
    else:
        Q = s.chunk
        ssd = 2 * H * (Q * (N + P) + 2 * P * N)  # amortized chunked SSD
        state = 0.0
    pbytes = (d * (2 * di + 2 * G * N + H) + di * d +
              s.d_conv * (di + 2 * G * N)) * bpp
    return proj + conv + ssd, 3 * d * bpa + state, pbytes


# ------------------------------------------------------------------ assembly

def decompose(cfg: ArchConfig, w: Workload) -> List[Stage]:
    """Full stage list for a workload: embed + per-layer x phase + head."""
    stages: List[Stage] = []
    bpa, bpp = w.bytes_per_act, w.bytes_per_param
    d, V = cfg.d_model, cfg.vocab_size
    n_pre, n_dec = w.n_prefill_tokens, w.n_decode_tokens
    # Speculative verify scores spec_query_factor tokens per committed token
    # (embed/head/per-layer compute and activation bytes scale with scored
    # queries), while weights re-stream only once per verify step — the
    # roofline trade `repro.spec.routing.spec_workload` prices.
    qf = w.spec_query_factor
    n_dec_scored = n_dec * qf
    n_all = n_pre + n_dec_scored
    decode_steps = w.decode_tokens / max(w.spec_tokens_per_step, 1e-9)

    embed_pbytes = V * d * cfg.n_codebooks * bpp
    stages.append(Stage("embed", "embed", -1,
                        flops=2.0 * d * n_all,
                        bytes_moved=n_all * d * bpa + n_all * d * bpp,
                        param_bytes=embed_pbytes, width=d))

    period = len(cfg.pattern)
    for layer in range(cfg.n_layers):
        mixer = cfg.pattern[layer % period]
        kind = "attn" if mixer == "a" else "ssm"
        for phase in ("prefill", "decode"):
            decode = phase == "decode"
            n_tok = n_dec_scored if decode else n_pre
            if n_tok == 0:
                continue
            if mixer == "a":
                f1, a1, p1 = _attn_counts(cfg, w, decode)
            else:
                f1, a1, p1 = _ssm_counts(cfg, w, decode)
            f2, a2, p2_active, p2_total = _ffn_counts(cfg, w, layer)
            flops = (f1 + f2) * n_tok
            if decode:
                weight_bytes = (p1 + p2_active) * decode_steps
            else:
                weight_bytes = p1 + p2_active
            bytes_moved = weight_bytes + n_tok * (a1 + a2)
            stages.append(Stage(f"layer{layer:02d}.{kind}+ffn.{phase}",
                                phase, layer, flops, bytes_moved,
                                p1 + p2_total, width=d))

    head_pbytes = V * d * cfg.n_codebooks * bpp
    stages.append(Stage("lm_head", "head", cfg.n_layers,
                        flops=2.0 * d * V * cfg.n_codebooks * n_all,
                        bytes_moved=head_pbytes + n_all * (d + V) * bpa,
                        param_bytes=head_pbytes, width=d))
    return stages


def phase_totals(stages: List[Stage]) -> dict:
    """Aggregate flops/bytes by phase — feeds the energy breakdown (Table 7)."""
    out = {}
    for st in stages:
        acc = out.setdefault(st.phase, {"flops": 0.0, "bytes": 0.0})
        acc["flops"] += st.flops
        acc["bytes"] += st.bytes_moved
    return out
