"""Roofline-derived energy model — the "v2" contribution in the assignment title.

The paper's v1 energy law (Formalism 2) is a calibrated power law. v2 replaces the
calibration with a *mechanistic* model: every stage's execution time is its roofline
time on the assigned device (max of compute and memory terms, from the analytic
FLOP/byte counts of ``repro.core.decomposition`` — or from compiled-HLO counts in
the dry-run pipeline), and energy integrates power over that time:

    t_stage  = max(FLOPs / (C_i * util), bytes / (B_i * util))
    E_stage  = t_stage * (P_idle + util * (P_peak - P_idle)) * f(Q)
    E_total  = sum over stages + idle energy of unassigned devices + transfer energy

This is what lets the orchestrator *derive* the Pareto frontier instead of
assuming the paper's measured constants — and on the TPU path, the same model
consumes ``compiled.cost_analysis()`` numbers directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.decomposition import Stage, Workload
from repro.core.devices import DeviceProfile
from repro.core.formalisms import quant_factor

TRANSFER_ENERGY_PER_BYTE = 60e-12  # J/B over PCIe-class links (~60 pJ/bit*8)


@dataclass
class StageExecution:
    stage: Stage
    device: DeviceProfile
    time_s: float
    energy_j: float
    bound: str                    # compute | memory


def execute_stage(stage: Stage, device: DeviceProfile,
                  quant: str = "bf16",
                  throttle: float = 1.0) -> StageExecution:
    """Roofline time + integrated energy for one stage on one device.

    ``throttle`` in (0,1] scales effective throughput (thermal protection:
    paper Principle 6.1 reduces workload intensity, stretching time but
    lowering power draw proportionally).
    """
    eff = device.util * throttle
    t_c = stage.flops / (device.peak_flops * eff)
    t_m = stage.bytes_moved / (device.mem_bw * eff)
    t = max(t_c, t_m)
    # Dynamic power scales with the paper's architectural efficiency
    # multiplier lambda_i (Formalism 2: NPUs spend far fewer pJ per op than
    # GPUs at the same utilization) and with how busy the compute units are:
    # memory-bound stages leave the MXU/SMs idling (busy_frac < 1).
    busy_frac = (t_c / t if t > 0 else 0.0)
    p_dyn = (device.power_peak - device.power_idle) * device.util * \
        device.lambda_eff * (0.55 + 0.45 * busy_frac) * throttle
    # marginal-energy accounting: the idle floor is paid by the platform
    # whether or not this stage runs; stage energy is the dynamic part.
    energy = t * p_dyn * quant_factor(quant)
    return StageExecution(stage, device, t, energy,
                          "compute" if t_c >= t_m else "memory")


@dataclass
class PlanCosts:
    executions: List[StageExecution]
    transfer_bytes: float
    transfer_time_s: float
    transfer_energy_j: float
    devices: Sequence[DeviceProfile]

    @property
    def energy_j(self) -> float:
        return (sum(e.energy_j for e in self.executions) +
                self.transfer_energy_j)

    @property
    def busy_time_s(self) -> float:
        return sum(e.time_s for e in self.executions) + self.transfer_time_s

    def per_device_time(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.executions:
            out[e.device.name] = out.get(e.device.name, 0.0) + e.time_s
        return out

    def per_device_energy(self) -> Dict[str, float]:
        """Stage (dynamic) energy summed per device, transfer excluded —
        divides by makespan to give the per-device average power draw the
        runtime control loop feeds the RC thermal models."""
        out: Dict[str, float] = {}
        for e in self.executions:
            out[e.device.name] = out.get(e.device.name, 0.0) + e.energy_j
        return out

    @property
    def makespan_s(self) -> float:
        """Pipeline view: devices work concurrently; the busiest device plus
        transfer time bounds the steady-state latency."""
        per_dev = self.per_device_time()
        return (max(per_dev.values()) if per_dev else 0.0) + self.transfer_time_s

    @property
    def avg_power_w(self) -> float:
        t = max(self.makespan_s, 1e-12)
        return self.energy_j / max(self.busy_time_s, t)

    def phase_energy(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.executions:
            out[e.stage.phase] = out.get(e.stage.phase, 0.0) + e.energy_j
        out["transfer"] = self.transfer_energy_j
        return out


def boundary_transfer_bytes(execs: List[StageExecution],
                            workload: Optional[Workload] = None) -> float:
    """Bytes crossing a link: activations (n_tokens x d_model) transfer
    whenever consecutive stages of the same phase sit on different devices.
    Shared by the v1 and v2 cost models so their transfer accounting can
    never drift apart. Decode-phase tokens are *scored queries*: under
    speculative decode every committed token rides a verify forward of
    ``spec_query_factor`` query tokens across the boundary (1.0 when not
    drafting — bit-identical to the pre-speculation accounting)."""
    transfer_bytes = 0.0
    by_phase: Dict[str, List[StageExecution]] = {}
    for e in execs:
        by_phase.setdefault(e.stage.phase, []).append(e)
    for phase, seq in by_phase.items():
        seq = sorted(seq, key=lambda e: e.stage.layer)
        for a, b in zip(seq, seq[1:]):
            if a.device.name != b.device.name:
                if workload is not None:
                    n_tok = (workload.n_decode_tokens *
                             workload.spec_query_factor
                             if phase == "decode"
                             else workload.n_prefill_tokens)
                    transfer_bytes += (n_tok * workload.bytes_per_act *
                                       max(a.stage.width, 1))
                else:
                    transfer_bytes += a.stage.bytes_moved * 0.01
    return transfer_bytes


def plan_costs(stages: List[Stage], assignment: Dict[str, DeviceProfile],
               quant: str = "bf16", workload: Optional[Workload] = None,
               throttle: Optional[Dict[str, float]] = None,
               model: str = "v1",
               temps: Optional[Dict[str, float]] = None,
               headroom: float = 0.9,
               provider=None) -> PlanCosts:
    """Cost a full stage->device assignment, including cross-device activation
    transfers whenever consecutive layers live on different devices.

    ``model="v2"`` dispatches to the DASI/CPQ/Phi physics-grounded energy
    equation (`repro.qeil2.energy_v2`); the default keeps the v1 path
    bit-for-bit reproducible. ``temps`` (device -> junction degC) and
    ``headroom`` (allocator fraction that counts as CPQ=1) only affect the v2
    path, which models temperature-dependent leakage and capacity pressure.
    ``provider`` (an optional `repro.qeil2.telemetry.CalibratedSignalProvider`)
    substitutes fitted coefficients and measured kernel times into the v2
    signals; it has no meaning for v1 and is rejected there.
    """
    if model == "v2":
        from repro.qeil2.energy_v2 import plan_costs_v2
        return plan_costs_v2(stages, assignment, quant, workload,
                             throttle=throttle, temps=temps,
                             headroom=headroom, provider=provider)
    if model != "v1":
        raise ValueError(f"unknown energy model {model!r} (want 'v1' or 'v2')")
    if provider is not None:
        raise ValueError("provider= is a v2 calibration hook; "
                         "pass model='v2' to use it")
    throttle = throttle or {}
    execs = []
    for st in stages:
        dev = assignment[st.name]
        execs.append(execute_stage(st, dev, quant,
                                   throttle.get(dev.name, 1.0)))

    transfer_bytes = boundary_transfer_bytes(execs, workload)
    link_bw = min(d.link_bw for d in assignment.values())
    t_io = transfer_bytes / link_bw if transfer_bytes else 0.0
    e_io = transfer_bytes * TRANSFER_ENERGY_PER_BYTE
    return PlanCosts(execs, transfer_bytes, t_io, e_io,
                     devices=list({d.name: d
                                   for d in assignment.values()}.values()))


def homogeneous_assignment(stages: List[Stage],
                           device: DeviceProfile) -> Dict[str, DeviceProfile]:
    return {st.name: device for st in stages}
