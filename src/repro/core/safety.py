"""Safety-first reliability framework (paper Section 3.4, Principles 6.1-6.3).

Hardware adaptation note (DESIGN.md §2): the paper reads temperatures from
nvidia-smi / MSR / ACPI; with no physical sensors here, device temperature
follows a first-order RC thermal model driven by the modeled power draw:

    dT/dt = (P * R_th - (T - T_ambient)) / tau_th

which reproduces the qualitative behavior the paper exploits (sustained load
heats toward T_amb + P*R_th; backing off cools exponentially). All safety logic
— the theta=0.85 proactive throttle, health states, failure detection/recovery,
input validation and output sanity checking — follows the paper exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.devices import DeviceProfile

THETA_THROTTLE = 0.85       # Principle 6.1
RECOVERY_BUDGET_S = 0.100   # Principle 6.2: redistribute within 100 ms
REINTRODUCE_CAPACITY = 0.5  # recovered devices restart at 50%


class Health(Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    FAILED = "failed"


# =========================================================== thermal (P. 6.1)

@dataclass
class ThermalState:
    temp_c: float
    throttle: float = 1.0     # workload multiplier in (0, 1]
    events: int = 0           # hardware-throttle events (what we must avoid)


class ThermalModel:
    """First-order RC model + the paper's proactive throttling rule."""

    def __init__(self, device: DeviceProfile):
        self.device = device
        self.state = ThermalState(temp_c=device.t_ambient)

    def step(self, power_w: float, dt_s: float) -> ThermalState:
        d = self.device
        t_inf = d.t_ambient + power_w * d.thermal_r
        decay = math.exp(-dt_s / d.thermal_tau)
        self.state.temp_c = t_inf + (self.state.temp_c - t_inf) * decay
        limit = THETA_THROTTLE * d.t_max
        if self.state.temp_c > d.t_max:
            # hardware throttling would fire here — this is the failure mode
            self.state.events += 1
        if self.state.temp_c > limit:
            # Eq. 8 proactive reduction: linear between theta*Tmax and Tmax
            frac = (self.state.temp_c - limit) / (d.t_max - limit)
            self.state.throttle = max(0.05, 1.0 - frac)
        else:
            self.state.throttle = 1.0
        return self.state


# ==================================================== drift events (runtime)

@dataclass(frozen=True)
class DriftEvent:
    """A signal-drift notification: the world the current plan was annealed
    for no longer matches reality. Consumed by `repro.qeil2.runtime`'s
    control loop (re-anneal) and by `PGSAMOrchestrator.on_drift` (frontier
    cache invalidation); emitted by `SafetyMonitor`.

    kinds:
      * ``thermal_margin``   — junction temp crossed theta*T_max (rising
        edge): Phi has decayed below the proactive-throttle yield.
      * ``device_failed``    — health monitor marked the device FAILED.
      * ``device_recovered`` — device reintroduced at reduced capacity.
      * ``cpq_saturation``   — resident working set approaching the
        allocator headroom (emitted by the control loop, not the monitor).
      * ``kv_squeeze``       — KV blocks withheld from serving admission
        (value = block count; 0 releases). Emitted by the fault-injection
        harness (`repro.serving.chaos`), consumed by the scheduler.
      * ``slow_kernel``      — service-time inflation factor (value >= 1;
        1 restores nominal). Same emitter/consumer as ``kv_squeeze``.
    """
    t_s: float
    device: str
    kind: str
    value: float = 0.0          # temp degC / capacity fraction, kind-specific
    detail: str = ""


# ====================================================== fault tolerance (6.2)

@dataclass
class FaultEvent:
    t_s: float
    device: str
    kind: str                  # fail | recover


@dataclass
class RecoveryRecord:
    device: str
    detected_at_s: float
    redistributed_at_s: float
    queries_lost: int
    throughput_factor: float   # remaining / original capacity

    @property
    def recovery_ms(self) -> float:
        return (self.redistributed_at_s - self.detected_at_s) * 1e3


class HealthMonitor:
    """Tracks health state per device from timeouts / error rates / heartbeats
    (Principle 6.2's three detectors) and drives recovery."""

    def __init__(self, devices: Sequence[DeviceProfile],
                 timeout_factor: float = 10.0,
                 error_rate_limit: float = 0.01,
                 window: int = 100):
        self.devices = {d.name: d for d in devices}
        self.health: Dict[str, Health] = {d.name: Health.HEALTHY
                                          for d in devices}
        self.capacity: Dict[str, float] = {d.name: 1.0 for d in devices}
        self.timeout_factor = timeout_factor
        self.error_rate_limit = error_rate_limit
        self._errors: Dict[str, List[bool]] = {d.name: [] for d in devices}
        self.window = window
        self.records: List[RecoveryRecord] = []
        # optional (device, kind) callback — SafetyMonitor wires this to its
        # drift-event bus so orchestrators learn about failures/recoveries.
        self.on_event: Optional[Callable[[str, str], None]] = None

    def healthy_devices(self) -> List[str]:
        return [n for n, h in self.health.items() if h != Health.FAILED]

    # --- detectors
    def observe_latency(self, device: str, observed_s: float,
                        expected_s: float) -> bool:
        if observed_s > self.timeout_factor * expected_s:
            self._fail(device, 0.0)
            return True
        return False

    def observe_kernel(self, device: str, ok: bool) -> bool:
        errs = self._errors[device]
        errs.append(not ok)
        if len(errs) > self.window:
            errs.pop(0)
        if len(errs) >= 10 and np.mean(errs) > self.error_rate_limit:
            self.health[device] = Health.DEGRADED
            return True
        return False

    def heartbeat_missed(self, device: str, now_s: float) -> None:
        self._fail(device, now_s)

    # --- recovery protocol
    def _fail(self, device: str, now_s: float) -> None:
        if self.health[device] == Health.FAILED:
            return
        self.health[device] = Health.FAILED
        self.capacity[device] = 0.0
        if self.on_event is not None:
            self.on_event(device, "device_failed")

    def fail_device(self, device: str, now_s: float,
                    inflight_queries: int = 0,
                    redistribution_latency_s: float = 0.05) -> RecoveryRecord:
        """Inject a failure; redistribution is bounded by the 100 ms budget
        and in-flight queries requeue onto healthy devices (zero loss)."""
        self._fail(device, now_s)
        redis_at = now_s + min(redistribution_latency_s, RECOVERY_BUDGET_S)
        healthy = self.healthy_devices()
        total = sum(self.devices[n].peak_flops for n in self.devices)
        remaining = sum(self.devices[n].peak_flops for n in healthy)
        rec = RecoveryRecord(device=device, detected_at_s=now_s,
                             redistributed_at_s=redis_at,
                             queries_lost=0 if healthy else inflight_queries,
                             throughput_factor=remaining / total if total else 0)
        self.records.append(rec)
        return rec

    def recover_device(self, device: str) -> None:
        """Driver reset + memory clear, reintroduce at 50% capacity."""
        self.health[device] = Health.DEGRADED
        self.capacity[device] = REINTRODUCE_CAPACITY
        if self.on_event is not None:
            self.on_event(device, "device_recovered")

    def promote_if_stable(self, device: str, clean_inferences: int) -> None:
        if clean_inferences >= self.window and \
                self.health[device] == Health.DEGRADED:
            self.health[device] = Health.HEALTHY
            self.capacity[device] = 1.0

    def degraded_latency_bound(self, optimal_s: float) -> float:
        """Formal guarantee: tau_degraded <= tau_optimal * D / D_healthy."""
        d_total = len(self.devices)
        d_healthy = len(self.healthy_devices())
        if d_healthy == 0:
            return float("inf")
        return optimal_s * d_total / d_healthy


# ============================================== adversarial robustness (6.3)

@dataclass
class ValidationResult:
    ok: bool
    reason: str = ""


class InputValidator:
    """Defense-in-depth input validation (Principle 6.3)."""

    def __init__(self, max_seq_len: int, vocab_size: int,
                 max_requests_per_s: float = 100.0):
        self.max_seq_len = max_seq_len
        self.vocab_size = vocab_size
        self.max_rps = max_requests_per_s
        self._bucket = max_requests_per_s   # token bucket for rate limiting
        self._last_t = 0.0

    def validate(self, tokens: np.ndarray, now_s: float = 0.0
                 ) -> ValidationResult:
        # rate limiting
        self._bucket = min(self.max_rps,
                           self._bucket + (now_s - self._last_t) * self.max_rps)
        self._last_t = now_s
        if self._bucket < 1.0:
            return ValidationResult(False, "rate-limited")
        self._bucket -= 1.0
        # structural checks
        if tokens.ndim != 1 or tokens.size == 0:
            return ValidationResult(False, "malformed input")
        if tokens.size > self.max_seq_len:
            return ValidationResult(
                False, f"oversized input {tokens.size} > {self.max_seq_len}")
        if tokens.min() < 0 or tokens.max() >= self.vocab_size:
            return ValidationResult(False, "token ids out of range "
                                           "(malformed encoding)")
        return ValidationResult(True)


class OutputSanitizer:
    """Output sanity checking: length cap, repetition halt, logit anomalies."""

    def __init__(self, expected_len: int, repetition_window: int = 100,
                 repetition_limit: float = 0.9):
        self.max_len = 2 * expected_len
        self.rep_window = repetition_window
        self.rep_limit = repetition_limit

    def check(self, tokens: np.ndarray,
              logit_entropy: Optional[float] = None) -> ValidationResult:
        if tokens.size > self.max_len:
            return ValidationResult(False, "generation length cap")
        w = tokens[-self.rep_window:]
        if w.size >= 20:
            _, counts = np.unique(w, return_counts=True)
            if counts.max() / w.size > self.rep_limit:
                return ValidationResult(False, "repetition halt")
        if logit_entropy is not None and logit_entropy < 1e-3:
            return ValidationResult(False, "confidence anomaly")
        return ValidationResult(True)


# =================================================== unified safety monitor

class SafetyMonitor:
    """The component with override authority over the optimizer (Section 3.2).

    Wires thermal models, health monitoring and validation together; the
    orchestrator consults `throttle_factors()` before costing assignments and
    must re-assign when `on_failure` fires.
    """

    def __init__(self, devices: Sequence[DeviceProfile],
                 max_seq_len: int = 32768, vocab_size: int = 2 ** 17):
        self.devices = list(devices)
        self.thermal = {d.name: ThermalModel(d) for d in devices}
        self.health = HealthMonitor(devices)
        self.validator = InputValidator(max_seq_len, vocab_size)
        self.resource_time_factor = 5.0     # tau_max = 5x expected
        self.resource_mem_factor = 1.5      # M_max = 1.5x expected
        # --- drift-event bus: subscribers get every DriftEvent ---
        self._subscribers: List[Callable[[DriftEvent], None]] = []
        self._above_margin: Dict[str, bool] = {d.name: False for d in devices}
        self.clock_s = 0.0                  # advanced by thermal_step
        self.health.on_event = lambda dev, kind: self.emit(
            DriftEvent(self.clock_s, dev, kind))

    def subscribe(self, fn: Callable[[DriftEvent], None]) -> None:
        """Register a drift-event consumer (e.g. the runtime control loop or
        `PGSAMOrchestrator.on_drift`)."""
        self._subscribers.append(fn)

    def emit(self, event: DriftEvent) -> None:
        for fn in self._subscribers:
            fn(event)

    def thermal_step(self, powers: Dict[str, float], dt_s: float
                     ) -> Dict[str, float]:
        """Advance every RC thermal model; emits a ``thermal_margin``
        DriftEvent on the rising edge of T crossing theta*T_max (the same
        threshold that arms the proactive throttle — equivalently, Phi
        dropping below its proactive-yield floor)."""
        self.clock_s += dt_s
        out = {}
        for name, tm in self.thermal.items():
            st = tm.step(powers.get(name, 0.0), dt_s)
            out[name] = st.throttle
            above = st.temp_c > THETA_THROTTLE * tm.device.t_max
            if above and not self._above_margin[name]:
                self.emit(DriftEvent(self.clock_s, name, "thermal_margin",
                                     value=st.temp_c,
                                     detail=f"T {st.temp_c:.1f} degC > "
                                            f"{THETA_THROTTLE:.2f} * "
                                            f"{tm.device.t_max:.0f}"))
            self._above_margin[name] = above
        return out

    def throttle_factors(self) -> Dict[str, float]:
        return {n: tm.state.throttle for n, tm in self.thermal.items()}

    def total_throttle_events(self) -> int:
        return sum(tm.state.events for tm in self.thermal.values())

    def resource_bounds(self, expected_latency_s: float,
                        expected_mem: float) -> Tuple[float, float]:
        return (self.resource_time_factor * expected_latency_s,
                self.resource_mem_factor * expected_mem)
