"""Safety-first agentic orchestration (paper Sections 3.2, 3.7; Eq. 12).

Implements:
  * ``GreedyOrchestrator`` — the paper's algorithm: rank devices by energy
    efficiency (Eq. 11), pin embedding/LM-head to the most efficient fitting
    device, distribute decoder layers greedily minimizing per-stage energy
    under memory / thermal constraints, then validate latency & coverage SLAs.
    O(L*D), re-runnable on safety events (the paper's justification for greedy).
  * ``exhaustive_oracle`` — brute-force optimal assignment for small cases,
    used to validate the paper's "greedy within 5% of ILP" claim (Section 3.7).
  * ``ParetoOrchestrator`` — beyond-paper: sweeps the energy/latency trade-off
    via epsilon-constraint scalarization and returns the non-dominated frontier
    (the "Pareto-optimal multi-objective orchestration" of the v2 title).

The Safety monitor (repro.core.safety) holds override authority: assignments are
checked against thermal predictions before being returned, and `reassign_on_failure`
redistributes stages away from failed devices.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.decomposition import Stage, Workload, decompose
from repro.core.devices import DeviceProfile
from repro.core.energy import PlanCosts, execute_stage, plan_costs
from repro.core.formalisms import CoverageParams, coverage
from repro.models.config import ArchConfig


@dataclass
class Constraints:
    latency_sla_s: Optional[float] = None
    # when no explicit SLA: per-device busy budget = factor x best homogeneous
    # makespan (1.0 = "never slower than the best single device"); None = pure
    # energy minimization with no latency constraint.
    latency_budget_factor: Optional[float] = 1.0
    coverage_min: Optional[float] = None
    thermal_margin: float = 0.85          # theta_throttle (Principle 6.1)
    memory_headroom: float = 0.9          # use <=90% of device memory


@dataclass
class Assignment:
    mapping: Dict[str, DeviceProfile]
    costs: Optional[PlanCosts]    # None iff no feasible placement exists
    feasible: bool
    violations: List[str] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def energy_j(self) -> float:
        return self.costs.energy_j if self.costs is not None else float("inf")

    @property
    def latency_s(self) -> float:
        return self.costs.makespan_s if self.costs is not None else float("inf")

    def device_names(self) -> List[str]:
        return sorted({d.name for d in self.mapping.values()})


def _memory_ok(dev: DeviceProfile, used: Dict[str, float], extra: float,
               headroom: float) -> bool:
    return used.get(dev.name, 0.0) + extra <= dev.mem_cap * headroom


def latency_budget(constraints: Constraints, stages: Sequence[Stage],
                   devices: Sequence[DeviceProfile],
                   quant: str = "bf16") -> float:
    """Per-device busy-time budget: the SLA if given, else
    latency_budget_factor x the best homogeneous device's makespan
    (factor None -> unconstrained energy minimization). Shared by every
    orchestrator so 'drop-in' engines agree on what the budget means."""
    if constraints.latency_sla_s is not None:
        return constraints.latency_sla_s
    if constraints.latency_budget_factor is None:
        return float("inf")
    best = min(sum(execute_stage(st, dev, quant).time_s for st in stages)
               for dev in devices)
    return constraints.latency_budget_factor * best


def constraint_violations(constraints: Constraints, makespan_s: float,
                          cfg: ArchConfig, workload: Workload) -> List[str]:
    """SLA / coverage checks every orchestrator applies to a finished plan
    (GreedyOrchestrator step 3; PGSAMOrchestrator post-anneal)."""
    violations: List[str] = []
    if constraints.latency_sla_s is not None and \
            makespan_s > constraints.latency_sla_s:
        violations.append(
            f"latency {makespan_s * 1e3:.2f} ms > SLA "
            f"{constraints.latency_sla_s * 1e3:.2f} ms")
    if constraints.coverage_min is not None:
        cov = coverage(workload.samples, N=cfg_param_millions(cfg),
                       T=workload.decode_tokens)
        if cov < constraints.coverage_min:
            violations.append(
                f"coverage {cov:.3f} < {constraints.coverage_min}")
    return violations


class GreedyOrchestrator:
    """Paper-faithful greedy layer assignment."""

    def __init__(self, devices: Sequence[DeviceProfile],
                 constraints: Constraints = Constraints(),
                 quant: str = "bf16"):
        if not devices:
            raise ValueError("need at least one device")
        self.devices = list(devices)
        self.constraints = constraints
        self.quant = quant

    # -- step 1: preprocessing — rank devices by energy efficiency (Eq. 11)
    def ranked_devices(self) -> List[DeviceProfile]:
        return sorted(self.devices,
                      key=lambda d: d.energy_efficiency(), reverse=True)

    def _latency_budget(self, stages: List[Stage]) -> float:
        return latency_budget(self.constraints, stages, self.devices,
                              self.quant)

    def assign(self, cfg: ArchConfig, workload: Workload,
               healthy: Optional[Sequence[str]] = None) -> Assignment:
        stages = decompose(cfg, workload)
        devices = [d for d in self.devices
                   if healthy is None or d.name in healthy]
        if not devices:
            raise RuntimeError("no healthy devices")
        ranked = sorted(devices, key=lambda d: d.energy_efficiency(),
                        reverse=True)
        used_mem: Dict[str, float] = {}
        mapping: Dict[str, DeviceProfile] = {}
        notes: List[str] = []

        all_budget = self._latency_budget(stages)
        busy: Dict[str, float] = {}

        # -- step 2a: embedding + LM head to the most efficient fitting
        # device whose accumulated busy time stays within the latency budget
        # (the LM-head matmul over all tokens is NOT free — pinning it to the
        # NPU unbudgeted was a measured -11% latency regression).
        for st in stages:
            if st.phase in ("embed", "head"):
                placed = False
                for dev in ranked:
                    if not _memory_ok(dev, used_mem, st.param_bytes,
                                      self.constraints.memory_headroom):
                        continue
                    ex = execute_stage(st, dev, self.quant)
                    if busy.get(dev.name, 0.0) + ex.time_s <= all_budget:
                        mapping[st.name] = dev
                        used_mem[dev.name] = used_mem.get(dev.name, 0.0) + \
                            st.param_bytes
                        busy[dev.name] = busy.get(dev.name, 0.0) + ex.time_s
                        placed = True
                        break
                if not placed:  # fallback: minimize resulting busy time
                    cands = [(busy.get(d.name, 0.0) +
                              execute_stage(st, d, self.quant).time_s, d)
                             for d in ranked
                             if _memory_ok(d, used_mem, st.param_bytes,
                                           self.constraints.memory_headroom)]
                    if not cands:
                        return Assignment({}, None, False,
                                          [f"{st.name}: no device fits"])
                    t_new, dev = min(cands, key=lambda c: c[0])
                    mapping[st.name] = dev
                    used_mem[dev.name] = used_mem.get(dev.name, 0.0) + \
                        st.param_bytes
                    busy[dev.name] = t_new

        # -- step 2b: decoder layers greedily, minimizing per-stage energy
        # subject to the latency budget. Devices execute concurrently
        # (pipelined batches), so the plan's latency is the busiest device's
        # time; the greedy keeps every device's accumulated busy time within
        # the budget while picking the cheapest-energy device per stage. This
        # is what yields the paper's simultaneous energy AND latency win over
        # the best homogeneous device: memory-bound decode spreads across the
        # aggregate bandwidth of all devices, weighted toward efficient ones.
        # A layer's prefill and decode stages may land on different devices
        # (prefill/decode disaggregation) — weights are then mirrored.
        layer_stages = [st for st in stages if st.phase in ("prefill", "decode")]
        budget = all_budget
        # hardest (most time-consuming) stages first: classic LPT bin packing
        order = sorted(layer_stages,
                       key=lambda s: -execute_stage(s, ranked[0], self.quant).time_s)
        for st in order:
            best: Tuple[float, Optional[DeviceProfile], float] = \
                (float("inf"), None, 0.0)
            fallback: Tuple[float, Optional[DeviceProfile], float] = \
                (float("inf"), None, 0.0)
            for dev in ranked:
                if not _memory_ok(dev, used_mem, st.param_bytes,
                                  self.constraints.memory_headroom):
                    continue
                ex = execute_stage(st, dev, self.quant)
                new_busy = busy.get(dev.name, 0.0) + ex.time_s
                if new_busy <= budget and ex.energy_j < best[0]:
                    best = (ex.energy_j, dev, ex.time_s)
                if new_busy < fallback[0]:
                    fallback = (new_busy, dev, ex.time_s)
            pick = best if best[1] is not None else fallback
            if pick[1] is None:
                return Assignment({}, None, False,
                                  [f"{st.name}: no device fits "
                                   f"({st.param_bytes/1e9:.1f} GB)"])
            dev = pick[1]
            mapping[st.name] = dev
            busy[dev.name] = busy.get(dev.name, 0.0) + pick[2]
            used_mem[dev.name] = used_mem.get(dev.name, 0.0) + st.param_bytes

        self._segmentize(mapping, layer_stages)
        costs = plan_costs(stages, mapping, self.quant, workload)

        # -- step 3: constraint checking
        violations = constraint_violations(self.constraints, costs.makespan_s,
                                           cfg, workload)
        return Assignment(mapping, costs, not violations, violations, notes)

    @staticmethod
    def _segmentize(mapping: Dict[str, DeviceProfile],
                    layer_stages: List[Stage]) -> None:
        """Reorder per-layer device assignments into contiguous segments.

        Within a (phase, stage-kind) group every layer stage has identical
        cost, so permuting which layer sits on which device preserves energy
        and per-device busy time while minimizing cross-device activation
        boundaries (each boundary costs n_tokens * d_model transfer bytes).
        """
        groups: Dict[Tuple[str, str], List[Stage]] = {}
        for st in layer_stages:
            kind = st.name.split(".")[1] if "." in st.name else ""
            groups.setdefault((st.phase, kind), []).append(st)
        for group in groups.values():
            group.sort(key=lambda s: s.layer)
            devs = [mapping[s.name] for s in group]
            order: List[DeviceProfile] = []
            counts: Dict[str, int] = {}
            for d in devs:
                if d.name not in counts:
                    order.append(d)
                    counts[d.name] = 0
                counts[d.name] += 1
            it = iter(group)
            for d in order:
                for _ in range(counts[d.name]):
                    mapping[next(it).name] = d

    # -- safety integration: redistribute away from failed devices
    def reassign_on_failure(self, cfg: ArchConfig, workload: Workload,
                            failed: Sequence[str]) -> Assignment:
        healthy = [d.name for d in self.devices if d.name not in failed]
        return self.assign(cfg, workload, healthy=healthy)

    # -- drift-event hook (`repro.core.safety.DriftEvent`): part of the
    # orchestrator engine contract so `SafetyMonitor.subscribe(orch.on_drift)`
    # works with any engine. Greedy keeps no cross-assign state, so there is
    # nothing to invalidate; PGSAMOrchestrator overrides this to bump its
    # frontier-cache epoch.
    def on_drift(self, event) -> None:
        return None


def cfg_param_millions(cfg: ArchConfig) -> float:
    from repro.models.model import Model
    return Model(cfg).param_count() / 1e6


# --------------------------------------------------------------------- oracle

def exhaustive_oracle(cfg: ArchConfig, workload: Workload,
                      devices: Sequence[DeviceProfile],
                      quant: str = "bf16",
                      max_stages: int = 12) -> Assignment:
    """Brute-force optimal assignment (small cases only): validates the
    paper's claim that greedy lands within ~5% of the ILP optimum."""
    stages = decompose(cfg, workload)
    if len(stages) > max_stages:
        raise ValueError(f"{len(stages)} stages > {max_stages}: "
                         "oracle is exponential, reduce the model")
    best: Tuple[float, Optional[Dict]] = (float("inf"), None)
    for combo in itertools.product(devices, repeat=len(stages)):
        used: Dict[str, float] = {}
        ok = True
        for st, dev in zip(stages, combo):
            used[dev.name] = used.get(dev.name, 0.0) + st.param_bytes
            if used[dev.name] > dev.mem_cap * 0.9:
                ok = False
                break
        if not ok:
            continue
        mapping = {st.name: dev for st, dev in zip(stages, combo)}
        costs = plan_costs(stages, mapping, quant, workload)
        if costs.energy_j < best[0]:
            best = (costs.energy_j, mapping)
    if best[1] is None:
        return Assignment({}, None, False, ["no feasible assignment"])
    mapping = best[1]
    return Assignment(mapping, plan_costs(stages, mapping, quant, workload),
                      True)


# --------------------------------------------------------------------- Pareto

# epsilon-constraint schedule shared by every frontier sweep (Pareto sweep,
# PGSAM seeding, benchmarks): factors of a base latency used as SLAs.
SLA_SWEEP_FACTORS: Tuple[float, ...] = tuple(0.6 + 0.15 * k for k in range(8))


def greedy_sla_sweep(devices: Sequence[DeviceProfile], cfg: ArchConfig,
                     workload: Workload, base_latency_s: float,
                     quant: str = "bf16",
                     factors: Sequence[float] = SLA_SWEEP_FACTORS,
                     engine: Optional[type] = None,
                     memory_headroom: float = 0.9) -> List[Assignment]:
    """One assignment per SLA = factor * base_latency_s (the epsilon-constraint
    trick that traces an energy/latency frontier out of a single-objective
    orchestrator). Infeasible points are returned as-is; filter on
    ``a.mapping and a.feasible``."""
    engine = engine or GreedyOrchestrator
    return [engine(devices,
                   Constraints(latency_sla_s=f * base_latency_s,
                               memory_headroom=memory_headroom),
                   quant).assign(cfg, workload)
            for f in factors]


class ParetoOrchestrator:
    """Beyond-paper: epsilon-constraint sweep over latency budgets produces
    the energy/latency/coverage Pareto frontier; pick by scalarized preference
    or hand the frontier to the caller (examples/pareto_orchestration.py).

    ``engine`` is any orchestrator class with the GreedyOrchestrator
    constructor/assign API — pass `repro.qeil2.PGSAMOrchestrator` to drive the
    sweep with the v2 annealer instead of the single-pass greedy."""

    def __init__(self, devices: Sequence[DeviceProfile], quant: str = "bf16",
                 engine: Optional[type] = None):
        self.devices = list(devices)
        self.quant = quant
        self.engine = engine or GreedyOrchestrator

    def frontier(self, cfg: ArchConfig, workload: Workload,
                 sample_budgets: Sequence[int] = (1, 5, 10, 20),
                 n_latency_points: int = 8) -> List[Dict]:
        """Enumerate (samples, latency-budget) grid -> feasible assignments,
        return the non-dominated set over (energy, latency, -coverage)."""
        from repro.core.pareto import pareto_front
        candidates: List[Dict] = []
        for S in sample_budgets:
            w = Workload(batch=workload.batch,
                         prompt_tokens=workload.prompt_tokens,
                         decode_tokens=workload.decode_tokens, samples=S,
                         bytes_per_param=workload.bytes_per_param,
                         bytes_per_act=workload.bytes_per_act)
            base = self.engine(self.devices, Constraints(),
                               self.quant).assign(cfg, w)
            if not base.mapping:
                continue
            sweep = greedy_sla_sweep(
                self.devices, cfg, w, base.latency_s, self.quant,
                factors=tuple(0.6 + 0.15 * k
                              for k in range(n_latency_points)),
                engine=self.engine)
            for a in sweep:
                if not a.mapping or not a.feasible:
                    continue
                cov = coverage(S, cfg_param_millions(cfg),
                               w.decode_tokens)
                candidates.append({
                    "samples": S, "assignment": a,
                    "energy_j": a.energy_j, "latency_s": a.latency_s,
                    "coverage": cov,
                })
        keys = [(c["energy_j"], c["latency_s"], -c["coverage"])
                for c in candidates]
        idx = pareto_front(keys)
        return [candidates[i] for i in idx]
