"""QEIL v2 physics-grounded orchestration subsystem.

signals   — DASI / CPQ / Phi runtime device-workload signals
energy_v2 — unified energy equation modulated by the signal triple
pgsam     — Pareto-Guided Simulated Annealing with Momentum + orchestrator
runtime   — Pareto-routed serving runtime (SLA router, control loop,
            incremental delta-cost evaluation)
telemetry — trace collection + coefficient fitting + calibrated signal
            provider (measured traces close the loop back into the model)
"""
from repro.qeil2.signals import (SignalSet, cpq, cpq_power_factor, dasi,
                                 memory_saturation, phi, signals_for)
from repro.qeil2.energy_v2 import (StageExecutionV2, execute_stage_v2,
                                   plan_costs_v2, W_COMPUTE, W_MEMORY)
from repro.qeil2.pgsam import (ArchiveEntry, PGSAM, PGSAMConfig,
                               PGSAMOrchestrator, PGSAMResult)
from repro.qeil2.runtime import (BatchRoutingDecision, ControlLoop,
                                 DeltaEvaluator, LoopConfig, ParetoRouter,
                                 RoutedServingEngine, RoutingDecision,
                                 SLATier, default_tiers, merge_tiers)
from repro.qeil2.telemetry import (CalibratedSignalProvider,
                                   CalibrationFitter, CalibrationProfile,
                                   ResidualReport, TraceStore,
                                   synthetic_trace_store)
