"""Physics-grounded runtime signals (paper Section 3, the v2 headline).

QEIL v2 replaces v1's static per-device constants with three per-(device,
workload) signals, each derived from a first-principles hardware model:

* **DASI** — Device-Adaptive Saturation Index. Roofline-derived duty cycles
  for the two power-drawing subsystems: the compute units are busy for the
  fraction ``t_compute / t_roofline`` of a stage's execution and the memory
  subsystem for ``t_memory / t_roofline``. Both follow directly from the
  stage's analytic FLOP/byte counts (`repro.core.decomposition.Stage`) and the
  device's peak rates (`repro.core.devices.DeviceProfile`) — no calibration.

* **CPQ** — Capacity Pressure Quotient. Working-set bytes over the device's
  allocation headroom. DRAM row-buffer conflicts, allocator fragmentation and
  cache thrash grow superlinearly as residency approaches capacity; CPQ is the
  dimensionless pressure that the v2 energy model maps to a power penalty.

* **Phi** — thermal yield from a CMOS leakage model. Subthreshold leakage
  current grows exponentially with junction temperature (roughly doubling
  every ~15 degC on recent nodes); Phi(T) is the fraction of total power that
  does useful (dynamic) work. Temperatures come from the RC thermal state that
  `repro.core.safety.ThermalModel` already tracks, closing the loop between
  the safety monitor and the energy model.

All three are pure functions of observable state, so the orchestrator can
re-evaluate them per candidate assignment at runtime — "every static heuristic
replaced by a physics-grounded, runtime-adaptive model".
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.decomposition import Stage
from repro.core.devices import DeviceProfile

# --- coefficient provenance -------------------------------------------------
# CPQ_KAPPA / CPQ_EXP: memory-pressure power penalty = 1 + KAPPA * cpq^EXP.
#   EXP=2 models the superlinear onset of row-buffer conflicts and allocator
#   fragmentation near capacity; KAPPA=0.35 caps the fully-packed penalty at
#   +35% dynamic power, the upper end of published DRAM-thrash overheads.
CPQ_KAPPA = 0.35
CPQ_EXP = 2.0
# PHI_RHO_REF: leakage as a fraction of dynamic power at the 25 degC reference
#   (modern mobile-class silicon idles near 8% leakage share at nominal Vdd).
# PHI_T_SLOPE: e-folding temperature of subthreshold leakage, degC. Leakage
#   roughly doubles every 15 degC -> e-folds every 15/ln(2) ~ 21.6 degC.
PHI_RHO_REF = 0.08
PHI_T_SLOPE = 21.6
PHI_T_REF_C = 25.0


def dasi(stage: Stage, device: DeviceProfile,
         ridge_scale: float = 1.0) -> float:
    """Compute-side saturation: fraction of roofline time the MXU/SMs are busy.

    ``min(1, intensity / ridge_point)`` — equals 1 exactly at and above the
    ridge point (compute-bound), and decays linearly with arithmetic intensity
    below it (memory-bound stages leave compute idling).

    ``ridge_scale`` is the calibration hook (`repro.qeil2.telemetry`): the
    effective ridge point is ``ridge_point * ridge_scale``, fitted against
    measured kernel times instead of taken from the datasheet (RooflineBench's
    central observation). The default 1.0 is the analytic model, bit-for-bit.
    """
    return min(1.0, stage.intensity / (device.ridge_point * ridge_scale))


def memory_saturation(stage: Stage, device: DeviceProfile,
                      ridge_scale: float = 1.0) -> float:
    """Dual of DASI: fraction of roofline time the memory subsystem is busy."""
    if stage.intensity <= 0:
        return 1.0
    return min(1.0, device.ridge_point * ridge_scale / stage.intensity)


def cpq(working_set_bytes: float, device: DeviceProfile,
        headroom: float = 0.9) -> float:
    """Capacity Pressure Quotient: resident bytes over allocation headroom.

    0 = empty device, 1 = at the allocator's headroom limit, >1 = overcommit
    (the orchestrator treats >1 as infeasible; the energy model clamps).
    """
    cap = device.mem_cap * headroom
    if cap <= 0:
        return float("inf")
    return max(0.0, working_set_bytes / cap)


def cpq_power_factor(cpq_value: float, kappa: float = CPQ_KAPPA,
                     exp: float = CPQ_EXP) -> float:
    """Dynamic-power multiplier from memory pressure: 1 + kappa * cpq^exp.

    ``kappa``/``exp`` default to the documented first-principles constants;
    a fitted `repro.qeil2.telemetry.CalibrationProfile` substitutes measured
    values (the defaults keep the uncalibrated path bit-for-bit)."""
    return 1.0 + kappa * min(cpq_value, 1.0) ** exp


def phi(temp_c: float, rho_ref: float = PHI_RHO_REF,
        t_slope: float = PHI_T_SLOPE, t_ref_c: float = PHI_T_REF_C) -> float:
    """Thermal yield: useful (dynamic) fraction of total power at temp T.

        P_leak(T) = rho_ref * P_dyn * exp((T - T_ref) / t_slope)
        Phi(T)    = P_dyn / (P_dyn + P_leak(T))
                  = 1 / (1 + rho_ref * exp((T - T_ref) / t_slope))

    Monotonically decreasing in T, -> 1 as T -> -inf, Phi(T_ref) =
    1/(1+rho_ref) ~ 0.926 with the default leakage share.
    """
    return 1.0 / (1.0 + rho_ref * math.exp((temp_c - t_ref_c) / t_slope))


@dataclass(frozen=True)
class SignalSet:
    """The v2 signal triple for one (stage, device) under current state."""
    dasi: float           # compute duty cycle in (0, 1]
    msat: float           # memory duty cycle in (0, 1]
    cpq: float            # capacity pressure, >= 0
    phi: float            # thermal yield in (0, 1]

    def as_dict(self) -> dict:
        """Plain-float dict for structured logging / trace emission
        (`repro.qeil2.telemetry.TraceStore` step records)."""
        return {"dasi": float(self.dasi), "msat": float(self.msat),
                "cpq": float(self.cpq), "phi": float(self.phi)}


def signals_for(stage: Stage, device: DeviceProfile,
                resident_bytes: float = 0.0,
                temp_c: float | None = None,
                headroom: float = 0.9) -> SignalSet:
    """Evaluate DASI/CPQ/Phi for a stage on a device given runtime state.

    ``resident_bytes`` is the device's total resident working set under the
    candidate assignment (this stage included); ``temp_c`` defaults to the
    device's ambient when no thermal state is available.
    """
    t = device.t_ambient if temp_c is None else temp_c
    return SignalSet(dasi=dasi(stage, device),
                     msat=memory_saturation(stage, device),
                     cpq=cpq(resident_bytes, device, headroom),
                     phi=phi(t))
