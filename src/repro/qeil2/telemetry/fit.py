"""Coefficient fitting: DASI knee / CPQ curve / Phi leakage from traces.

The v2 energy equation factors per stage as

    E = t_roofline * P0 * A(s) * C(kappa, e) / Phi(rho, tau) * f(Q)
    A = W_c * min(1, I / (R * s)) + W_m * min(1, R * s / I)
    C = 1 + kappa * min(cpq, 1)^e
    Phi = 1 / (1 + rho * exp((T - T_ref) / tau))

where every non-coefficient quantity (roofline time ``t``, base power ``P0``,
intensity ``I``, datasheet ridge ``R``, capacity pressure input ``cpq``,
junction temperature ``T``, quant factor ``f(Q)``) is carried by an ``energy``
trace record. `CalibrationFitter` fits the five coefficients theta =
(ridge_scale s, cpq_kappa, cpq_exp, phi_rho_ref, phi_t_slope) by bounded
least squares on log-energy residuals — log space makes the multiplicative
model additive and the residuals scale-free across devices — with bootstrap
confidence intervals via trace resampling (the `repro.core.fitting` pattern).

Kernel duty factors ``eta_k = t_roofline / t_measured`` are fitted per kernel
from ``kernel`` records (a direct measurement; its CI comes from bootstrap
over timing reps), bounded to (0, 1]: a kernel can be slower than its
roofline, never faster.

The output is a `CalibrationProfile` (frozen, hashable — it participates in
PGSAM's frontier cache key) plus a `ResidualReport` comparing the fitted
coefficients against the documented first-principles defaults, so v2 energies
carry error bars instead of provenance comments.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.qeil2.signals import (CPQ_EXP, CPQ_KAPPA, PHI_RHO_REF,
                                 PHI_T_REF_C, PHI_T_SLOPE)
from repro.qeil2.energy_v2 import W_COMPUTE, W_MEMORY
from repro.qeil2.telemetry.trace import TraceStore

# fit bounds per coefficient: physically-motivated boxes (a ridge point is
# within 5x of the datasheet; leakage share stays below 50% of dynamic; the
# CPQ onset exponent is superlinear but not a cliff).
COEF_NAMES = ("ridge_scale", "cpq_kappa", "cpq_exp",
              "phi_rho_ref", "phi_t_slope")
COEF_DEFAULTS = (1.0, CPQ_KAPPA, CPQ_EXP, PHI_RHO_REF, PHI_T_SLOPE)
COEF_BOUNDS = ((0.2, 5.0), (0.0, 2.0), (1.0, 4.0), (0.0, 0.5), (5.0, 60.0))
ETA_BOUNDS = (1e-3, 1.0)
# full-precision formats keep the bare kernel name as the eta key so existing
# profiles/gates are unchanged; quantized records fit per-format keys
_FULL_PRECISION = ("bf16", "fp16", "fp32")


def _eta_key(record: dict) -> str:
    """Duty-factor grouping key for a kernel record: ``"<kernel>:<quant>"``
    when the record carries a quantized format (repro.quant serving paths
    have format-dependent byte mixes), else the bare kernel name."""
    kernel = str(record["kernel"])
    quant = record.get("quant")
    if quant and str(quant).lower() not in _FULL_PRECISION:
        return f"{kernel}:{quant}"
    return kernel


@dataclass(frozen=True)
class CalibrationProfile:
    """Fitted v2 coefficients + per-kernel measured duty factors.

    The identity profile reproduces the documented defaults bit-for-bit
    (`CalibratedSignalProvider` guarantees it); a fitted profile carries the
    bootstrap CI of every coefficient in ``ci`` (name -> (lo, hi))."""
    ridge_scale: float = 1.0
    cpq_kappa: float = CPQ_KAPPA
    cpq_exp: float = CPQ_EXP
    phi_rho_ref: float = PHI_RHO_REF
    phi_t_slope: float = PHI_T_SLOPE
    phi_t_ref_c: float = PHI_T_REF_C
    # (kernel name, eta) pairs — tuples keep the profile hashable
    kernel_eta: Tuple[Tuple[str, float], ...] = ()
    # speculative-decode accept rates fitted from "spec" trace records:
    # ("model|tier|policy", rate) pairs, with "*" wildcard components for
    # the policy-wide aggregates the fallback chain lands on
    accept_rates: Tuple[Tuple[str, float], ...] = ()
    ci: Tuple[Tuple[str, Tuple[float, float]], ...] = ()
    source: str = "identity"
    n_traces: int = 0

    @classmethod
    def identity(cls) -> "CalibrationProfile":
        return cls()

    @property
    def is_identity(self) -> bool:
        return (self.coefficients() == COEF_DEFAULTS and
                not self.kernel_eta and not self.accept_rates)

    def coefficients(self) -> Tuple[float, ...]:
        return (self.ridge_scale, self.cpq_kappa, self.cpq_exp,
                self.phi_rho_ref, self.phi_t_slope)

    def eta_for(self, kernel: Optional[str],
                quant: Optional[str] = None) -> float:
        """Measured duty factor for a kernel (1.0 when unmeasured/None).

        With ``quant`` the per-format key ``"<kernel>:<quant>"`` is tried
        first (quantized kernels fit distinct etas — see `_eta_key`), falling
        back to the bare kernel name, then 1.0."""
        if kernel is not None:
            keys = ([f"{kernel}:{quant}", kernel] if quant else [kernel])
            for want in keys:
                for name, eta in self.kernel_eta:
                    if name == want:
                        return eta
        return 1.0

    def accept_rate_for(self, model: Optional[str] = None,
                        tier: Optional[str] = None,
                        policy: Optional[str] = None,
                        default: Optional[float] = None) -> Optional[float]:
        """Fitted speculative accept rate for (model, tier, policy).

        Fallback chain, most to least specific: the exact
        ``model|tier|policy`` key, then the policy-wide ``*|*|policy``
        aggregate, then ``default``. A None component matches the wildcard
        slot directly."""
        if policy is not None:
            keys = [f"{model or '*'}|{tier or '*'}|{policy}"]
            if keys[0] != f"*|*|{policy}":
                keys.append(f"*|*|{policy}")
            for want in keys:
                for key, rate in self.accept_rates:
                    if key == want:
                        return rate
        return default

    def ci_for(self, name: str) -> Optional[Tuple[float, float]]:
        for n, interval in self.ci:
            if n == name:
                return interval
        return None

    # ------------------------------------------------------------- serializ.
    def to_dict(self) -> dict:
        return {
            "ridge_scale": self.ridge_scale,
            "cpq_kappa": self.cpq_kappa, "cpq_exp": self.cpq_exp,
            "phi_rho_ref": self.phi_rho_ref,
            "phi_t_slope": self.phi_t_slope, "phi_t_ref_c": self.phi_t_ref_c,
            "kernel_eta": dict(self.kernel_eta),
            "accept_rates": dict(self.accept_rates),
            "ci": {k: list(v) for k, v in self.ci},
            "source": self.source, "n_traces": self.n_traces,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationProfile":
        return cls(
            ridge_scale=float(d.get("ridge_scale", 1.0)),
            cpq_kappa=float(d.get("cpq_kappa", CPQ_KAPPA)),
            cpq_exp=float(d.get("cpq_exp", CPQ_EXP)),
            phi_rho_ref=float(d.get("phi_rho_ref", PHI_RHO_REF)),
            phi_t_slope=float(d.get("phi_t_slope", PHI_T_SLOPE)),
            phi_t_ref_c=float(d.get("phi_t_ref_c", PHI_T_REF_C)),
            kernel_eta=tuple(sorted(
                (str(k), float(np.clip(float(v), *ETA_BOUNDS)))
                for k, v in (d.get("kernel_eta") or {}).items())),
            accept_rates=tuple(sorted(
                (str(k), float(np.clip(float(v), 0.0, 1.0)))
                for k, v in (d.get("accept_rates") or {}).items())),
            ci=tuple(sorted(
                (str(k), (float(v[0]), float(v[1])))
                for k, v in (d.get("ci") or {}).items())),
            source=str(d.get("source", "identity")),
            n_traces=int(d.get("n_traces", 0)))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


@dataclass
class ResidualReport:
    """Fit quality: fitted-vs-default residuals + per-coefficient provenance.

    ``rmse_*`` are log-space energy RMSEs over the energy records (relative
    error, device-scale-free); ``coefficients`` maps every fitted name to its
    documented default, fitted value and bootstrap CI — the error bars the
    ROADMAP item asks for."""
    rmse_default: float
    rmse_fitted: float
    n_energy: int
    n_kernel: int
    n_step: int
    n_dryrun: int
    n_spec: int = 0
    coefficients: Dict[str, dict] = field(default_factory=dict)
    kernel_eta: Dict[str, dict] = field(default_factory=dict)
    accept_rates: Dict[str, float] = field(default_factory=dict)

    @property
    def improvement_pct(self) -> float:
        if self.rmse_default <= 0:
            return 0.0
        return 100.0 * (1.0 - self.rmse_fitted / self.rmse_default)

    def to_dict(self) -> dict:
        return {
            "rmse_default": self.rmse_default,
            "rmse_fitted": self.rmse_fitted,
            "improvement_pct": self.improvement_pct,
            "n_energy": self.n_energy, "n_kernel": self.n_kernel,
            "n_step": self.n_step, "n_dryrun": self.n_dryrun,
            "n_spec": self.n_spec,
            "coefficients": self.coefficients,
            "kernel_eta": self.kernel_eta,
            "accept_rates": self.accept_rates,
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path


# =========================================================== bounded LSQ core

def _project(x: np.ndarray, bounds: Sequence[Tuple[float, float]]
             ) -> np.ndarray:
    lo = np.array([b[0] for b in bounds])
    hi = np.array([b[1] for b in bounds])
    return np.clip(x, lo, hi)


def bounded_least_squares(residual_fn: Callable[[np.ndarray], np.ndarray],
                          x0: Sequence[float],
                          bounds: Sequence[Tuple[float, float]],
                          max_iter: int = 60,
                          tol: float = 1e-10) -> np.ndarray:
    """Box-constrained Levenberg-Marquardt with numeric Jacobian.

    Small-dimension (here: 5 coefficients), dense, deterministic — a
    projected-step LM is all the calibration fit needs, with no dependency
    beyond numpy. Steps that violate the box are clipped to it; the damping
    parameter adapts on accept/reject as usual.
    """
    x = _project(np.asarray(x0, float), bounds)
    r = residual_fn(x)
    cost = float(r @ r)
    lam = 1e-3
    n = len(x)
    for _ in range(max_iter):
        # central-difference Jacobian, step scaled to the box width
        J = np.empty((len(r), n))
        for j in range(n):
            h = 1e-6 * max(1.0, abs(x[j]), bounds[j][1] - bounds[j][0])
            xp, xm = x.copy(), x.copy()
            xp[j] = min(x[j] + h, bounds[j][1])
            xm[j] = max(x[j] - h, bounds[j][0])
            denom = xp[j] - xm[j]
            if denom == 0:
                J[:, j] = 0.0
                continue
            J[:, j] = (residual_fn(xp) - residual_fn(xm)) / denom
        g = J.T @ r
        if float(np.max(np.abs(g))) < tol:
            break
        H = J.T @ J
        improved = False
        for _ in range(12):                      # adapt damping until accept
            try:
                step = np.linalg.solve(H + lam * np.diag(np.diag(H) + 1e-12),
                                       -g)
            except np.linalg.LinAlgError:
                lam *= 10.0
                continue
            x_new = _project(x + step, bounds)
            r_new = residual_fn(x_new)
            cost_new = float(r_new @ r_new)
            if cost_new < cost:
                x, r, cost = x_new, r_new, cost_new
                lam = max(lam * 0.3, 1e-12)
                improved = True
                break
            lam *= 10.0
        if not improved:
            break
    return x


# ============================================================ the fitter

def _energy_matrix(records: List[dict]) -> Dict[str, np.ndarray]:
    """Column-ize the energy records once; the residual fn is then pure
    vectorized numpy (the LM calls it ~hundreds of times per bootstrap)."""
    cols = {k: np.array([float(r[k]) for r in records])
            for k in ("intensity", "ridge", "cpq", "temp_c",
                      "t_s", "p0_w", "quant_f", "energy_j")}
    cols["log_e"] = np.log(np.clip(cols["energy_j"], 1e-300, None))
    cols["log_base"] = np.log(np.clip(
        cols["t_s"] * cols["p0_w"] * cols["quant_f"], 1e-300, None))
    return cols


def predict_log_energy(theta: Sequence[float], cols: Dict[str, np.ndarray],
                       t_ref_c: float = PHI_T_REF_C) -> np.ndarray:
    """log E_pred under coefficients theta for column-ized energy records."""
    s, kappa, e, rho, tau = theta
    ridge = cols["ridge"] * s
    a = (W_COMPUTE * np.minimum(1.0, cols["intensity"] / ridge) +
         W_MEMORY * np.minimum(1.0, ridge / np.maximum(cols["intensity"],
                                                       1e-300)))
    c = 1.0 + kappa * np.minimum(cols["cpq"], 1.0) ** e
    inv_phi = 1.0 + rho * np.exp((cols["temp_c"] - t_ref_c) / tau)
    return cols["log_base"] + np.log(a * c * inv_phi)


class CalibrationFitter:
    """Fit a `CalibrationProfile` from a `TraceStore`.

    ``fit()`` returns (profile, report). Deterministic under ``seed``; the
    bootstrap resamples energy records (coefficient CIs) and kernel timing
    reps (eta CIs) ``n_bootstrap`` times, taking 2.5/97.5 percentiles."""

    def __init__(self, store: TraceStore, n_bootstrap: int = 200,
                 seed: int = 0):
        self.store = store
        self.n_bootstrap = n_bootstrap
        self.seed = seed

    # ------------------------------------------------------------ coef fit
    def _fit_theta(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        def resid(theta: np.ndarray) -> np.ndarray:
            return predict_log_energy(theta, cols) - cols["log_e"]
        return bounded_least_squares(resid, COEF_DEFAULTS, COEF_BOUNDS)

    def _fit_kernel_eta(self, records: List[dict]
                        ) -> Dict[str, Tuple[float, Tuple[float, float]]]:
        by_kernel: Dict[str, List[float]] = {}
        for r in records:
            measured = float(r["measured_us"])
            if measured <= 0:
                continue
            eta = float(r["roofline_us"]) / measured
            by_kernel.setdefault(_eta_key(r), []).append(
                float(np.clip(eta, *ETA_BOUNDS)))
        rng = np.random.default_rng(self.seed + 1)
        out = {}
        for name, etas in sorted(by_kernel.items()):
            arr = np.array(etas)
            point = float(np.clip(arr.mean(), *ETA_BOUNDS))
            if len(arr) > 1 and self.n_bootstrap > 0:
                means = [float(np.clip(
                    arr[rng.integers(0, len(arr), len(arr))].mean(),
                    *ETA_BOUNDS)) for _ in range(self.n_bootstrap)]
                lo, hi = np.percentile(means, [2.5, 97.5])
            else:
                lo = hi = point
            out[name] = (point, (float(lo), float(hi)))
        return out

    def _fit_accept_rates(self, records: List[dict]) -> Dict[str, float]:
        """Pooled speculative accept rates from "spec" records: total
        accepted / total proposed per (model, tier, policy) key, plus the
        policy-wide ``*|*|policy`` aggregate the lookup chain falls back to.
        Pooling weights each batch by its proposal count — exactly the
        maximum-likelihood estimate for per-token Bernoulli acceptance."""
        prop: Dict[str, float] = {}
        acc: Dict[str, float] = {}
        for r in records:
            p = float(r["proposed"])
            if p <= 0:
                continue
            a = float(r["accepted"])
            policy = str(r["policy"])
            exact = (f"{r.get('model', '*')}|{r.get('tier', '*')}|{policy}")
            for key in {exact, f"*|*|{policy}"}:
                prop[key] = prop.get(key, 0.0) + p
                acc[key] = acc.get(key, 0.0) + a
        return {k: float(np.clip(acc[k] / prop[k], 0.0, 1.0))
                for k in sorted(prop)}

    # ----------------------------------------------------------------- fit
    def fit(self) -> Tuple[CalibrationProfile, ResidualReport]:
        energy = self.store.records("energy")
        kernel = self.store.records("kernel")
        spec = self.store.records("spec")
        if not energy and not kernel and not spec:
            raise ValueError("trace store holds no energy, kernel or spec "
                             "records to fit against")

        theta = np.array(COEF_DEFAULTS, float)
        ci: Dict[str, Tuple[float, float]] = {}
        rmse_default = rmse_fitted = 0.0
        if energy:
            cols = _energy_matrix(energy)
            theta = self._fit_theta(cols)
            r0 = predict_log_energy(COEF_DEFAULTS, cols) - cols["log_e"]
            r1 = predict_log_energy(theta, cols) - cols["log_e"]
            rmse_default = float(np.sqrt(np.mean(r0 ** 2)))
            rmse_fitted = float(np.sqrt(np.mean(r1 ** 2)))
            # bootstrap CI: refit on resampled records
            rng = np.random.default_rng(self.seed)
            n = len(energy)
            samples: List[np.ndarray] = []
            for _ in range(self.n_bootstrap):
                idx = rng.integers(0, n, n)
                sub = {k: v[idx] for k, v in cols.items()}
                samples.append(self._fit_theta(sub))
            if samples:
                arr = np.stack(samples)
                for j, name in enumerate(COEF_NAMES):
                    lo, hi = np.percentile(arr[:, j], [2.5, 97.5])
                    ci[name] = (float(lo), float(hi))
            else:
                ci = {name: (float(theta[j]), float(theta[j]))
                      for j, name in enumerate(COEF_NAMES)}

        etas = self._fit_kernel_eta(kernel)
        for name, (_, interval) in etas.items():
            ci[f"eta:{name}"] = interval
        rates = self._fit_accept_rates(spec)

        profile = CalibrationProfile(
            ridge_scale=float(theta[0]), cpq_kappa=float(theta[1]),
            cpq_exp=float(theta[2]), phi_rho_ref=float(theta[3]),
            phi_t_slope=float(theta[4]),
            kernel_eta=tuple(sorted((k, v[0]) for k, v in etas.items())),
            accept_rates=tuple(sorted(rates.items())),
            ci=tuple(sorted(ci.items())),
            source="fit", n_traces=len(self.store))

        counts = self.store.counts()
        report = ResidualReport(
            rmse_default=rmse_default, rmse_fitted=rmse_fitted,
            n_energy=counts.get("energy", 0),
            n_kernel=counts.get("kernel", 0),
            n_step=counts.get("step", 0),
            n_dryrun=counts.get("dryrun", 0),
            n_spec=counts.get("spec", 0),
            coefficients={
                name: {"default": COEF_DEFAULTS[j],
                       "fitted": float(theta[j]),
                       "ci": list(ci.get(name, (float(theta[j]),
                                                float(theta[j]))))}
                for j, name in enumerate(COEF_NAMES)},
            kernel_eta={name: {"fitted": point, "ci": list(interval)}
                        for name, (point, interval) in etas.items()},
            accept_rates=dict(rates))
        return profile, report
