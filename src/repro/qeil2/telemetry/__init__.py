"""Telemetry & calibration subsystem: close the measurement -> model loop.

trace     — `TraceStore`: JSONL-persisted telemetry (kernel timings, energy
            observations, control-loop step records, dry-run HLO counts)
fit       — `CalibrationFitter`: bounded least squares + bootstrap CIs over
            traces -> `CalibrationProfile` + `ResidualReport`
provider  — `CalibratedSignalProvider`: the fitted profile as a drop-in
            signal source for ``plan_costs(model="v2")`` / PGSAM / the
            runtime control loop (measured kernel duty cycles included)
synthetic — seeded ground-truth trace fixture for CI and tests
"""
from repro.qeil2.telemetry.trace import TraceStore
from repro.qeil2.telemetry.fit import (CalibrationFitter, CalibrationProfile,
                                       ResidualReport, bounded_least_squares,
                                       COEF_BOUNDS, COEF_DEFAULTS, COEF_NAMES)
from repro.qeil2.telemetry.provider import (CalibratedSignalProvider,
                                            kernel_for_stage)
from repro.qeil2.telemetry.synthetic import (TRUE_COEFFS, TRUE_KERNEL_ETA,
                                             synthetic_trace_store)
