"""Runtime feedback: a calibrated drop-in for the analytic signal functions.

`CalibratedSignalProvider` wraps a `CalibrationProfile` and exposes the same
signal surface `repro.qeil2.signals` does — ``signals_for``, ``phi``,
``cpq_power_factor`` — plus a per-stage ``time_scale``. It is accepted by
``plan_costs(..., model="v2", provider=...)``, `PGSAM`/`PGSAMOrchestrator`
(``provider=``) and the `DeltaEvaluator`, so the control loop's re-anneal
path runs on measured DASI instead of analytic FLOP/byte counts.

Two calibration effects:

* **coefficients** — DASI's ridge point is scaled by the fitted
  ``ridge_scale``; CPQ's (kappa, exp) and Phi's (rho_ref, t_slope) come from
  the profile. With the identity profile every expression evaluates with the
  documented default constants — bit-identical to the uncalibrated path.
* **measured kernel duty cycles** — where a Pallas kernel backs a stage
  (flash attention for prefill attention, decode attention for decode,
  the SSD scan for SSM stages), the measured duty factor
  ``eta = t_roofline / t_measured`` replaces the analytic assumption that
  the kernel runs at the roofline: execution time stretches by ``1/eta``
  while both duty cycles shrink by ``eta`` (the subsystems are busy the
  same absolute time inside a longer window). Dynamic stage energy is
  invariant under that substitution — measurement moves *latency* (and
  therefore makespans, annealer objectives and SLA routing), while the
  energy model keeps its physical grounding.
"""
from __future__ import annotations

from typing import Optional

from repro.core.decomposition import Stage
from repro.core.devices import DeviceProfile
from repro.qeil2.signals import (SignalSet, cpq, cpq_power_factor, dasi,
                                 memory_saturation, phi)
from repro.qeil2.telemetry.fit import CalibrationProfile

# stage-name markers -> kernel names as measured by benchmarks/kernel_bench.py
KERNEL_STAGE_MAP = (
    (".attn", "prefill", "flash_attention"),
    (".attn", "decode", "decode_attention"),
    (".ssm", "prefill", "ssd_scan"),
    (".ssm", "decode", "ssd_scan"),
)


def kernel_for_stage(stage: Stage) -> Optional[str]:
    """Which measured Pallas kernel (if any) backs a decomposition stage."""
    for marker, phase, kernel in KERNEL_STAGE_MAP:
        if marker in stage.name and stage.phase == phase:
            return kernel
    return None


class CalibratedSignalProvider:
    """`signals_for`-compatible evaluator backed by a `CalibrationProfile`."""

    def __init__(self, profile: Optional[CalibrationProfile] = None):
        self.profile = profile or CalibrationProfile.identity()

    # ------------------------------------------------------------- signals
    def eta(self, stage: Stage) -> float:
        """Measured kernel duty factor for a stage (1.0 when unmeasured)."""
        return self.profile.eta_for(kernel_for_stage(stage))

    def time_scale(self, stage: Stage) -> float:
        """Execution-time stretch from measured kernel times: t_measured /
        t_roofline = 1 / eta (1.0 for unmeasured stages)."""
        return 1.0 / self.eta(stage)

    def dasi(self, stage: Stage, device: DeviceProfile) -> float:
        d = dasi(stage, device, ridge_scale=self.profile.ridge_scale)
        return min(1.0, d * self.eta(stage))

    def memory_saturation(self, stage: Stage, device: DeviceProfile) -> float:
        m = memory_saturation(stage, device,
                              ridge_scale=self.profile.ridge_scale)
        return min(1.0, m * self.eta(stage))

    def cpq_power_factor(self, cpq_value: float) -> float:
        return cpq_power_factor(cpq_value, kappa=self.profile.cpq_kappa,
                                exp=self.profile.cpq_exp)

    def phi(self, temp_c: float) -> float:
        return phi(temp_c, rho_ref=self.profile.phi_rho_ref,
                   t_slope=self.profile.phi_t_slope,
                   t_ref_c=self.profile.phi_t_ref_c)

    def signals_for(self, stage: Stage, device: DeviceProfile,
                    resident_bytes: float = 0.0,
                    temp_c: Optional[float] = None,
                    headroom: float = 0.9) -> SignalSet:
        """Calibrated counterpart of `repro.qeil2.signals.signals_for`."""
        t = device.t_ambient if temp_c is None else temp_c
        return SignalSet(dasi=self.dasi(stage, device),
                         msat=self.memory_saturation(stage, device),
                         cpq=cpq(resident_bytes, device, headroom),
                         phi=self.phi(t))

    def __repr__(self) -> str:
        p = self.profile
        return (f"CalibratedSignalProvider(source={p.source!r}, "
                f"identity={p.is_identity}, kernels={len(p.kernel_eta)})")
