"""Seeded synthetic trace fixture: a ground-truth world to calibrate against.

Real traces need hardware; CI and the tier-1 tests need a measurement source
whose *true* coefficients are known so the fit can be judged. This module
generates traces from the v2 energy equation evaluated with a ground-truth
coefficient vector deliberately different from the documented defaults (the
situation the paper's "traceable to semiconductor physics" claim glosses
over: datasheet constants are starting points, silicon disagrees), plus
multiplicative lognormal measurement noise.

The fixture is deterministic under ``seed`` — `benchmarks/calibration_report.py`
gates CI on its fitted output.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.devices import EDGE_PLATFORM
from repro.core.formalisms import quant_factor
from repro.qeil2.telemetry.fit import PHI_T_REF_C, predict_log_energy
from repro.qeil2.telemetry.trace import TraceStore

# ground truth: the silicon this synthetic platform "actually" is. Every
# entry deviates from the documented default (ridge 1.0, kappa 0.35, exp 2.0,
# rho 0.08, slope 21.6) by enough that a fit must move to explain the data.
TRUE_COEFFS = {
    "ridge_scale": 0.75,      # kernels saturate compute earlier than datasheet
    "cpq_kappa": 0.55,        # heavier thrash penalty than the published cap
    "cpq_exp": 2.6,           # sharper onset near capacity
    "phi_rho_ref": 0.13,      # leakier silicon at reference temperature
    "phi_t_slope": 17.0,      # faster leakage growth with temperature
}
TRUE_KERNEL_ETA = {
    "flash_attention": 0.82,  # measured time 1/0.82 of roofline
    "decode_attention": 0.64,
    "ssd_scan": 0.71,
    # quantized-format duty factors fit as distinct keys ("<kernel>:<quant>",
    # see fit._fit_kernel_eta): the fused dequant path trades MXU work for
    # packed-byte HBM traffic, so its eta differs per format
    "dequant_matmul:int8": 0.77,
    "dequant_matmul:int4": 0.69,
}


def synthetic_trace_store(seed: int = 0, n_energy: int = 240,
                          n_kernel_reps: int = 12,
                          noise: float = 0.03,
                          true_coeffs: Optional[Dict[str, float]] = None,
                          path: Optional[str] = None) -> TraceStore:
    """Generate a `TraceStore` of energy + kernel records from ground truth.

    Energy records sweep arithmetic intensity (log-uniform around each
    device's ridge point), capacity pressure in [0, 1.2] and junction
    temperature in [25, 95] degC over the 4-device edge platform; measured
    joules are the true-coefficient model times lognormal(0, ``noise``).
    Kernel records time each Pallas kernel ``n_kernel_reps`` times at its
    true duty factor with the same noise model.
    """
    rng = np.random.default_rng(seed)
    tc = dict(TRUE_COEFFS)
    tc.update(true_coeffs or {})
    theta = (tc["ridge_scale"], tc["cpq_kappa"], tc["cpq_exp"],
             tc["phi_rho_ref"], tc["phi_t_slope"])
    store = TraceStore(path=path)

    devices = list(EDGE_PLATFORM)
    for i in range(n_energy):
        dev = devices[int(rng.integers(len(devices)))]
        # intensity from 1/30x to 30x the ridge: spans both roofline regimes
        intensity = dev.ridge_point * float(
            np.exp(rng.uniform(np.log(1 / 30), np.log(30))))
        cpq_in = float(rng.uniform(0.0, 1.2))
        temp_c = float(rng.uniform(25.0, 95.0))
        t_s = float(np.exp(rng.uniform(np.log(1e-4), np.log(1e-1))))
        p0 = (dev.power_peak - dev.power_idle) * dev.util * dev.lambda_eff
        quant = ("fp8", "bf16", "int8", "bf16", "int4")[i % 5]
        fq = quant_factor(quant)
        cols = {
            "intensity": np.array([intensity]),
            "ridge": np.array([dev.ridge_point]),
            "cpq": np.array([cpq_in]),
            "temp_c": np.array([temp_c]),
            "log_base": np.array([np.log(t_s * p0 * fq)]),
        }
        log_e = float(predict_log_energy(theta, cols, PHI_T_REF_C)[0])
        energy_j = float(np.exp(log_e + rng.normal(0.0, noise)))
        store.ingest({
            "kind": "energy", "device": dev.name,
            "intensity": intensity, "ridge": dev.ridge_point,
            "cpq": cpq_in, "temp_c": temp_c, "t_s": t_s, "p0_w": p0,
            "quant_f": fq, "energy_j": energy_j, "quant": quant,
        })

    for name, eta in sorted(TRUE_KERNEL_ETA.items()):
        # "<kernel>:<quant>" names emit a quant-stamped record; the fitter
        # re-derives the same suffixed key from (kernel, quant)
        kernel, _, quant = name.partition(":")
        # nominal per-call shape costs (arbitrary but fixed — eta is a ratio)
        flops = {"flash_attention": 2.1e9, "decode_attention": 1.3e8,
                 "ssd_scan": 5.4e8, "dequant_matmul": 8.6e8}[kernel]
        bytes_moved = {"flash_attention": 6.3e6, "decode_attention": 8.4e6,
                       "ssd_scan": 1.2e7, "dequant_matmul": 4.1e6}[kernel]
        roofline_us = 120.0
        for rep in range(n_kernel_reps):
            measured = roofline_us / eta * float(
                np.exp(rng.normal(0.0, noise)))
            rec = {
                "kind": "kernel", "kernel": kernel, "rep": rep,
                "flops": flops, "bytes": bytes_moved,
                "measured_us": measured, "roofline_us": roofline_us,
                "device": "synthetic",
            }
            if quant:
                rec["quant"] = quant
            store.ingest(rec)
    return store
