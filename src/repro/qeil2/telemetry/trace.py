"""Trace collection: the measurement side of the calibration loop.

`TraceStore` is an append-only store of telemetry records with optional JSONL
persistence. Three record kinds close the measurement loop the ROADMAP's two
open calibration items describe:

* ``kernel`` — measured Pallas kernel timings from `benchmarks/kernel_bench.py`
  (per-rep: flops, bytes, measured µs, roofline µs). The fitter turns these
  into per-kernel duty factors ``eta = t_roofline / t_measured`` that the
  `CalibratedSignalProvider` substitutes for analytic FLOP/byte duty cycles.
* ``energy`` — per-(stage, device) energy observations carrying the minimal
  sufficient statistics of the v2 energy equation (roofline time, base power,
  arithmetic intensity, ridge point, CPQ input, junction temperature, quant
  factor, measured joules). These drive the DASI-knee / CPQ-curve / Phi-leakage
  coefficient fit.
* ``step`` — per-step execution records emitted by
  `repro.qeil2.runtime.control_loop.ControlLoop` (temps, powers, energy,
  per-stage `SignalSet.as_dict()` snapshots): runtime provenance for the
  residual report and replayable input for offline refits.
* ``dryrun`` — compiled-HLO FLOP/byte counts from `repro.launch.dryrun`'s
  ``compiled.cost_analysis()``, cross-checking the analytic decomposition
  counts the energy records are built from.
* ``serve`` — per-batch step records from the continuous-batching scheduler
  (`repro.serving.scheduler`): tier mix, queue delay, the routed operating
  point, batch energy/makespan, and per-stage `SignalSet.as_dict()`
  snapshots of the batch-workload costing — serving traces feed the same
  `CalibrationFitter` as control-loop step records.
* ``span`` — request-lifecycle spans from `repro.obs.Tracer` (admit ->
  queue -> schedule -> prefill -> decode -> release, explicit sim/wall
  clock): per-request latency attribution riding the same JSONL files.
* ``spec`` — per-batch speculative-decode outcomes from the scheduler
  (draft policy, depth, proposed/accepted draft token counts, optionally
  the serving model and merged tier): the `CalibrationFitter` aggregates
  these into per-(model, tier, policy) accept rates that
  `repro.spec.SpecPlanner` prices draft depths with.

Records are plain dicts (JSON-serializable); `ingest` validates the minimal
per-kind schema — and rejects NaN/inf anywhere in a record's numeric fields
— so a malformed producer fails at the boundary, not inside the fitter (a
NaN that reaches JSONL round-trips as invalid JSON for strict parsers and
poisons every fit it touches).
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Iterable, List, Optional

# minimal required keys per record kind (ingest-time schema check)
_SCHEMAS: Dict[str, tuple] = {
    "kernel": ("kernel", "flops", "bytes", "measured_us", "roofline_us"),
    "energy": ("device", "intensity", "ridge", "cpq", "temp_c",
               "t_s", "p0_w", "quant_f", "energy_j"),
    "step": ("t_s", "temps", "powers", "energy_j"),
    "dryrun": ("arch", "shape", "flops"),
    "serve": ("t_s", "bucket", "tier_mix", "queue_delay_s", "point_index",
              "energy_j", "latency_s"),
    "span": ("name", "t0_s", "t1_s"),
    "spec": ("t_s", "policy", "n", "proposed", "accepted"),
}


def _check_finite(value, path: str) -> None:
    """Recursively reject NaN/inf numeric leaves (bool is not numeric here).
    ``path`` names the offending key for the producer's error message."""
    if isinstance(value, bool):
        return
    if isinstance(value, (int, float)):
        if not math.isfinite(value):
            raise ValueError(f"non-finite value {value!r} at {path!r} "
                             "(trace records must be finite JSON numbers)")
    elif isinstance(value, dict):
        for k, v in value.items():
            _check_finite(v, f"{path}.{k}")
    elif isinstance(value, (list, tuple)):
        for i, v in enumerate(value):
            _check_finite(v, f"{path}[{i}]")


def _validate(record: dict) -> dict:
    kind = record.get("kind")
    if kind not in _SCHEMAS:
        raise ValueError(f"unknown trace record kind {kind!r} "
                         f"(want one of {sorted(_SCHEMAS)})")
    missing = [k for k in _SCHEMAS[kind] if k not in record]
    if missing:
        raise ValueError(f"{kind!r} record missing keys {missing}")
    for k, v in record.items():
        _check_finite(v, k)
    return record


class TraceStore:
    """Append-only telemetry store with optional JSONL persistence.

    ``path=None`` keeps everything in memory (tests, synthetic fixtures);
    with a path every `ingest` appends one JSON line, so a crashed run's
    traces survive and `TraceStore.load` resumes from them.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: List[dict] = []
        if path is not None and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        # resumed records go through the same schema gate as
                        # fresh ingests: a truncated/hand-edited trace fails
                        # here, not inside the fitter.
                        self._records.append(_validate(json.loads(line)))

    # ------------------------------------------------------------- ingestion
    def ingest(self, record: dict) -> dict:
        """Validate + append one record (and persist it when backed by a
        file). Returns the stored record."""
        self._records.append(_validate(record))
        if self.path is not None:
            with open(self.path, "a") as f:
                f.write(json.dumps(record) + "\n")
        return record

    def ingest_many(self, records: Iterable[dict]) -> int:
        n = 0
        for r in records:
            self.ingest(r)
            n += 1
        return n

    # ---- producer adapters --------------------------------------------------
    def ingest_kernel_bench(self, results: dict) -> int:
        """Ingest `benchmarks.kernel_bench.run()` output (its ``records``
        list of per-rep kernel measurements)."""
        return self.ingest_many(results.get("records", []))

    def ingest_dryrun_artifact(self, artifact: dict) -> Optional[dict]:
        """Ingest one `repro.launch.dryrun` artifact's compiled-HLO counts.
        Returns the stored record, or None when the artifact carries no
        usable ``cost_analysis`` (errored dry-run, CPU backend gaps)."""
        cost = artifact.get("cost_analysis") or {}
        if "flops" not in cost:
            return None
        return self.ingest({
            "kind": "dryrun",
            "arch": artifact.get("arch", "?"),
            "shape": artifact.get("shape", "?"),
            "mesh": artifact.get("mesh", "?"),
            "flops": float(cost["flops"]),
            # XLA reports HBM traffic under "bytes accessed"
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "n_chips": artifact.get("n_chips"),
        })

    def ingest_step(self, report, signals: Optional[Dict[str, dict]] = None,
                    extra: Optional[dict] = None) -> dict:
        """Ingest one `ControlLoop` `StepReport` (plus optional per-stage
        `SignalSet.as_dict()` snapshots keyed by stage name)."""
        rec = {
            "kind": "step",
            "t_s": float(report.t_s),
            "load": float(report.load),
            "temps": {k: float(v) for k, v in report.temps.items()},
            "powers": {k: float(v) for k, v in report.powers.items()},
            "energy_j": float(report.energy_j),
            "inferences": float(report.inferences),
            "served": bool(report.served),
            "reannealed": bool(report.reannealed),
            "throttle_events": int(report.throttle_events),
            "drift": [ev.kind for ev in report.drift],
            "excluded": list(report.excluded),
        }
        if signals:
            rec["signals"] = signals
        if extra:
            rec.update(extra)
        return self.ingest(rec)

    def ingest_serve(self, record, signals: Optional[Dict[str, dict]] = None,
                     extra: Optional[dict] = None) -> dict:
        """Ingest one scheduler `BatchRecord` (plus optional per-stage
        `SignalSet.as_dict()` snapshots of the batch-workload costing)."""
        rec = {
            "kind": "serve",
            "t_s": float(record.t_s),
            "batch_id": int(record.batch_id),
            "bucket": int(record.bucket),
            "n_requests": int(record.n_requests),
            "n_sequences": int(record.n_sequences),
            "tier_mix": {k: int(v) for k, v in record.tier_mix.items()},
            "queue_delay_s": float(record.queue_delay_s),
            "point_index": int(record.point_index),
            "energy_j": float(record.energy_j),
            "latency_s": float(record.latency_s),
            "meets_caps": bool(record.meets_caps),
            "reroute": bool(record.reroute),
            # paged-KV occupancy: lets the calibration fitter see paging's
            # allocation pressure (CPQ residuals) alongside batch energy
            "prefill_bytes_saved": float(getattr(record,
                                                 "prefill_bytes_saved", 0.0)),
            # resident prefix pool: cross-batch block reuse and the LRU
            # evictions this batch's tails forced
            "pool_hit_blocks": int(getattr(record, "pool_hit_blocks", 0)),
            "pool_evictions": int(getattr(record, "pool_evictions", 0)),
            # serving formats (repro.quant): per-format duty factors and the
            # effective bytes the energy model should price
            "quant": str(getattr(record, "quant", "bf16")),
            "kv_format": str(getattr(record, "kv_format", "bf16")),
        }
        # speculative decode plan (depth chosen at formation); measured
        # accept counts ride the separate retire-time "spec" record
        spec_n = int(getattr(record, "spec_n", 0) or 0)
        if spec_n:
            rec["spec_policy"] = str(getattr(record, "spec_policy", "off"))
            rec["spec_n"] = spec_n
        kv = getattr(record, "kv_blocks_in_use", None)
        if kv is not None:
            rec["kv_blocks_in_use"] = int(kv)
        wb = getattr(record, "weight_bytes", None)
        if wb is not None:
            rec["weight_bytes"] = int(wb)
        kvb = getattr(record, "kv_bytes_in_use", None)
        if kvb is not None:
            rec["kv_bytes_in_use"] = int(kvb)
        # per-member simulated queue delays: p95 queue delay is computable
        # from serve traces alone (no scheduler state re-derivation)
        entries = getattr(record, "request_entries", None)
        if entries:
            rec["requests"] = [dict(e) for e in entries]
        if signals:
            rec["signals"] = signals
        if extra:
            rec.update(extra)
        return self.ingest(rec)

    def ingest_spans(self, tracer) -> int:
        """Ingest every span a `repro.obs.Tracer` collected (kind ``"span"``).
        Unneeded when the tracer was constructed with ``store=self`` — spans
        then mirror on emit."""
        return self.ingest_many(tracer.records())

    # --------------------------------------------------------------- queries
    def records(self, kind: Optional[str] = None) -> List[dict]:
        if kind is None:
            return list(self._records)
        return [r for r in self._records if r.get("kind") == kind]

    def __len__(self) -> int:
        return len(self._records)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self._records:
            out[r["kind"]] = out.get(r["kind"], 0) + 1
        return out

    # ----------------------------------------------------------- persistence
    def save(self, path: str) -> str:
        """Write every record as JSONL (full rewrite — for memory-backed
        stores; file-backed stores persist incrementally on ingest)."""
        with open(path, "w") as f:
            for r in self._records:
                f.write(json.dumps(r) + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "TraceStore":
        """Read-only view of an existing JSONL trace (records validated)."""
        store = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    store.ingest(json.loads(line))
        return store
