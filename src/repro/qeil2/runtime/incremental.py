"""Incremental (delta-cost) plan evaluation for PGSAM re-anneals.

`repro.core.energy.plan_costs` re-executes every stage on every call — O(S)
`execute_stage` evaluations per annealer candidate, which is what makes online
re-annealing (and 50+ device fleets) expensive: PGSAM proposes *single-stage
moves*, so S-1 of those evaluations recompute numbers that did not change.

`DeltaEvaluator` maintains the cost decomposition as per-device accumulators
that a single-stage move updates in O(1) (stage-count-independent; the final
aggregation is O(D) over devices, never O(S) over stages):

* **busy time** — `sum t_stage` per device; makespan is the max over occupied
  devices plus transfer time.
* **raw energy** — `sum t * p_base * f(Q)` per device, where `p_base` is the
  part of dynamic power that depends only on (stage, device, throttle). The
  device-level factors that *couple* stages sharing a device — the CPQ
  memory-pressure tax (a function of the device's total resident bytes) and
  the Phi leakage divisor (a function of its junction temperature) — multiply
  the accumulator at aggregation time, so moving a stage re-prices every
  stage on the two affected devices without touching them individually.
* **resident bytes** — `sum param_bytes` per device, driving CPQ.
* **transfer bytes** — the phase chains (stages of one phase ordered by
  layer) are fixed by the workload; a move flips at most the two boundaries
  adjacent to the moved stage.

Per-(stage, device) roofline times and base powers are cached on first use
(`signal cache`), so a long anneal converges to pure accumulator arithmetic.

Parity contract: objectives match the full `plan_costs(..., model=...)` path
to ~1e-9 relative (float associativity is the only difference), for both the
v1 and v2 energy models — enforced by `tests/test_incremental.py`.

Moves are applied speculatively: `apply` returns an undo token holding the
*exact prior values* of every touched accumulator, and `revert` restores them
bit-for-bit (no `+= x; -= x` float drift), so a rejected proposal leaves the
evaluator in the identical state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.decomposition import Stage, Workload
from repro.core.devices import DeviceProfile
from repro.core.energy import TRANSFER_ENERGY_PER_BYTE, execute_stage
from repro.qeil2.energy_v2 import execute_stage_v2
from repro.qeil2.signals import cpq, cpq_power_factor, phi

Objectives = Tuple[float, float, float]     # energy_j, makespan_s, underutil


@dataclass
class UndoToken:
    """Exact prior state of everything one move touched."""
    stage: int
    old_dev: int
    new_dev: int
    busy: Tuple[float, float]
    raw: Tuple[float, float]
    resident: Tuple[float, float]
    count: Tuple[int, int]
    transfer_bytes: float


class DeltaEvaluator:
    """O(1)-per-move incremental counterpart of ``plan_costs``.

    ``mapping`` is the stage->device-index tuple PGSAM anneals over; the
    evaluator mirrors it and must be kept in sync via ``apply``/``revert``.
    """

    def __init__(self, stages: Sequence[Stage],
                 devices: Sequence[DeviceProfile],
                 mapping: Sequence[int],
                 quant: str = "bf16",
                 workload: Optional[Workload] = None,
                 model: str = "v2",
                 temps: Optional[Dict[str, float]] = None,
                 headroom: float = 0.9,
                 throttle: Optional[Dict[str, float]] = None,
                 provider=None):
        if model not in ("v1", "v2"):
            raise ValueError(f"unknown energy model {model!r}")
        if provider is not None and model != "v2":
            raise ValueError("a CalibratedSignalProvider requires "
                             "model='v2'")
        self.stages = list(stages)
        self.devices = list(devices)
        self.quant = quant
        self.workload = workload
        self.model = model
        self.headroom = headroom
        self.provider = provider
        temps = temps or {}
        throttle = throttle or {}
        self._throttle = [throttle.get(d.name, 1.0) for d in self.devices]
        # Phi is fixed per anneal (temperatures evolve between re-anneals, not
        # inside one), so the leakage divisor is a per-device constant here —
        # from the calibrated provider when one is installed.
        phi_fn = phi if provider is None else provider.phi
        self._phi = [phi_fn(temps.get(d.name, d.t_ambient))
                     for d in self.devices]

        # --- phase chains + per-boundary costs (device-independent) ---------
        # boundary_transfer_bytes sorts each phase's stages by layer; the
        # boundary cost depends only on the *earlier* stage and the workload.
        by_phase: Dict[str, List[int]] = {}
        for si, st in enumerate(self.stages):
            by_phase.setdefault(st.phase, []).append(si)
        self._prev: List[Optional[int]] = [None] * len(self.stages)
        self._next: List[Optional[int]] = [None] * len(self.stages)
        self._bcost: List[float] = [0.0] * len(self.stages)  # cost of (si, next)
        for phase, idxs in by_phase.items():
            idxs.sort(key=lambda i: self.stages[i].layer)
            for a, b in zip(idxs, idxs[1:]):
                self._prev[b] = a
                self._next[a] = b
                st_a = self.stages[a]
                if workload is not None:
                    n_tok = (workload.n_decode_tokens if phase == "decode"
                             else workload.n_prefill_tokens)
                    self._bcost[a] = (n_tok * workload.bytes_per_act *
                                      max(st_a.width, 1))
                else:
                    self._bcost[a] = st_a.bytes_moved * 0.01

        # --- lazily-filled (stage, device) cache: (time_s, raw_energy_j) ----
        self._sd_cache: Dict[Tuple[int, int], Tuple[float, float]] = {}

        self.rebuild(mapping)

    # ------------------------------------------------------------ primitives
    def _stage_on_device(self, si: int, di: int) -> Tuple[float, float]:
        """Roofline time + raw (device-factor-free) energy, cached.

        Delegates to the canonical energy laws so the physics lives in one
        place: v1 stage energy has no cross-stage coupling, so
        `execute_stage` is the raw energy outright; for v2,
        `execute_stage_v2` at zero residency / ambient temperature gives
        energy with CPQ factor 1 and the ambient Phi divided in — multiply
        that Phi back out to strip all device-level factors (the ~1-ulp
        round-trip is far inside the 1e-9 parity contract).
        """
        key = (si, di)
        hit = self._sd_cache.get(key)
        if hit is not None:
            return hit
        st, dev = self.stages[si], self.devices[di]
        thr = self._throttle[di]
        if self.model == "v2":
            ex = execute_stage_v2(st, dev, self.quant, throttle=thr,
                                  headroom=self.headroom,
                                  provider=self.provider)
            out = (ex.time_s, ex.energy_j * ex.signals.phi)
        else:
            ex = execute_stage(st, dev, self.quant, throttle=thr)
            out = (ex.time_s, ex.energy_j)
        self._sd_cache[key] = out
        return out

    def _dev_factor(self, di: int) -> float:
        """Device-level energy multiplier: CPQ tax / Phi yield (v2 only)."""
        if self.model != "v2":
            return 1.0
        c = cpq(self._resident[di], self.devices[di], self.headroom)
        cpf = (cpq_power_factor(c) if self.provider is None
               else self.provider.cpq_power_factor(c))
        return cpf / self._phi[di]

    # --------------------------------------------------------------- rebuild
    def rebuild(self, mapping: Sequence[int]) -> None:
        """Full O(S) (re)build from an arbitrary mapping — used for seeds and
        whenever the annealer jumps rather than steps."""
        self.mapping = list(mapping)
        n_dev = len(self.devices)
        self._busy = [0.0] * n_dev
        self._raw = [0.0] * n_dev
        self._resident = [0.0] * n_dev
        self._count = [0] * n_dev
        for si, di in enumerate(self.mapping):
            t, e = self._stage_on_device(si, di)
            self._busy[di] += t
            self._raw[di] += e
            self._resident[di] += self.stages[si].param_bytes
            self._count[di] += 1
        self._transfer_bytes = 0.0
        for si in range(len(self.stages)):
            nxt = self._next[si]
            if nxt is not None and self.mapping[si] != self.mapping[nxt]:
                self._transfer_bytes += self._bcost[si]

    def move_fits(self, si: int, new_di: int, cap_bytes: float) -> bool:
        """Memory feasibility of moving stage ``si`` to ``new_di``: only the
        destination can newly overflow (the source merely frees bytes), so a
        feasible current mapping stays feasible iff the destination fits."""
        return (self._resident[new_di] + self.stages[si].param_bytes
                <= cap_bytes)

    # ------------------------------------------------------------------ move
    def apply(self, si: int, new_di: int) -> UndoToken:
        """Move stage ``si`` to device ``new_di``; returns the undo token."""
        old_di = self.mapping[si]
        token = UndoToken(
            stage=si, old_dev=old_di, new_dev=new_di,
            busy=(self._busy[old_di], self._busy[new_di]),
            raw=(self._raw[old_di], self._raw[new_di]),
            resident=(self._resident[old_di], self._resident[new_di]),
            count=(self._count[old_di], self._count[new_di]),
            transfer_bytes=self._transfer_bytes)
        if new_di == old_di:
            return token
        t_old, e_old = self._stage_on_device(si, old_di)
        t_new, e_new = self._stage_on_device(si, new_di)
        pb = self.stages[si].param_bytes
        self._busy[old_di] -= t_old
        self._busy[new_di] += t_new
        self._raw[old_di] -= e_old
        self._raw[new_di] += e_new
        self._resident[old_di] -= pb
        self._resident[new_di] += pb
        self._count[old_di] -= 1
        self._count[new_di] += 1
        # only the two boundaries adjacent to si can flip
        for a in (self._prev[si], si):
            if a is None:
                continue
            b = self._next[a]
            if b is None:
                continue
            pair = (self.mapping[a], self.mapping[b])
            was_cut = pair[0] != pair[1]
            now = (new_di if a == si else pair[0],
                   new_di if b == si else pair[1])
            is_cut = now[0] != now[1]
            if was_cut and not is_cut:
                self._transfer_bytes -= self._bcost[a]
            elif is_cut and not was_cut:
                self._transfer_bytes += self._bcost[a]
        self.mapping[si] = new_di
        return token

    def revert(self, token: UndoToken) -> None:
        """Bit-exact rollback of ``apply`` (restores saved values, no
        floating-point round-trip)."""
        a, b = token.old_dev, token.new_dev
        self._busy[a], self._busy[b] = token.busy
        self._raw[a], self._raw[b] = token.raw
        self._resident[a], self._resident[b] = token.resident
        self._count[a], self._count[b] = token.count
        self._transfer_bytes = token.transfer_bytes
        self.mapping[token.stage] = token.old_dev

    # ------------------------------------------------------------ objectives
    def objectives(self) -> Objectives:
        """(energy_j, makespan_s, underutil) — PGSAM's objective triple,
        matching ``PGSAM._evaluate`` on the same mapping."""
        energy = self._transfer_bytes * TRANSFER_ENERGY_PER_BYTE
        busy_total = 0.0
        busy_max = 0.0
        link_bw = float("inf")
        for di in range(len(self.devices)):
            if self._count[di] == 0:
                continue
            energy += self._raw[di] * self._dev_factor(di)
            busy_total += self._busy[di]
            if self._busy[di] > busy_max:
                busy_max = self._busy[di]
            if self.devices[di].link_bw < link_bw:
                link_bw = self.devices[di].link_bw
        t_io = (self._transfer_bytes / link_bw
                if self._transfer_bytes else 0.0)
        makespan = busy_max + t_io
        n = len(self.devices)
        underutil = (1.0 - busy_total / (n * makespan)
                     if makespan > 0 else 0.0)
        return (energy, makespan, underutil)

    def peek(self, si: int, new_di: int) -> Objectives:
        """Objectives after a hypothetical move, state unchanged."""
        token = self.apply(si, new_di)
        try:
            return self.objectives()
        finally:
            self.revert(token)
