"""Pareto-routed serving runtime: the online side of QEIL v2.

incremental  — DeltaEvaluator, O(1)-per-move plan costing for PGSAM anneals
router       — SLATier / ParetoRouter / RoutedServingEngine: the archive as
               a live routing surface for request classes
control_loop — ControlLoop: orchestrate -> execute -> heat -> re-orchestrate
               with drift-triggered, archive-warm-started re-anneals
"""
from repro.qeil2.runtime.incremental import DeltaEvaluator, UndoToken
from repro.qeil2.runtime.router import (BatchRoutingDecision, ParetoRouter,
                                        RoutedServingEngine,
                                        RoutingDecision, SLATier,
                                        default_tiers, merge_tiers)
from repro.qeil2.runtime.control_loop import (ControlLoop, LoopConfig,
                                              StepReport)
