"""Closed orchestrate -> execute -> heat -> re-orchestrate loop.

PR 1's `PGSAMOrchestrator(..., safety=...)` reads the RC thermal state once
per `assign`; the paper's headline numbers (zero hardware-throttle events at
a 75.6% energy reduction) come from placement that *keeps adapting* as the
device signals drift under sustained load. This loop closes it:

  1. **orchestrate** — an assignment from the PGSAM archive (first step), or
     a bounded warm-start re-anneal after drift.
  2. **execute** — the plan runs for one step: per-device power is the
     plan's per-device dynamic energy spread over its makespan, scaled by
     the offered load, plus the idle floor (exogenous heat — co-located
     processes, enclosure ramps — enters via ``extra_power``).
  3. **heat** — `SafetyMonitor.thermal_step` evolves every RC thermal model
     and emits `DriftEvent`s on margin crossings; the health monitor emits
     on failures/recoveries.
  4. **re-orchestrate** — drift (Phi through the proactive-throttle yield,
     a failed or recovered device, CPQ saturation) triggers a *bounded*
     re-anneal warm-started from the current archive (never from greedy
     seeds), with the frontier cache invalidated so routers re-pull.

Devices that crossed the thermal margin are excluded from placement until
they cool below the hysteresis threshold — this, not reactive throttling, is
what keeps hardware-throttle events at zero while a statically-placed
baseline rides through the margin into the throttle ceiling
(`benchmarks/pareto_router.py` measures exactly that).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.decomposition import Workload, decompose
from repro.core.orchestrator import Assignment
from repro.core.safety import THETA_THROTTLE, DriftEvent, SafetyMonitor
from repro.models.config import ArchConfig


@dataclass(frozen=True)
class LoopConfig:
    dt_s: float = 2.0
    # bounded re-anneal budget per drift event (warm starts converge fast;
    # with PGSAMConfig.incremental this is ~O(iters) accumulator updates)
    reanneal_iters: int = 400
    # resident/capacity fraction that counts as CPQ saturation drift
    cpq_saturation: float = 0.95
    # excluded-for-cooling devices rejoin below this fraction of the margin
    cool_frac: float = 0.90
    # False = measurement baseline: same telemetry, no re-orchestration
    adaptive: bool = True


@dataclass
class StepReport:
    t_s: float
    load: float
    temps: Dict[str, float]
    powers: Dict[str, float]          # what our plan drew (exogenous excluded)
    drift: List[DriftEvent]
    reannealed: bool
    served: bool                      # False: plan referenced a dead device
    inferences: float
    energy_j: float
    throttle_events: int              # cumulative hardware events (safety)
    excluded: List[str] = field(default_factory=list)


class ControlLoop:
    """Drives one (cfg, workload) serving deployment against a SafetyMonitor.

    ``orchestrator`` is any engine with the GreedyOrchestrator ``assign``
    API; the re-anneal fast path and frontier bookkeeping light up when it
    also exposes `PGSAMOrchestrator`'s ``reanneal`` / ``pareto_frontier``.
    An attached `ParetoRouter` (``router=``) is kept in sync with the
    healthy-device set so tier routing follows the loop's world view; an
    attached `repro.serving.ContinuousBatchingScheduler` (``scheduler=``)
    is notified after every drift-triggered re-anneal so the new frontier
    takes effect at the next batch *boundary* — in-flight batches finish on
    the operating point they were priced against.
    """

    def __init__(self, orchestrator, safety: SafetyMonitor, cfg: ArchConfig,
                 workload: Workload, loop: LoopConfig = LoopConfig(),
                 router=None, trace=None, scheduler=None, obs=None):
        self.orch = orchestrator
        self.safety = safety
        self.cfg = cfg
        self.workload = workload
        self.loop = loop
        self.router = router
        self.scheduler = scheduler
        # optional repro.qeil2.telemetry.TraceStore: every step emits one
        # execution record (temps/powers/energy + per-stage SignalSet
        # snapshots when the plan was v2-costed) — the runtime's side of the
        # measurement loop the calibration fitter closes.
        self.trace = trace
        # optional repro.obs bundle: live drift/re-anneal counters and
        # temperature/power gauges for the metrics endpoint (`launch/serve
        # --metrics-out`); the trace store above stays the replayable record
        self._m = None
        if obs is not None and obs.metrics.enabled:
            reg = obs.metrics
            self._m = {
                "drift": reg.counter(
                    "control_drift_events_total",
                    "Drift events seen by the control loop, by kind",
                    labelnames=("kind",)),
                "reanneal": reg.counter(
                    "control_reanneals_total",
                    "Drift-triggered re-anneals executed"),
                "energy": reg.counter(
                    "control_energy_j_total",
                    "Energy integrated over control-loop steps"),
                "throttle": reg.gauge(
                    "control_throttle_events",
                    "Cumulative hardware throttle events (safety monitor)"),
                "temp": reg.gauge(
                    "control_device_temp_c",
                    "Junction temperature per device",
                    labelnames=("device",)),
            }
        self.assignment: Optional[Assignment] = None
        self._archive: List[Assignment] = []
        self.t_s = 0.0
        self.reanneals = 0
        self.reanneal_wall_s = 0.0
        self._pending: List[DriftEvent] = []
        self._excluded: Set[str] = set()       # cooling, placement-excluded
        self._cpq_flagged: Set[str] = set()    # one saturation event per plan
        self._stage_bytes = [(st.name, st.param_bytes)
                             for st in decompose(cfg, workload)]
        safety.subscribe(self._on_drift)
        if hasattr(orchestrator, "on_drift"):
            safety.subscribe(orchestrator.on_drift)
        # the scheduler consumes the raw event stream too: a device failure
        # must preempt its in-flight batches NOW (the re-anneal below only
        # redirects future formations), and the chaos-harness kinds
        # (kv_squeeze / slow_kernel) adjust its admission/pricing state
        if scheduler is not None and hasattr(scheduler, "on_drift"):
            safety.subscribe(scheduler.on_drift)

    # ------------------------------------------------------------ plumbing
    def _on_drift(self, event: DriftEvent) -> None:
        self._pending.append(event)

    def allowed_devices(self) -> List[str]:
        """Health-monitor-healthy minus thermally-cooling devices."""
        healthy = set(self.safety.health.healthy_devices())
        out = [d.name for d in self.orch.devices
               if d.name in healthy and d.name not in self._excluded]
        return out or [d.name for d in self.orch.devices
                       if d.name in healthy]   # never exclude everything

    def _sync_router(self) -> None:
        if self.router is not None:
            self.router.set_healthy(self.allowed_devices())

    def _notify_scheduler(self, warm: bool) -> None:
        # drift re-anneal boundary: the router's healthy set / epoch moved,
        # so the scheduler's next *formed* batch re-routes on the post-drift
        # frontier (routing only ever happens at batch formation)
        if warm and self.scheduler is not None:
            self.scheduler.on_reorchestrate(healthy=self.allowed_devices())

    def _orchestrate(self, warm: bool) -> None:
        allowed = self.allowed_devices()
        t0 = time.perf_counter()
        if warm and hasattr(self.orch, "reanneal") and \
                self.assignment is not None and self.assignment.mapping:
            # drift path: bounded re-anneal warm-started from the current
            # plan + archive (never greedy seeds); refreshes the frontier
            # cache at the post-drift epoch as a side effect.
            warm_starts = [self.assignment.mapping] + \
                [a.mapping for a in self._archive if a.mapping]
            self.assignment = self.orch.reanneal(
                self.cfg, self.workload, warm_starts, healthy=allowed,
                iters_max=self.loop.reanneal_iters)
            self.reanneals += 1
            self._archive = self.orch.pareto_frontier(
                self.cfg, self.workload, healthy=allowed)   # cache hit
        elif hasattr(self.orch, "pareto_frontier"):
            # cold start: one anneal builds the archive; serve from its
            # cheapest feasible point (best-effort cheapest if none is)
            self._archive = self.orch.pareto_frontier(
                self.cfg, self.workload, healthy=allowed)
            placed = [a for a in self._archive if a.mapping]
            pool = [a for a in placed if a.feasible] or placed
            self.assignment = (min(pool, key=lambda a: a.energy_j) if pool
                               else self.orch.assign(self.cfg, self.workload,
                                                     healthy=allowed))
        else:
            self.assignment = self.orch.assign(self.cfg, self.workload,
                                               healthy=allowed)
            self._archive = [self.assignment]
        self.reanneal_wall_s += time.perf_counter() - t0
        self._sync_router()
        self._notify_scheduler(warm)

    # ------------------------------------------------------------- physics
    def _hw_speed(self) -> float:
        """Hardware-throttle slowdown: any plan device at/over T_max is
        force-clocked to half speed by firmware (the failure mode the paper
        measures in Table 10 — the closed loop exists to never hit it). The
        pipeline runs at its slowest stage's speed."""
        a = self.assignment
        if a is None or not a.mapping:
            return 1.0
        speed = 1.0
        for name in {d.name for d in a.mapping.values()}:
            tm = self.safety.thermal.get(name)
            if tm is not None and tm.state.temp_c > tm.device.t_max:
                speed = min(speed, 0.5)
        return speed

    def _plan_powers(self, load: float, speed: float = 1.0
                     ) -> Dict[str, float]:
        """Average per-device power of executing the plan at the offered
        load: dynamic energy over makespan (scaled by the hardware-throttle
        speed — a half-clocked pipeline draws half the dynamic power), plus
        the idle floor for every device the plan occupies. Devices the plan
        does not touch are put in their low-power sleep state (modeled as
        ~0 W): the runtime owns placement, so it also owns power-gating what
        placement freed up."""
        powers: Dict[str, float] = {}
        failed = {n for n in self.safety.health.health
                  if n not in self.safety.health.healthy_devices()}
        a = self.assignment
        in_plan = ({d.name for d in a.mapping.values()}
                   if a is not None and a.mapping else set())
        alive = set()
        for dev in self.orch.devices:
            on = dev.name in in_plan and dev.name not in failed
            if on:
                alive.add(dev.name)
            powers[dev.name] = dev.power_idle if on else 0.0
        if a is not None and a.costs is not None:
            mk = max(a.costs.makespan_s, 1e-12)
            for name, e_j in a.costs.per_device_energy().items():
                if name in alive:
                    powers[name] += e_j / mk * load * speed
        return powers

    def _check_cpq(self) -> None:
        """CPQ saturation drift: the plan's resident set is approaching the
        allocator headroom on some device (emitted once per plan per
        device)."""
        a = self.assignment
        if a is None or not a.mapping:
            return
        headroom = getattr(getattr(self.orch, "constraints", None),
                           "memory_headroom", 0.9)
        resident: Dict[str, float] = {}
        for st_name, pb in self._stage_bytes:
            dev = a.mapping.get(st_name)
            if dev is not None:
                resident[dev.name] = resident.get(dev.name, 0.0) + pb
        for dev in self.orch.devices:
            cap = dev.mem_cap * headroom
            frac = resident.get(dev.name, 0.0) / cap if cap > 0 else 0.0
            if frac < self.loop.cpq_saturation:
                # falling edge re-arms the detector; while saturation
                # persists (a re-anneal may not be able to relieve it) the
                # flag holds, so one episode emits one event instead of
                # re-annealing every step forever.
                self._cpq_flagged.discard(dev.name)
            elif dev.name not in self._cpq_flagged:
                self._cpq_flagged.add(dev.name)
                self.safety.emit(DriftEvent(
                    self.t_s, dev.name, "cpq_saturation", value=frac,
                    detail=f"resident {frac:.2f} of headroom"))

    def _update_exclusions(self, new_events: List[DriftEvent]) -> None:
        for ev in new_events:
            if ev.kind == "thermal_margin":
                self._excluded.add(ev.device)
        for name in sorted(self._excluded):
            tm = self.safety.thermal[name]
            cool_at = (self.loop.cool_frac * THETA_THROTTLE *
                       tm.device.t_max)
            if tm.state.temp_c < cool_at:
                self._excluded.discard(name)
                self.safety.emit(DriftEvent(
                    self.t_s, name, "device_cooled", value=tm.state.temp_c,
                    detail="rejoining placement pool"))

    # ----------------------------------------------------------------- step
    def step(self, load: float = 1.0,
             extra_power: Optional[Dict[str, float]] = None) -> StepReport:
        """One control period: execute the current plan for ``dt_s`` under
        ``load`` (a throughput multiplier), heat the RC models (plus any
        exogenous ``extra_power``), then re-orchestrate if signals drifted.
        """
        dt = self.loop.dt_s
        self.t_s += dt
        if self.assignment is None:
            self._orchestrate(warm=False)
        executed = self.assignment        # the plan this step actually ran

        # execute: our plan's draw; exogenous watts only heat, never bill
        speed = self._hw_speed()
        powers = self._plan_powers(load, speed)
        thermal_in = dict(powers)
        for name, w in (extra_power or {}).items():
            thermal_in[name] = thermal_in.get(name, 0.0) + w

        # heat: may emit thermal_margin / failure events into _pending
        n_before = len(self._pending)
        self.safety.thermal_step(thermal_in, dt)
        self._check_cpq()
        if self.loop.adaptive:
            self._update_exclusions(self._pending[n_before:])

        # accounting against the *executed* plan (a re-anneal below takes
        # effect next step; crediting its throughput or billing its power
        # for a period it never ran would skew the policy comparison)
        failed = {n for n, h in self.safety.health.health.items()
                  if n not in self.safety.health.healthy_devices()}
        served = bool(executed and executed.mapping) and not any(
            d.name in failed for d in executed.mapping.values())
        inferences = 0.0
        if served and executed.costs is not None:
            inferences = speed * load * dt / \
                max(executed.costs.makespan_s, 1e-12) * self.workload.batch
        energy = sum(powers.values()) * dt

        # re-orchestrate on drift
        reannealed = False
        drift = list(self._pending)
        self._pending.clear()
        if drift and self.loop.adaptive:
            self._orchestrate(warm=True)
            reannealed = True
        report = StepReport(
            t_s=self.t_s, load=load,
            temps={n: tm.state.temp_c
                   for n, tm in self.safety.thermal.items()},
            powers=powers, drift=drift, reannealed=reannealed,
            served=served, inferences=inferences, energy_j=energy,
            throttle_events=self.safety.total_throttle_events(),
            excluded=sorted(self._excluded))
        if self.trace is not None:
            self.trace.ingest_step(report, signals=self._plan_signals(executed))
        if self._m is not None:
            for ev in drift:
                self._m["drift"].inc(kind=ev.kind)
            if reannealed:
                self._m["reanneal"].inc()
            self._m["energy"].inc(report.energy_j)
            self._m["throttle"].set(report.throttle_events)
            for name, t in report.temps.items():
                self._m["temp"].set(t, device=name)
        return report

    def _plan_signals(self, assignment) -> Dict[str, dict]:
        """Per-stage `SignalSet.as_dict()` snapshots of the executed plan —
        present when the orchestrator costs plans with the v2 model (its
        `StageExecutionV2` records carry the signal triple)."""
        out: Dict[str, dict] = {}
        if assignment is not None and assignment.costs is not None:
            for e in assignment.costs.executions:
                sig = getattr(e, "signals", None)
                if sig is not None:
                    out[e.stage.name] = sig.as_dict()
        return out
