"""SLA-tiered operating-point routing over the PGSAM non-dominated archive.

PR 1 left the Pareto frontier a one-shot artifact: `PGSAMOrchestrator`
computes it, callers print it. This module makes it a *live routing surface*:
every request class (SLA tier) is scalarized over the archive to pick the
operating point — a full stage->device placement with known energy, makespan
and quality — that serves that tier cheapest within its caps.

* ``SLATier`` — a request class: optional hard caps (`latency_p99_s` on the
  plan makespan, `energy_cap_w` on its average power draw, `min_quality` on
  repeated-sampling coverage) plus scalarization weights for choosing among
  the cap-feasible archive points.
* ``ParetoRouter`` — holds the frontier (via the orchestrator's memoized
  `pareto_frontier`, so repeated routing never re-anneals an unchanged
  world) and maps tiers to `RoutingDecision`s. Tracks the orchestrator's
  health epoch: after a drift event invalidates the archive, the next
  `route` call transparently refreshes.
* ``RoutedServingEngine`` — the `repro.serving.ServingEngine` adapter:
  placement becomes frontier-driven per `generate` call (the engine's
  `placement_provider` hook observes the chosen operating point), and a
  tier's `min_quality` floor can raise the sampling budget.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Union

from repro.core.decomposition import Workload
from repro.core.formalisms import coverage, samples_for_coverage
from repro.core.orchestrator import Assignment, cfg_param_millions
from repro.models.config import ArchConfig


@dataclass(frozen=True)
class SLATier:
    """One request class. Caps are hard constraints on the operating point;
    weights scalarize among the points that satisfy them (objectives are
    normalized by the frontier minima, so the weights are unitless)."""
    name: str
    latency_p99_s: Optional[float] = None   # cap on plan makespan
    energy_cap_w: Optional[float] = None    # cap on plan average power
    min_quality: Optional[float] = None     # coverage floor (Formalism 1.1)
    energy_weight: float = 1.0
    latency_weight: float = 0.0


@dataclass
class RoutingDecision:
    tier: SLATier
    assignment: Assignment          # the chosen archive operating point
    point_index: int                # index into the router's frontier
    meets_caps: bool                # False -> best-effort (caps violated)
    quality: Optional[float] = None     # coverage at the workload's samples
    samples: Optional[int] = None       # raised budget to reach min_quality
    notes: List[str] = field(default_factory=list)

    @property
    def energy_j(self) -> float:
        return self.assignment.energy_j

    @property
    def latency_s(self) -> float:
        return self.assignment.latency_s

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / max(self.latency_s, 1e-12)


def default_tiers(base_latency_s: float) -> List[SLATier]:
    """Three canonical tiers around a reference latency (typically the
    balanced plan's makespan): interactive chases the low-latency end of the
    frontier, economy the low-energy end, standard trades both under a
    relaxed cap."""
    return [
        SLATier("interactive", latency_p99_s=0.9 * base_latency_s,
                energy_weight=0.0, latency_weight=1.0),
        SLATier("standard", latency_p99_s=1.5 * base_latency_s,
                energy_weight=0.5, latency_weight=0.5),
        SLATier("economy", energy_weight=1.0, latency_weight=0.0),
    ]


class ParetoRouter:
    """Maps SLA tiers to operating points on the PGSAM archive.

    ``orchestrator`` must expose ``pareto_frontier(cfg, workload, healthy)``
    and a ``health_epoch`` counter (`repro.qeil2.PGSAMOrchestrator`); the
    router re-pulls the frontier whenever the epoch moved — i.e. after any
    drift event the control loop (or safety monitor) delivered.
    """

    def __init__(self, orchestrator, cfg: ArchConfig, workload: Workload,
                 tiers: Sequence[SLATier] = (),
                 healthy: Optional[Sequence[str]] = None):
        self.orchestrator = orchestrator
        self.cfg = cfg
        self.workload = workload
        self.tiers: Dict[str, SLATier] = {t.name: t for t in tiers}
        self.healthy = list(healthy) if healthy is not None else None
        self._frontier: Optional[List[Assignment]] = None
        self._epoch = -1

    def add_tier(self, tier: SLATier) -> None:
        self.tiers[tier.name] = tier

    def set_healthy(self, healthy: Optional[Sequence[str]]) -> None:
        """Restrict routing to a device subset (the control loop calls this
        when devices fail, cool down, or come back)."""
        self.healthy = list(healthy) if healthy is not None else None
        self._frontier = None

    @property
    def frontier(self) -> List[Assignment]:
        """The current archive (placed points only), refreshed when the
        orchestrator's health epoch has moved since the last pull."""
        epoch = getattr(self.orchestrator, "health_epoch", 0)
        if self._frontier is None or epoch != self._epoch:
            pts = self.orchestrator.pareto_frontier(
                self.cfg, self.workload, healthy=self.healthy)
            self._frontier = [a for a in pts if a.mapping]
            self._epoch = epoch
        return self._frontier

    # ------------------------------------------------------------- routing
    def route(self, request_class: Union[str, SLATier]) -> RoutingDecision:
        """Pick the operating point for a request class: hard-filter the
        archive by the tier's caps, then scalarize (weights over frontier-
        normalized energy/latency). With no cap-feasible point the least-
        violating point is returned flagged ``meets_caps=False`` — serving
        degrades, it does not crash."""
        tier = (self.tiers[request_class]
                if isinstance(request_class, str) else request_class)
        pts = self.frontier
        if not pts:
            raise RuntimeError("empty frontier: no placeable operating point")
        e_min = max(min(a.energy_j for a in pts), 1e-12)
        t_min = max(min(a.latency_s for a in pts), 1e-12)

        def score(a: Assignment) -> float:
            return (tier.energy_weight * a.energy_j / e_min +
                    tier.latency_weight * a.latency_s / t_min)

        def violation(a: Assignment) -> float:
            v = 0.0
            if tier.latency_p99_s is not None and \
                    a.latency_s > tier.latency_p99_s:
                v += a.latency_s / tier.latency_p99_s - 1.0
            if tier.energy_cap_w is not None:
                p = a.energy_j / max(a.latency_s, 1e-12)
                if p > tier.energy_cap_w:
                    v += p / tier.energy_cap_w - 1.0
            # sub-ulp overshoot is a rounding artifact, not a violation:
            # callers routinely derive caps as fractions of frontier points
            # (cap = x/0.9 * 0.9 can land one ulp under x)
            return 0.0 if v < 1e-9 else v

        feasible = [i for i, a in enumerate(pts) if violation(a) == 0.0]
        notes = []
        if feasible:
            idx = min(feasible, key=lambda i: (score(pts[i]), i))
            meets = True
        else:
            idx = min(range(len(pts)),
                      key=lambda i: (violation(pts[i]), score(pts[i]), i))
            meets = False
            notes.append(f"no archive point satisfies tier "
                         f"{tier.name!r} caps; best-effort")

        quality = None
        samples = None
        if tier.min_quality is not None:
            w = self.workload
            n_millions = cfg_param_millions(self.cfg)
            quality = coverage(w.samples, n_millions, w.decode_tokens)
            if quality < tier.min_quality:
                samples = int(math.ceil(samples_for_coverage(
                    tier.min_quality, n_millions, w.decode_tokens)))
                notes.append(f"coverage {quality:.3f} < "
                             f"{tier.min_quality}: raise samples to "
                             f"{samples}")
        return RoutingDecision(tier, pts[idx], idx, meets, quality, samples,
                               notes)

    def route_all(self) -> Dict[str, RoutingDecision]:
        return {name: self.route(name) for name in self.tiers}


# ======================================================= serving-side adapter

class RoutedServingEngine:
    """Frontier-driven placement for `repro.serving.ServingEngine`.

    The engine executes on whatever accelerator JAX sees; *placement* in this
    reproduction is the orchestrator's simulated stage->device plan. This
    adapter closes the gap the ROADMAP called out: each ``generate`` call
    routes its SLA tier through the `ParetoRouter`, installs the chosen
    operating point into the engine's ``placement_provider`` hook, and (when
    the tier sets ``min_quality``) raises ``n_samples`` to the coverage
    floor's sampling budget.
    """

    def __init__(self, engine, router: ParetoRouter,
                 default_tier: Optional[str] = None):
        self.engine = engine
        self.router = router
        self.default_tier = default_tier
        # bounded: decisions reference full plans; cap the history so a
        # long-lived server doesn't grow with request count
        self.decisions: Deque[RoutingDecision] = deque(maxlen=256)
        self._current: Optional[RoutingDecision] = None
        engine.placement_provider = self._placement

    def _placement(self, n_prompts: int, n_samples: int):
        return self._current.assignment if self._current is not None else None

    def generate(self, prompts, tier: Optional[Union[str, SLATier]] = None,
                 n_samples: int = 1, **kwargs):
        """`ServingEngine.generate` with per-call frontier routing; the
        decision lands in ``self.decisions`` (and the operating point in
        ``engine.last_placement``)."""
        tier = tier if tier is not None else self.default_tier
        if tier is None:
            raise ValueError("no tier given and no default_tier configured")
        decision = self.router.route(tier)
        if decision.samples is not None:
            n_samples = max(n_samples, decision.samples)
        self._current = decision
        self.decisions.append(decision)
        return self.engine.generate(prompts, n_samples=n_samples, **kwargs)
