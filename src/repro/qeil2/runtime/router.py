"""SLA-tiered operating-point routing over the PGSAM non-dominated archive.

PR 1 left the Pareto frontier a one-shot artifact: `PGSAMOrchestrator`
computes it, callers print it. This module makes it a *live routing surface*:
every request class (SLA tier) is scalarized over the archive to pick the
operating point — a full stage->device placement with known energy, makespan
and quality — that serves that tier cheapest within its caps.

* ``SLATier`` — a request class: optional hard caps (`latency_p99_s` on the
  plan makespan, `energy_cap_w` on its average power draw, `min_quality` on
  repeated-sampling coverage) plus scalarization weights for choosing among
  the cap-feasible archive points.
* ``ParetoRouter`` — holds the frontier (via the orchestrator's memoized
  `pareto_frontier`, so repeated routing never re-anneals an unchanged
  world) and maps tiers to `RoutingDecision`s. Tracks the orchestrator's
  health epoch: after a drift event invalidates the archive, the next
  `route` call transparently refreshes.
* ``route_batch`` — the batch-aware path the continuous-batching scheduler
  uses: a mixed-tier batch routes to ONE shared operating point (caps merge
  to the tightest member tier, weights blend by request count), with every
  frontier point *re-costed under the batch workload* so decode
  weight-streaming amortization is priced into feasibility, and the chosen
  point's cost attributed back per tier.
* ``RoutedServingEngine`` — thin compatibility shim over the scheduler for
  the old per-`generate` adapter API; new code should drive
  `repro.serving.ContinuousBatchingScheduler` directly.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.decomposition import Workload, decompose
from repro.core.energy import PlanCosts, plan_costs
from repro.core.formalisms import coverage, samples_for_coverage
from repro.core.orchestrator import Assignment, cfg_param_millions
from repro.models.config import ArchConfig


@dataclass(frozen=True)
class SLATier:
    """One request class. Caps are hard constraints on the operating point;
    weights scalarize among the points that satisfy them (objectives are
    normalized by the frontier minima, so the weights are unitless)."""
    name: str
    latency_p99_s: Optional[float] = None   # cap on plan makespan
    energy_cap_w: Optional[float] = None    # cap on plan average power
    min_quality: Optional[float] = None     # coverage floor (Formalism 1.1)
    energy_weight: float = 1.0
    latency_weight: float = 0.0


@dataclass
class RoutingDecision:
    tier: SLATier
    assignment: Assignment          # the chosen archive operating point
    point_index: int                # index into the router's frontier
    meets_caps: bool                # False -> best-effort (caps violated)
    quality: Optional[float] = None     # coverage at the workload's samples
    samples: Optional[int] = None       # raised budget to reach min_quality
    notes: List[str] = field(default_factory=list)

    @property
    def energy_j(self) -> float:
        return self.assignment.energy_j

    @property
    def latency_s(self) -> float:
        return self.assignment.latency_s

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / max(self.latency_s, 1e-12)


@dataclass(eq=False)
class BatchRoutingDecision:
    """One shared operating point for a mixed-tier batch.

    ``tier`` is the *merged* request class (tightest member caps,
    count-blended weights; a single-tier batch keeps that tier's name).
    ``batch_costs`` is the chosen point's mapping re-costed under the batch
    workload — its makespan is the batch's simulated service time, and its
    energy is attributed per member tier in ``per_tier_energy_j``.
    """
    tier: SLATier
    tier_counts: Dict[str, int]
    assignment: Assignment
    point_index: int
    meets_caps: bool
    workload: Workload                  # the batch workload costed
    batch_costs: PlanCosts
    per_tier_energy_j: Dict[str, float]
    notes: List[str] = field(default_factory=list)

    @property
    def n_requests(self) -> int:
        return sum(self.tier_counts.values())

    @property
    def energy_j(self) -> float:
        return self.batch_costs.energy_j

    @property
    def latency_s(self) -> float:
        return self.batch_costs.makespan_s

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / max(self.latency_s, 1e-12)


def merge_tiers(tiers: Sequence[SLATier],
                counts: Optional[Dict[str, int]] = None) -> SLATier:
    """Collapse a batch's member tiers into one request class: hard caps
    tighten to the strictest member (min latency/power cap, max quality
    floor) — a shared operating point must satisfy every rider — while the
    scalarization weights blend by request count (amortization: the batch
    optimizes for its population mix)."""
    by_name = {t.name: t for t in tiers}
    if len(by_name) == 1:
        return next(iter(by_name.values()))
    counts = counts or Counter(t.name for t in tiers)
    total = max(sum(counts.values()), 1)
    lat = [t.latency_p99_s for t in by_name.values()
           if t.latency_p99_s is not None]
    pow_ = [t.energy_cap_w for t in by_name.values()
            if t.energy_cap_w is not None]
    qual = [t.min_quality for t in by_name.values()
            if t.min_quality is not None]
    return SLATier(
        name="+".join(sorted(by_name)),
        latency_p99_s=min(lat) if lat else None,
        energy_cap_w=min(pow_) if pow_ else None,
        min_quality=max(qual) if qual else None,
        energy_weight=sum(by_name[n].energy_weight * c
                          for n, c in counts.items()) / total,
        latency_weight=sum(by_name[n].latency_weight * c
                           for n, c in counts.items()) / total)


def default_tiers(base_latency_s: float) -> List[SLATier]:
    """Three canonical tiers around a reference latency (typically the
    balanced plan's makespan): interactive chases the low-latency end of the
    frontier, economy the low-energy end, standard trades both under a
    relaxed cap."""
    return [
        SLATier("interactive", latency_p99_s=0.9 * base_latency_s,
                energy_weight=0.0, latency_weight=1.0),
        SLATier("standard", latency_p99_s=1.5 * base_latency_s,
                energy_weight=0.5, latency_weight=0.5),
        SLATier("economy", energy_weight=1.0, latency_weight=0.0),
    ]


class ParetoRouter:
    """Maps SLA tiers to operating points on the PGSAM archive.

    ``orchestrator`` must expose ``pareto_frontier(cfg, workload, healthy)``
    and a ``health_epoch`` counter (`repro.qeil2.PGSAMOrchestrator`); the
    router re-pulls the frontier whenever the epoch moved — i.e. after any
    drift event the control loop (or safety monitor) delivered.
    """

    def __init__(self, orchestrator, cfg: ArchConfig, workload: Workload,
                 tiers: Sequence[SLATier] = (),
                 healthy: Optional[Sequence[str]] = None):
        self.orchestrator = orchestrator
        self.cfg = cfg
        self.workload = workload
        self.tiers: Dict[str, SLATier] = {t.name: t for t in tiers}
        self.healthy = list(healthy) if healthy is not None else None
        self._frontier: Optional[List[Assignment]] = None
        self._epoch = -1
        # batch-workload re-costings, keyed by (point identity, workload);
        # the value pins the assignment (id-recycling safety); dropped with
        # the frontier
        self._recost_cache: Dict[Tuple[int, Workload],
                                 Tuple[Assignment, PlanCosts]] = {}

    def add_tier(self, tier: SLATier) -> None:
        self.tiers[tier.name] = tier

    def resolve_tier(self, tier: Union[str, SLATier]) -> SLATier:
        """Registered tier by name, or an ad-hoc `SLATier` verbatim."""
        return self.tiers[tier] if isinstance(tier, str) else tier

    def set_healthy(self, healthy: Optional[Sequence[str]]) -> None:
        """Restrict routing to a device subset (the control loop calls this
        when devices fail, cool down, or come back)."""
        self.healthy = list(healthy) if healthy is not None else None
        self._frontier = None
        self._recost_cache.clear()

    @property
    def frontier(self) -> List[Assignment]:
        """The current archive (placed points only), refreshed when the
        orchestrator's health epoch has moved since the last pull."""
        epoch = getattr(self.orchestrator, "health_epoch", 0)
        if self._frontier is None or epoch != self._epoch:
            pts = self.orchestrator.pareto_frontier(
                self.cfg, self.workload, healthy=self.healthy)
            self._frontier = [a for a in pts if a.mapping]
            self._epoch = epoch
            self._recost_cache.clear()
        return self._frontier

    # ------------------------------------------------------- batch costing
    def batch_workload(self, n_requests: int,
                       samples: Optional[int] = None,
                       prompt_tokens: Optional[int] = None,
                       decode_tokens: Optional[int] = None) -> Workload:
        """The router's canonical per-request workload scaled to a batch of
        ``n_requests``, optionally overriding the sampling budget and token
        counts with what the batch will actually execute (the scheduler
        passes its bucket's prompt length / decode horizon and the members'
        admission-raised sample mean)."""
        kw = {"batch": max(int(n_requests), 1)}
        if samples is not None:
            kw["samples"] = int(samples)
        if prompt_tokens is not None:
            kw["prompt_tokens"] = int(prompt_tokens)
        if decode_tokens is not None:
            kw["decode_tokens"] = int(decode_tokens)
        return dataclasses.replace(self.workload, **kw)

    def recost(self, assignment: Assignment,
               workload: Workload) -> PlanCosts:
        """Re-cost an archive point's *mapping* under a different workload —
        same placement, batched tokens. This is where batching amortization
        becomes visible: decode stages re-stream weights once per token
        regardless of batch size, so a batch's makespan grows sublinearly in
        its request count. Uses the orchestrator's quant / energy model /
        calibration provider (and live temps when it is thermally aware)."""
        # the cached tuple pins the assignment so its id cannot be recycled
        # by a new object while the entry lives (cache drops with the
        # frontier epoch / healthy-set changes)
        key = (id(assignment), workload)
        hit = self._recost_cache.get(key)
        if hit is not None:
            return hit[1]
        orch = self.orchestrator
        stages = decompose(self.cfg, workload)
        mapping = {st.name: assignment.mapping[st.name] for st in stages}
        model = getattr(orch, "energy_model", "v1")
        temps = None
        safety = getattr(orch, "safety", None)
        if safety is not None and model == "v2":
            temps = {n: tm.state.temp_c
                     for n, tm in safety.thermal.items()}
        costs = plan_costs(
            stages, mapping, getattr(orch, "quant", "bf16"), workload,
            model=model, temps=temps,
            headroom=getattr(getattr(orch, "constraints", None),
                             "memory_headroom", 0.9),
            provider=getattr(orch, "provider", None))
        self._recost_cache[key] = (assignment, costs)
        return costs

    # ------------------------------------------------------------- routing
    def route(self, request_class: Union[str, SLATier]) -> RoutingDecision:
        """Pick the operating point for a request class: hard-filter the
        archive by the tier's caps, then scalarize (weights over frontier-
        normalized energy/latency). With no cap-feasible point the least-
        violating point is returned flagged ``meets_caps=False`` — serving
        degrades, it does not crash."""
        tier = (self.tiers[request_class]
                if isinstance(request_class, str) else request_class)
        pts = self.frontier
        if not pts:
            raise RuntimeError("empty frontier: no placeable operating point")
        e_min = max(min(a.energy_j for a in pts), 1e-12)
        t_min = max(min(a.latency_s for a in pts), 1e-12)

        def score(a: Assignment) -> float:
            return (tier.energy_weight * a.energy_j / e_min +
                    tier.latency_weight * a.latency_s / t_min)

        def violation(a: Assignment) -> float:
            v = 0.0
            if tier.latency_p99_s is not None and \
                    a.latency_s > tier.latency_p99_s:
                v += a.latency_s / tier.latency_p99_s - 1.0
            if tier.energy_cap_w is not None:
                p = a.energy_j / max(a.latency_s, 1e-12)
                if p > tier.energy_cap_w:
                    v += p / tier.energy_cap_w - 1.0
            # sub-ulp overshoot is a rounding artifact, not a violation:
            # callers routinely derive caps as fractions of frontier points
            # (cap = x/0.9 * 0.9 can land one ulp under x)
            return 0.0 if v < 1e-9 else v

        feasible = [i for i, a in enumerate(pts) if violation(a) == 0.0]
        notes = []
        if feasible:
            idx = min(feasible, key=lambda i: (score(pts[i]), i))
            meets = True
        else:
            idx = min(range(len(pts)),
                      key=lambda i: (violation(pts[i]), score(pts[i]), i))
            meets = False
            notes.append(f"no archive point satisfies tier "
                         f"{tier.name!r} caps; best-effort")

        quality = None
        samples = None
        if tier.min_quality is not None:
            quality = self._coverage()
            samples = self.required_samples(tier)
            if samples is not None:
                notes.append(f"coverage {quality:.3f} < "
                             f"{tier.min_quality}: raise samples to "
                             f"{samples}")
        return RoutingDecision(tier, pts[idx], idx, meets, quality, samples,
                               notes)

    def route_all(self) -> Dict[str, RoutingDecision]:
        return {name: self.route(name) for name in self.tiers}

    # ------------------------------------------------------- quality floor
    def _coverage(self) -> float:
        w = self.workload
        return coverage(w.samples, cfg_param_millions(self.cfg),
                        w.decode_tokens)

    def required_samples(self, tier: Union[str, SLATier]) -> Optional[int]:
        """Sampling budget needed to reach the tier's coverage floor
        (Formalism 1.1), or None when there is no floor or the canonical
        workload already meets it. The admission queue raises each
        request's budget with this at submit time."""
        tier = self.resolve_tier(tier)
        if tier.min_quality is None or self._coverage() >= tier.min_quality:
            return None
        w = self.workload
        return int(math.ceil(samples_for_coverage(
            tier.min_quality, cfg_param_millions(self.cfg),
            w.decode_tokens)))

    # ------------------------------------------------------- batch routing
    def route_batch(self, tiers: Sequence[Union[str, SLATier]],
                    samples: Optional[int] = None,
                    prompt_tokens: Optional[int] = None,
                    decode_tokens: Optional[int] = None,
                    workload_map=None
                    ) -> BatchRoutingDecision:
        """Route a mixed-tier batch to ONE shared operating point.

        Caps merge to the tightest member tier (`merge_tiers`); every
        archive point is re-costed under the batch workload before caps and
        scalarization apply, because feasibility genuinely depends on batch
        size (weight-streaming amortizes, activation traffic does not). The
        chosen point's batch energy is attributed back per tier by request
        share — the amortized per-tier cost the telemetry records. Like
        `route`, an infeasible batch degrades to the least-violating point
        flagged ``meets_caps=False`` instead of crashing.

        ``workload_map`` (Workload -> Workload) rewrites the batch workload
        before re-costing — how speculative-decode pricing enters
        (`repro.spec.routing.spec_workload` divides decode weight re-streams
        by expected accepted tokens per verify step while scaling per-query
        compute); the rewritten workload rides in ``decision.workload``.
        """
        members = [self.resolve_tier(t) for t in tiers]
        if not members:
            raise ValueError("route_batch needs at least one request")
        counts = dict(Counter(t.name for t in members))
        merged = merge_tiers(members, counts)
        pts = self.frontier
        if not pts:
            raise RuntimeError("empty frontier: no placeable operating point")
        w_b = self.batch_workload(len(members), samples,
                                  prompt_tokens, decode_tokens)
        if workload_map is not None:
            w_b = workload_map(w_b)
        costed = [self.recost(a, w_b) for a in pts]
        e_min = max(min(c.energy_j for c in costed), 1e-12)
        t_min = max(min(c.makespan_s for c in costed), 1e-12)

        def score(c: PlanCosts) -> float:
            return (merged.energy_weight * c.energy_j / e_min +
                    merged.latency_weight * c.makespan_s / t_min)

        def violation(c: PlanCosts) -> float:
            v = 0.0
            if merged.latency_p99_s is not None and \
                    c.makespan_s > merged.latency_p99_s:
                v += c.makespan_s / merged.latency_p99_s - 1.0
            if merged.energy_cap_w is not None:
                p = c.energy_j / max(c.makespan_s, 1e-12)
                if p > merged.energy_cap_w:
                    v += p / merged.energy_cap_w - 1.0
            return 0.0 if v < 1e-9 else v      # sub-ulp guard, as in route

        feasible = [i for i in range(len(pts))
                    if violation(costed[i]) == 0.0]
        notes: List[str] = []
        if feasible:
            idx = min(feasible, key=lambda i: (score(costed[i]), i))
            meets = True
        else:
            idx = min(range(len(pts)),
                      key=lambda i: (violation(costed[i]),
                                     score(costed[i]), i))
            meets = False
            notes.append(f"no archive point satisfies merged caps of "
                         f"batch {counts}; best-effort")
        chosen = costed[idx]
        total = sum(counts.values())
        per_tier = {name: chosen.energy_j * c / total
                    for name, c in counts.items()}
        return BatchRoutingDecision(
            tier=merged, tier_counts=counts, assignment=pts[idx],
            point_index=idx, meets_caps=meets, workload=w_b,
            batch_costs=chosen, per_tier_energy_j=per_tier, notes=notes)


# ======================================================= serving-side adapter

class RoutedServingEngine:
    """Thin compatibility shim: the old per-`generate` adapter API on top of
    the continuous-batching scheduler.

    Each ``generate`` call submits its prompts (one tier, per-call) into a
    private `repro.serving.ContinuousBatchingScheduler` sized so the whole
    call forms one batch per prompt-length bucket, drains it, and returns
    results in input order. The routed operating point lands in
    ``engine.last_placement`` / ``engine.placements`` exactly as before;
    ``decisions`` now holds `BatchRoutingDecision`s (one per formed batch).
    A tier's ``min_quality`` floor still raises the sampling budget — that
    moved into the scheduler's admission control.

    Migration: new code should construct the scheduler directly
    (``ContinuousBatchingScheduler(engine.backend, router)``) and ``submit``
    requests with per-request tiers — that is what unlocks mixed-tier
    batches; this shim serializes call-by-call like the pre-refactor
    engine did.
    """

    def __init__(self, engine, router: ParetoRouter,
                 default_tier: Optional[str] = None, obs=None):
        self.engine = engine
        self.router = router
        self.default_tier = default_tier
        self.obs = obs                 # forwarded to the backing scheduler
        # bounded: decisions reference full plans; cap the history so a
        # long-lived server doesn't grow with request count
        self.decisions: Deque[BatchRoutingDecision] = deque(maxlen=256)
        self._scheduler = None

    @property
    def scheduler(self):
        """The backing `ContinuousBatchingScheduler` (created on first
        use): batch records, telemetry, stats."""
        return self._sched()

    def _sched(self):
        if self._scheduler is None:
            from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                                 SchedulerConfig)
            self._scheduler = ContinuousBatchingScheduler(
                self.engine.backend, self.router,
                config=SchedulerConfig(max_batch_requests=10 ** 9,
                                       max_inflight_batches=1,
                                       max_queue_depth=None),
                obs=self.obs)
        return self._scheduler

    def generate(self, prompts, tier: Optional[Union[str, SLATier]] = None,
                 n_samples: int = 1, max_new_tokens: Optional[int] = None,
                 temperature: Optional[float] = None, rng=None,
                 extras: Optional[Dict] = None):
        """`ServingEngine.generate` semantics with frontier routing; the
        batch decision lands in ``self.decisions`` (and the operating point
        in ``engine.last_placement``)."""
        tier = tier if tier is not None else self.default_tier
        if tier is None:
            raise ValueError("no tier given and no default_tier configured")
        sched = self._sched()
        ids = []
        for i, p in enumerate(prompts):
            row = ({k: np.asarray(v)[i] for k, v in extras.items()}
                   if extras else None)
            adm = sched.submit(
                p, tier=tier, n_samples=n_samples,
                max_new_tokens=(max_new_tokens if max_new_tokens is not None
                                else self.engine.max_new_tokens),
                temperature=(temperature if temperature is not None
                             else self.engine.temperature),
                rng=rng, extras=row)
            if not adm.admitted:       # unbounded shim queue: unknown tier
                raise KeyError(adm.reason)
            ids.append(adm.request_id)
        sched.run_until_idle()
        # drain: the scheduler's completed map is the caller's to empty —
        # a long-lived shim must not accumulate every past call's results
        done = {rid: sched.completed.pop(rid) for rid in ids}
        for rid in ids:
            d = done[rid].decision
            if not any(d is seen for seen in self.decisions):
                self.decisions.append(d)
        return [done[rid].result for rid in ids]
