"""PGSAM — Pareto-Guided Simulated Annealing with Momentum (paper Section 3).

The v2 orchestrator. Where v1's `GreedyOrchestrator` commits to each stage
placement in a single myopic pass, PGSAM searches the full stage->device
mapping space with a multi-objective annealer:

* **objectives** — simultaneously minimize ``(energy_j, makespan_s,
  underutilization)``; the third term rewards spreading work across the
  platform's aggregate bandwidth instead of piling onto one efficient device.
* **Pareto guidance** — a bounded non-dominated archive steers acceptance:
  candidates that extend the archive are always accepted; dominated
  candidates are accepted with Boltzmann probability on their normalized
  worsening, so the walk can cross energy barriers early and anneals into the
  frontier as the temperature cools geometrically.
* **momentum** — move proposals are biased toward *directions* (target
  devices) that were recently accepted: heterogeneous platforms have long
  runs of stages that belong on the same device, and momentum exploits that
  correlation instead of rediscovering it one uniform move at a time.
* **seeding** — the walk starts from `GreedyOrchestrator.assign` solutions
  (several latency budgets), so PGSAM is never worse than greedy and the
  archive's hypervolume starts at the greedy sweep's.
* **convergence** — `repro.core.pareto.hypervolume_2d` over the archive's
  (energy, makespan) projection; the anneal stops when the hypervolume has
  not improved for ``hv_patience`` iterations.

Everything is deterministic under a fixed ``PGSAMConfig.seed``.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.decomposition import Stage, Workload, decompose
from repro.core.devices import DeviceProfile
from repro.core.energy import PlanCosts, plan_costs
from repro.core.orchestrator import (Assignment, Constraints,
                                     GreedyOrchestrator,
                                     constraint_violations, greedy_sla_sweep,
                                     latency_budget)
from repro.core.pareto import dominates, hypervolume_2d
from repro.models.config import ArchConfig

Mapping = Tuple[int, ...]          # stage index -> device index


@dataclass(frozen=True)
class PGSAMConfig:
    seed: int = 0
    iters_max: int = 3000
    # Boltzmann temperature is dimensionless: the barrier height is the sum
    # of *relative* objective worsenings, so t_init_frac=0.05 means "a 5%
    # worsening is accepted with prob 1/e at the start", independent of the
    # workload's absolute joule/second scale; geometric cooling per iter.
    t_init: Optional[float] = None
    t_init_frac: float = 0.05      # initial temp when t_init is None
    cooling: float = 0.998
    # probability a proposal reuses a recently-accepted target device
    momentum: float = 0.6
    momentum_window: int = 32
    archive_max: int = 64
    # convergence: stop when frontier hypervolume hasn't improved by hv_tol
    # (relative) for hv_patience consecutive iterations
    hv_patience: int = 400
    hv_check_every: int = 25
    hv_tol: float = 1e-4


@dataclass
class ArchiveEntry:
    objectives: Tuple[float, float, float]   # energy_j, makespan_s, underutil
    mapping: Mapping
    costs: PlanCosts


@dataclass
class PGSAMResult:
    archive: List[ArchiveEntry]
    best_energy: ArchiveEntry                # min-energy feasible point seen
    iterations: int
    accepted: int
    hypervolume: float
    hv_ref: Tuple[float, float]


class PGSAM:
    """The annealer itself, independent of the Assignment API (see
    `PGSAMOrchestrator` for the drop-in orchestrator wrapper)."""

    def __init__(self, stages: Sequence[Stage],
                 devices: Sequence[DeviceProfile],
                 quant: str = "bf16",
                 workload: Optional[Workload] = None,
                 config: PGSAMConfig = PGSAMConfig(),
                 memory_headroom: float = 0.9,
                 energy_model: str = "v1",
                 temps: Optional[Dict[str, float]] = None,
                 latency_budget_s: float = float("inf")):
        self.stages = list(stages)
        self.devices = list(devices)
        self.quant = quant
        self.workload = workload
        self.cfg = config
        self.headroom = memory_headroom
        self.energy_model = energy_model
        self.temps = temps
        self.latency_budget_s = latency_budget_s
        self.rng = np.random.default_rng(config.seed)
        # per-device param_bytes capacity in bytes
        self._caps = [d.mem_cap * memory_headroom for d in devices]

    # ---------------------------------------------------------------- eval
    def _mem_ok(self, mapping: Mapping) -> bool:
        used = [0.0] * len(self.devices)
        for si, di in enumerate(mapping):
            used[di] += self.stages[si].param_bytes
            if used[di] > self._caps[di]:
                return False
        return True

    def _evaluate(self, mapping: Mapping) -> ArchiveEntry:
        assign = {st.name: self.devices[di]
                  for st, di in zip(self.stages, mapping)}
        costs = plan_costs(self.stages, assign, self.quant, self.workload,
                           model=self.energy_model, temps=self.temps,
                           headroom=self.headroom)
        makespan = costs.makespan_s
        per_dev = costs.per_device_time()
        busy = sum(per_dev.values())
        n = len(self.devices)
        underutil = 1.0 - busy / (n * makespan) if makespan > 0 else 0.0
        return ArchiveEntry((costs.energy_j, makespan, underutil),
                            mapping, costs)

    def _feasible(self, entry: ArchiveEntry) -> bool:
        return entry.objectives[1] <= self.latency_budget_s

    # ------------------------------------------------------------- archive
    def _archive_insert(self, archive: List[ArchiveEntry],
                        cand: ArchiveEntry) -> bool:
        """Insert if non-dominated; prune dominated members. Returns whether
        the candidate entered the archive."""
        if any(dominates(a.objectives, cand.objectives) or
               a.objectives == cand.objectives for a in archive):
            return False
        archive[:] = [a for a in archive
                      if not dominates(cand.objectives, a.objectives)]
        archive.append(cand)
        if len(archive) > self.cfg.archive_max:
            # deterministic thinning: sort by energy, keep evenly spaced
            # points including both extremes (preserves frontier span).
            archive.sort(key=lambda a: a.objectives)
            idx = np.linspace(0, len(archive) - 1,
                              self.cfg.archive_max).round().astype(int)
            archive[:] = [archive[i] for i in sorted(set(idx.tolist()))]
        return True

    # ------------------------------------------------------------ proposal
    def _propose(self, mapping: Mapping,
                 momentum_devs: deque) -> Optional[Mapping]:
        n_stage, n_dev = len(mapping), len(self.devices)
        if n_dev < 2:
            return None
        use_momentum = (len(momentum_devs) > 0 and
                        self.rng.random() < self.cfg.momentum)
        if use_momentum:
            # repeat a recently-accepted direction: pull another stage onto
            # a device the walk has lately had success moving work to.
            di = momentum_devs[int(self.rng.integers(len(momentum_devs)))]
            cands = [si for si in range(n_stage) if mapping[si] != di]
            if not cands:
                use_momentum = False
            else:
                si = int(cands[int(self.rng.integers(len(cands)))])
                new = list(mapping)
                new[si] = di
                return tuple(new)
        si = int(self.rng.integers(n_stage))
        di = int(self.rng.integers(n_dev - 1))
        if di >= mapping[si]:
            di += 1
        new = list(mapping)
        new[si] = di
        return tuple(new)

    # ---------------------------------------------------------------- run
    def optimize(self, seeds: Sequence[Mapping]) -> PGSAMResult:
        seeds = [tuple(s) for s in seeds if self._mem_ok(tuple(s))]
        if not seeds:
            raise ValueError("no memory-feasible seed mapping")
        entries = [self._evaluate(s) for s in seeds]
        archive: List[ArchiveEntry] = []
        for e in entries:
            self._archive_insert(archive, e)

        # lexicographic: feasible beats infeasible, then min energy
        def best_key(e: ArchiveEntry) -> Tuple[bool, float]:
            return (not self._feasible(e), e.objectives[0])

        best = min(entries, key=best_key)
        current = best

        # fixed hypervolume reference: 20% beyond the worst seed objectives,
        # so 'did the frontier move' is measured against a stable yardstick.
        ref = (1.2 * max(e.objectives[0] for e in entries),
               1.2 * max(e.objectives[1] for e in entries))
        hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                             for a in archive], ref)
        last_improve = 0

        temp = (self.cfg.t_init if self.cfg.t_init is not None
                else self.cfg.t_init_frac)
        momentum_devs: deque = deque(maxlen=self.cfg.momentum_window)
        accepted = 0
        it = 0
        for it in range(1, self.cfg.iters_max + 1):
            cand_map = self._propose(current.mapping, momentum_devs)
            if cand_map is None:
                break
            if self._mem_ok(cand_map):
                cand = self._evaluate(cand_map)
                if best_key(cand) < best_key(best):
                    best = cand
                accept = self._accept(current, cand, archive, temp)
                if accept:
                    # record the accepted direction (the device that gained a
                    # stage) for momentum-biased proposals.
                    diff = [si for si, (a, b) in
                            enumerate(zip(current.mapping, cand.mapping))
                            if a != b]
                    if diff:
                        momentum_devs.append(cand.mapping[diff[0]])
                    current = cand
                    accepted += 1
            temp *= self.cfg.cooling
            if it % self.cfg.hv_check_every == 0:
                new_hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                                         for a in archive], ref)
                if new_hv > hv * (1.0 + self.cfg.hv_tol):
                    hv = new_hv
                    last_improve = it
                if it - last_improve >= self.cfg.hv_patience:
                    break

        hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                             for a in archive], ref)
        archive.sort(key=lambda a: a.objectives)
        return PGSAMResult(archive, best, it, accepted, hv, ref)

    def _accept(self, current: ArchiveEntry, cand: ArchiveEntry,
                archive: List[ArchiveEntry], temp: float) -> bool:
        entered = self._archive_insert(archive, cand)
        if dominates(cand.objectives, current.objectives):
            return True
        if entered:
            # Pareto guidance: frontier-extending moves are always taken.
            return True
        # dominated or archive-rejected: Boltzmann on the summed *relative*
        # worsening of the (energy, makespan) pair — dimensionless, so joules
        # and seconds exert comparable barriers regardless of absolute scale
        # (underutil is a tie-break objective and deliberately excluded).
        delta = 0.0
        for o_new, o_old in zip(cand.objectives[:2], current.objectives[:2]):
            if o_new > o_old:
                delta += (o_new - o_old) / max(abs(o_old), 1e-12)
        if delta <= 0:
            return True
        if temp <= 0:
            return False
        return bool(self.rng.random() < math.exp(-delta / temp))


# ===================================================== orchestrator wrapper

class PGSAMOrchestrator:
    """Drop-in replacement for `GreedyOrchestrator` (same constructor and
    `assign` / `reassign_on_failure` API) that anneals the greedy seed with
    PGSAM. `ParetoOrchestrator`, the safety monitor, examples and benches can
    swap it in unchanged; `pareto_frontier` additionally exposes the full
    non-dominated archive of a single anneal."""

    def __init__(self, devices: Sequence[DeviceProfile],
                 constraints: Constraints = Constraints(),
                 quant: str = "bf16",
                 config: PGSAMConfig = PGSAMConfig(),
                 energy_model: str = "v1",
                 safety=None):
        if not devices:
            raise ValueError("need at least one device")
        self.devices = list(devices)
        self.constraints = constraints
        self.quant = quant
        self.config = config
        self.energy_model = energy_model
        # optional repro.core.safety.SafetyMonitor: its RC thermal states feed
        # Phi (v2 energy) and its health view feeds reassign_on_failure.
        self.safety = safety
        self.last_result: Optional[PGSAMResult] = None

    # -- seeds: greedy at several latency budgets spans the frontier
    def _greedy_seeds(self, cfg: ArchConfig, workload: Workload,
                      stages: List[Stage],
                      devices: List[DeviceProfile]) -> List[Mapping]:
        dev_idx = {d.name: i for i, d in enumerate(devices)}
        seeds: List[Mapping] = []
        lat0: Optional[float] = None

        def keep(a: Assignment, is_balanced: bool = False) -> None:
            nonlocal lat0
            if a.mapping and all(st.name in a.mapping for st in stages):
                seeds.append(tuple(dev_idx[a.mapping[st.name].name]
                                   for st in stages))
                if is_balanced and lat0 is None:
                    lat0 = a.latency_s

        hr = self.constraints.memory_headroom
        # only the dedicated factor-1.0 run is "balanced" — self.constraints
        # may carry an SLA while leaving latency_budget_factor at its default
        for c, balanced in [
                (self.constraints, False),
                (Constraints(latency_budget_factor=None,
                             memory_headroom=hr), False),
                (Constraints(latency_budget_factor=1.0,
                             memory_headroom=hr), True),
                (Constraints(latency_budget_factor=0.7,
                             memory_headroom=hr), False)]:
            try:
                keep(GreedyOrchestrator(devices, c, self.quant).assign(
                    cfg, workload), is_balanced=balanced)
            except RuntimeError:
                pass
        # epsilon-constraint SLA sweep around the balanced greedy latency:
        # spans the low-latency end of the frontier, so the archive starts at
        # (and can only grow beyond) the v1 sweep's hypervolume.
        if lat0 is not None:
            for a in greedy_sla_sweep(devices, cfg, workload, lat0,
                                      self.quant, memory_headroom=hr):
                keep(a)
        return list(dict.fromkeys(seeds))      # dedupe, order-stable

    def _anneal(self, cfg: ArchConfig, workload: Workload,
                healthy: Optional[Sequence[str]]) -> Tuple[
                    List[Stage], List[DeviceProfile], PGSAMResult]:
        stages = decompose(cfg, workload)
        devices = [d for d in self.devices
                   if healthy is None or d.name in healthy]
        if not devices:
            raise RuntimeError("no healthy devices")
        seeds = self._greedy_seeds(cfg, workload, stages, devices)
        if not seeds:
            raise _Infeasible([f"no device subset fits "
                               f"{sum(s.param_bytes for s in stages)/1e9:.1f} GB"])
        temps = None
        if self.safety is not None and self.energy_model == "v2":
            temps = {n: tm.state.temp_c
                     for n, tm in self.safety.thermal.items()}
        sam = PGSAM(stages, devices, self.quant, workload,
                    config=self.config,
                    memory_headroom=self.constraints.memory_headroom,
                    energy_model=self.energy_model, temps=temps,
                    latency_budget_s=latency_budget(
                        self.constraints, stages, devices, self.quant))
        result = sam.optimize(seeds)
        self.last_result = result
        return stages, devices, result

    def assign(self, cfg: ArchConfig, workload: Workload,
               healthy: Optional[Sequence[str]] = None) -> Assignment:
        try:
            stages, devices, result = self._anneal(cfg, workload, healthy)
        except _Infeasible as e:
            return Assignment({}, None, False, e.violations)
        best = result.best_energy
        mapping = {st.name: devices[di]
                   for st, di in zip(stages, best.mapping)}
        violations = constraint_violations(self.constraints,
                                           best.objectives[1], cfg, workload)
        notes = [f"pgsam: {result.iterations} iters, "
                 f"{result.accepted} accepted, "
                 f"archive {len(result.archive)}, "
                 f"hv {result.hypervolume:.3g}"]
        return Assignment(mapping, best.costs, not violations, violations,
                          notes)

    def pareto_frontier(self, cfg: ArchConfig, workload: Workload,
                        healthy: Optional[Sequence[str]] = None
                        ) -> List[Assignment]:
        """Full non-dominated archive of one anneal, as Assignments sorted by
        energy — the multi-objective counterpart of
        `ParetoOrchestrator.frontier` from a single optimization run."""
        try:
            stages, devices, result = self._anneal(cfg, workload, healthy)
        except _Infeasible as e:
            return [Assignment({}, None, False, e.violations)]
        out = []
        for entry in result.archive:
            mapping = {st.name: devices[di]
                       for st, di in zip(stages, entry.mapping)}
            # the archive deliberately keeps SLA-violating points (they shape
            # the frontier); feasibility is re-judged per entry so callers
            # filtering on `a.feasible` never pick a violating plan.
            violations = constraint_violations(
                self.constraints, entry.objectives[1], cfg, workload)
            out.append(Assignment(mapping, entry.costs, not violations,
                                  violations,
                                  notes=[f"underutil "
                                         f"{entry.objectives[2]:.3f}"]))
        return out

    def reassign_on_failure(self, cfg: ArchConfig, workload: Workload,
                            failed: Sequence[str]) -> Assignment:
        healthy = [d.name for d in self.devices if d.name not in failed]
        return self.assign(cfg, workload, healthy=healthy)


class _Infeasible(Exception):
    def __init__(self, violations: List[str]):
        super().__init__("; ".join(violations))
        self.violations = violations
