"""PGSAM — Pareto-Guided Simulated Annealing with Momentum (paper Section 3).

The v2 orchestrator. Where v1's `GreedyOrchestrator` commits to each stage
placement in a single myopic pass, PGSAM searches the full stage->device
mapping space with a multi-objective annealer:

* **objectives** — simultaneously minimize ``(energy_j, makespan_s,
  underutilization)``; the third term rewards spreading work across the
  platform's aggregate bandwidth instead of piling onto one efficient device.
* **Pareto guidance** — a bounded non-dominated archive steers acceptance:
  candidates that extend the archive are always accepted; dominated
  candidates are accepted with Boltzmann probability on their normalized
  worsening, so the walk can cross energy barriers early and anneals into the
  frontier as the temperature cools geometrically.
* **momentum** — move proposals are biased toward *directions* (target
  devices) that were recently accepted: heterogeneous platforms have long
  runs of stages that belong on the same device, and momentum exploits that
  correlation instead of rediscovering it one uniform move at a time.
* **seeding** — the walk starts from `GreedyOrchestrator.assign` solutions
  (several latency budgets), so PGSAM is never worse than greedy and the
  archive's hypervolume starts at the greedy sweep's.
* **convergence** — `repro.core.pareto.hypervolume_2d` over the archive's
  (energy, makespan) projection; the anneal stops when the hypervolume has
  not improved for ``hv_patience`` iterations.

Everything is deterministic under a fixed ``PGSAMConfig.seed``.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.decomposition import Stage, Workload, decompose
from repro.core.devices import DeviceProfile
from repro.core.energy import PlanCosts, execute_stage, plan_costs
from repro.core.orchestrator import (Assignment, Constraints,
                                     GreedyOrchestrator,
                                     constraint_violations, greedy_sla_sweep,
                                     latency_budget)
from repro.core.pareto import dominates, hypervolume_2d
from repro.models.config import ArchConfig

Mapping = Tuple[int, ...]          # stage index -> device index


@dataclass(frozen=True)
class PGSAMConfig:
    seed: int = 0
    iters_max: int = 3000
    # Boltzmann temperature is dimensionless: the barrier height is the sum
    # of *relative* objective worsenings, so t_init_frac=0.05 means "a 5%
    # worsening is accepted with prob 1/e at the start", independent of the
    # workload's absolute joule/second scale; geometric cooling per iter.
    t_init: Optional[float] = None
    t_init_frac: float = 0.05      # initial temp when t_init is None
    cooling: float = 0.998
    # probability a proposal reuses a recently-accepted target device
    momentum: float = 0.6
    momentum_window: int = 32
    archive_max: int = 64
    # convergence: stop when frontier hypervolume hasn't improved by hv_tol
    # (relative) for hv_patience consecutive iterations
    hv_patience: int = 400
    hv_check_every: int = 25
    hv_tol: float = 1e-4
    # delta-cost evaluation (repro.qeil2.runtime.incremental): every proposal
    # is a single-stage move, so candidate objectives come from O(1)
    # accumulator updates instead of a full O(stages) plan_costs pass. The
    # objective values agree with the full path to ~1e-9 relative (float
    # summation order), so the walk may differ in the last ulp; archive
    # entries get exact full-path costs filled in after the anneal.
    incremental: bool = False


@dataclass
class ArchiveEntry:
    objectives: Tuple[float, float, float]   # energy_j, makespan_s, underutil
    mapping: Mapping
    # None only transiently inside an incremental anneal; `optimize` fills
    # every returned entry with full-path costs before returning.
    costs: Optional[PlanCosts]


@dataclass
class PGSAMResult:
    archive: List[ArchiveEntry]
    best_energy: ArchiveEntry                # min-energy feasible point seen
    iterations: int
    accepted: int
    hypervolume: float
    hv_ref: Tuple[float, float]


class PGSAM:
    """The annealer itself, independent of the Assignment API (see
    `PGSAMOrchestrator` for the drop-in orchestrator wrapper)."""

    def __init__(self, stages: Sequence[Stage],
                 devices: Sequence[DeviceProfile],
                 quant: str = "bf16",
                 workload: Optional[Workload] = None,
                 config: PGSAMConfig = PGSAMConfig(),
                 memory_headroom: float = 0.9,
                 energy_model: str = "v1",
                 temps: Optional[Dict[str, float]] = None,
                 latency_budget_s: float = float("inf"),
                 provider=None):
        if provider is not None and energy_model != "v2":
            raise ValueError("a CalibratedSignalProvider requires "
                             "energy_model='v2'")
        self.stages = list(stages)
        self.devices = list(devices)
        self.quant = quant
        self.workload = workload
        self.cfg = config
        self.headroom = memory_headroom
        self.energy_model = energy_model
        self.temps = temps
        self.latency_budget_s = latency_budget_s
        self.provider = provider
        self.rng = np.random.default_rng(config.seed)
        # per-device param_bytes capacity in bytes
        self._caps = [d.mem_cap * memory_headroom for d in devices]

    # ---------------------------------------------------------------- eval
    def _mem_ok(self, mapping: Mapping) -> bool:
        used = [0.0] * len(self.devices)
        for si, di in enumerate(mapping):
            used[di] += self.stages[si].param_bytes
            if used[di] > self._caps[di]:
                return False
        return True

    def _evaluate(self, mapping: Mapping) -> ArchiveEntry:
        assign = {st.name: self.devices[di]
                  for st, di in zip(self.stages, mapping)}
        costs = plan_costs(self.stages, assign, self.quant, self.workload,
                           model=self.energy_model, temps=self.temps,
                           headroom=self.headroom, provider=self.provider)
        makespan = costs.makespan_s
        per_dev = costs.per_device_time()
        busy = sum(per_dev.values())
        n = len(self.devices)
        underutil = 1.0 - busy / (n * makespan) if makespan > 0 else 0.0
        return ArchiveEntry((costs.energy_j, makespan, underutil),
                            mapping, costs)

    def _feasible(self, entry: ArchiveEntry) -> bool:
        return entry.objectives[1] <= self.latency_budget_s

    # ------------------------------------------------------------- archive
    def _archive_insert(self, archive: List[ArchiveEntry],
                        cand: ArchiveEntry) -> bool:
        """Insert if non-dominated; prune dominated members. Returns whether
        the candidate entered the archive."""
        if any(dominates(a.objectives, cand.objectives) or
               a.objectives == cand.objectives for a in archive):
            return False
        archive[:] = [a for a in archive
                      if not dominates(cand.objectives, a.objectives)]
        archive.append(cand)
        if len(archive) > self.cfg.archive_max:
            # deterministic thinning: sort by energy, keep evenly spaced
            # points including both extremes (preserves frontier span).
            archive.sort(key=lambda a: a.objectives)
            idx = np.linspace(0, len(archive) - 1,
                              self.cfg.archive_max).round().astype(int)
            archive[:] = [archive[i] for i in sorted(set(idx.tolist()))]
        return True

    # ------------------------------------------------------------ proposal
    def _propose(self, mapping: Mapping, momentum_devs: deque
                 ) -> Optional[Tuple[Mapping, int, int]]:
        """One single-stage move: returns (new mapping, stage, target device).
        The explicit (stage, device) pair is what lets the incremental
        evaluator apply the move in O(1)."""
        n_stage, n_dev = len(mapping), len(self.devices)
        if n_dev < 2:
            return None
        use_momentum = (len(momentum_devs) > 0 and
                        self.rng.random() < self.cfg.momentum)
        if use_momentum:
            # repeat a recently-accepted direction: pull another stage onto
            # a device the walk has lately had success moving work to.
            di = momentum_devs[int(self.rng.integers(len(momentum_devs)))]
            cands = [si for si in range(n_stage) if mapping[si] != di]
            if not cands:
                use_momentum = False
            else:
                si = int(cands[int(self.rng.integers(len(cands)))])
                new = list(mapping)
                new[si] = di
                return tuple(new), si, di
        si = int(self.rng.integers(n_stage))
        di = int(self.rng.integers(n_dev - 1))
        if di >= mapping[si]:
            di += 1
        new = list(mapping)
        new[si] = di
        return tuple(new), si, di

    # ---------------------------------------------------------------- run
    def optimize(self, seeds: Sequence[Mapping]) -> PGSAMResult:
        seeds = [tuple(s) for s in seeds if self._mem_ok(tuple(s))]
        if not seeds:
            raise ValueError("no memory-feasible seed mapping")
        entries = [self._evaluate(s) for s in seeds]
        archive: List[ArchiveEntry] = []
        for e in entries:
            self._archive_insert(archive, e)

        # lexicographic: feasible beats infeasible, then min energy
        def best_key(e: ArchiveEntry) -> Tuple[bool, float]:
            return (not self._feasible(e), e.objectives[0])

        best = min(entries, key=best_key)
        current = best

        # fixed hypervolume reference: 20% beyond the worst seed objectives,
        # so 'did the frontier move' is measured against a stable yardstick.
        ref = (1.2 * max(e.objectives[0] for e in entries),
               1.2 * max(e.objectives[1] for e in entries))
        hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                             for a in archive], ref)
        last_improve = 0

        temp = (self.cfg.t_init if self.cfg.t_init is not None
                else self.cfg.t_init_frac)
        momentum_devs: deque = deque(maxlen=self.cfg.momentum_window)
        accepted = 0
        it = 0

        # delta-cost evaluation: mirror `current` in an incremental evaluator;
        # proposals are applied speculatively and reverted on rejection.
        evalr = None
        if self.cfg.incremental:
            from repro.qeil2.runtime.incremental import DeltaEvaluator
            evalr = DeltaEvaluator(self.stages, self.devices, current.mapping,
                                   self.quant, self.workload,
                                   model=self.energy_model, temps=self.temps,
                                   headroom=self.headroom,
                                   provider=self.provider)

        for it in range(1, self.cfg.iters_max + 1):
            prop = self._propose(current.mapping, momentum_devs)
            if prop is None:
                break
            cand_map, si, di = prop
            if evalr is not None:
                # O(1) destination check: the source device only frees memory,
                # so feasibility of a single move is the destination's alone.
                mem_ok = evalr.move_fits(si, di, self._caps[di])
            else:
                mem_ok = self._mem_ok(cand_map)
            if mem_ok:
                if evalr is not None:
                    token = evalr.apply(si, di)
                    cand = ArchiveEntry(evalr.objectives(), cand_map, None)
                else:
                    token = None
                    cand = self._evaluate(cand_map)
                if best_key(cand) < best_key(best):
                    best = cand
                accept = self._accept(current, cand, archive, temp)
                if accept:
                    # record the accepted direction (the device that gained a
                    # stage) for momentum-biased proposals.
                    momentum_devs.append(di)
                    current = cand
                    accepted += 1
                elif evalr is not None:
                    evalr.revert(token)
            temp *= self.cfg.cooling
            if it % self.cfg.hv_check_every == 0:
                new_hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                                         for a in archive], ref)
                if new_hv > hv * (1.0 + self.cfg.hv_tol):
                    hv = new_hv
                    last_improve = it
                if it - last_improve >= self.cfg.hv_patience:
                    break

        hv = hypervolume_2d([(a.objectives[0], a.objectives[1])
                             for a in archive], ref)
        # incremental entries carry delta-evaluated objectives and no costs:
        # fill in the exact full-path PlanCosts for everything we return
        # (if best sits in the archive it is the same object and is covered
        # by the first loop).
        if evalr is not None:
            for entry in archive:
                if entry.costs is None:
                    full = self._evaluate(entry.mapping)
                    entry.costs = full.costs
                    entry.objectives = full.objectives
            if best.costs is None:
                full = self._evaluate(best.mapping)
                best.costs = full.costs
                best.objectives = full.objectives
        archive.sort(key=lambda a: a.objectives)
        return PGSAMResult(archive, best, it, accepted, hv, ref)

    def _accept(self, current: ArchiveEntry, cand: ArchiveEntry,
                archive: List[ArchiveEntry], temp: float) -> bool:
        entered = self._archive_insert(archive, cand)
        if dominates(cand.objectives, current.objectives):
            return True
        if entered:
            # Pareto guidance: frontier-extending moves are always taken.
            return True
        # dominated or archive-rejected: Boltzmann on the summed *relative*
        # worsening of the (energy, makespan) pair — dimensionless, so joules
        # and seconds exert comparable barriers regardless of absolute scale
        # (underutil is a tie-break objective and deliberately excluded).
        delta = 0.0
        for o_new, o_old in zip(cand.objectives[:2], current.objectives[:2]):
            if o_new > o_old:
                delta += (o_new - o_old) / max(abs(o_old), 1e-12)
        if delta <= 0:
            return True
        if temp <= 0:
            return False
        return bool(self.rng.random() < math.exp(-delta / temp))


# ===================================================== orchestrator wrapper

class PGSAMOrchestrator:
    """Drop-in replacement for `GreedyOrchestrator` (same constructor and
    `assign` / `reassign_on_failure` API) that anneals the greedy seed with
    PGSAM. `ParetoOrchestrator`, the safety monitor, examples and benches can
    swap it in unchanged; `pareto_frontier` additionally exposes the full
    non-dominated archive of a single anneal."""

    def __init__(self, devices: Sequence[DeviceProfile],
                 constraints: Constraints = Constraints(),
                 quant: str = "bf16",
                 config: PGSAMConfig = PGSAMConfig(),
                 energy_model: str = "v1",
                 safety=None,
                 provider=None):
        if not devices:
            raise ValueError("need at least one device")
        if provider is not None and energy_model != "v2":
            raise ValueError("a CalibratedSignalProvider requires "
                             "energy_model='v2'")
        self.devices = list(devices)
        self.constraints = constraints
        self.quant = quant
        self.config = config
        self.energy_model = energy_model
        # optional repro.qeil2.telemetry.CalibratedSignalProvider: fitted
        # coefficients + measured kernel times for every v2 plan costing
        # (anneals, re-anneals, frontier materialization).
        self.provider = provider
        # optional repro.core.safety.SafetyMonitor: its RC thermal states feed
        # Phi (v2 energy) and its health view feeds reassign_on_failure.
        self.safety = safety
        self.last_result: Optional[PGSAMResult] = None
        # frontier archive cache: `pareto_frontier` memoizes per (cfg,
        # workload, healthy-set, health epoch). The epoch is the invalidation
        # handle — drift events (thermal margin crossings, failures, CPQ
        # saturation) bump it via `on_drift` / `invalidate_frontier`, so a
        # stale frontier is never served after the world has moved.
        self.health_epoch = 0
        self._frontier_cache: Dict[tuple, List[Assignment]] = {}

    # -- seeds: greedy at several latency budgets spans the frontier
    def _greedy_seeds(self, cfg: ArchConfig, workload: Workload,
                      stages: List[Stage],
                      devices: List[DeviceProfile]) -> List[Mapping]:
        dev_idx = {d.name: i for i, d in enumerate(devices)}
        seeds: List[Mapping] = []
        lat0: Optional[float] = None

        def keep(a: Assignment, is_balanced: bool = False) -> None:
            nonlocal lat0
            if a.mapping and all(st.name in a.mapping for st in stages):
                seeds.append(tuple(dev_idx[a.mapping[st.name].name]
                                   for st in stages))
                if is_balanced and lat0 is None:
                    lat0 = a.latency_s

        hr = self.constraints.memory_headroom
        # only the dedicated factor-1.0 run is "balanced" — self.constraints
        # may carry an SLA while leaving latency_budget_factor at its default
        for c, balanced in [
                (self.constraints, False),
                (Constraints(latency_budget_factor=None,
                             memory_headroom=hr), False),
                (Constraints(latency_budget_factor=1.0,
                             memory_headroom=hr), True),
                (Constraints(latency_budget_factor=0.7,
                             memory_headroom=hr), False)]:
            try:
                keep(GreedyOrchestrator(devices, c, self.quant).assign(
                    cfg, workload), is_balanced=balanced)
            except RuntimeError:
                pass
        # epsilon-constraint SLA sweep around the balanced greedy latency:
        # spans the low-latency end of the frontier, so the archive starts at
        # (and can only grow beyond) the v1 sweep's hypervolume.
        if lat0 is not None:
            for a in greedy_sla_sweep(devices, cfg, workload, lat0,
                                      self.quant, memory_headroom=hr):
                keep(a)
        return list(dict.fromkeys(seeds))      # dedupe, order-stable

    def _anneal(self, cfg: ArchConfig, workload: Workload,
                healthy: Optional[Sequence[str]]) -> Tuple[
                    List[Stage], List[DeviceProfile], PGSAMResult]:
        stages = decompose(cfg, workload)
        devices = [d for d in self.devices
                   if healthy is None or d.name in healthy]
        if not devices:
            raise RuntimeError("no healthy devices")
        seeds = self._greedy_seeds(cfg, workload, stages, devices)
        if not seeds:
            raise _Infeasible([f"no device subset fits "
                               f"{sum(s.param_bytes for s in stages)/1e9:.1f} GB"])
        temps = None
        if self.safety is not None and self.energy_model == "v2":
            temps = {n: tm.state.temp_c
                     for n, tm in self.safety.thermal.items()}
        sam = PGSAM(stages, devices, self.quant, workload,
                    config=self.config,
                    memory_headroom=self.constraints.memory_headroom,
                    energy_model=self.energy_model, temps=temps,
                    latency_budget_s=latency_budget(
                        self.constraints, stages, devices, self.quant),
                    provider=self.provider)
        result = sam.optimize(seeds)
        self.last_result = result
        return stages, devices, result

    def assign(self, cfg: ArchConfig, workload: Workload,
               healthy: Optional[Sequence[str]] = None) -> Assignment:
        try:
            stages, devices, result = self._anneal(cfg, workload, healthy)
        except _Infeasible as e:
            return Assignment({}, None, False, e.violations)
        best = result.best_energy
        mapping = {st.name: devices[di]
                   for st, di in zip(stages, best.mapping)}
        violations = constraint_violations(self.constraints,
                                           best.objectives[1], cfg, workload)
        notes = [f"pgsam: {result.iterations} iters, "
                 f"{result.accepted} accepted, "
                 f"archive {len(result.archive)}, "
                 f"hv {result.hypervolume:.3g}"]
        return Assignment(mapping, best.costs, not violations, violations,
                          notes)

    # ---------------------------------------------------- frontier caching
    def _frontier_key(self, cfg: ArchConfig, workload: Workload,
                      healthy: Optional[Sequence[str]]) -> tuple:
        # a (frozen, hashable) CalibrationProfile participates directly: a
        # refitted profile is a different key, so stale-calibration archives
        # are never served.
        profile = (self.provider.profile if self.provider is not None
                   else None)
        return (cfg.name, repr(cfg), workload,
                tuple(sorted(healthy)) if healthy is not None else None,
                self.quant, self.energy_model, self.health_epoch, profile)

    def invalidate_frontier(self) -> None:
        """Bump the device-health epoch and drop every cached archive. Called
        by the runtime control loop when signals drift (and usable directly
        after out-of-band device/thermal changes)."""
        self.health_epoch += 1
        self._frontier_cache.clear()

    def on_drift(self, event) -> None:
        """`repro.core.safety.SafetyMonitor.subscribe` target: any drift
        event invalidates the cached frontier (the archive was annealed
        against the pre-drift temperatures / health set)."""
        self.invalidate_frontier()

    def _materialize(self, stages: List[Stage],
                     devices: List[DeviceProfile], result: PGSAMResult,
                     cfg: ArchConfig, workload: Workload) -> List[Assignment]:
        out = []
        for entry in result.archive:
            mapping = {st.name: devices[di]
                       for st, di in zip(stages, entry.mapping)}
            # the archive deliberately keeps SLA-violating points (they shape
            # the frontier); feasibility is re-judged per entry so callers
            # filtering on `a.feasible` never pick a violating plan.
            violations = constraint_violations(
                self.constraints, entry.objectives[1], cfg, workload)
            out.append(Assignment(mapping, entry.costs, not violations,
                                  violations,
                                  notes=[f"underutil "
                                         f"{entry.objectives[2]:.3f}"]))
        return out

    def pareto_frontier(self, cfg: ArchConfig, workload: Workload,
                        healthy: Optional[Sequence[str]] = None
                        ) -> List[Assignment]:
        """Full non-dominated archive of one anneal, as Assignments sorted by
        energy — the multi-objective counterpart of
        `ParetoOrchestrator.frontier` from a single optimization run.

        Memoized on (cfg, workload, healthy, health_epoch): repeated routing
        queries against an unchanged world reuse the archive instead of
        re-annealing; `invalidate_frontier` (or any drift event delivered to
        `on_drift`) forces the next call to anneal fresh."""
        key = self._frontier_key(cfg, workload, healthy)
        hit = self._frontier_cache.get(key)
        if hit is not None:
            return hit
        try:
            stages, devices, result = self._anneal(cfg, workload, healthy)
        except _Infeasible as e:
            return [Assignment({}, None, False, e.violations)]
        out = self._materialize(stages, devices, result, cfg, workload)
        self._frontier_cache[key] = out
        return out

    # ------------------------------------------------- online re-annealing
    def _patch_mapping(self, mapping: Dict[str, DeviceProfile],
                       stages: List[Stage], devices: List[DeviceProfile],
                       caps: List[float]) -> Optional[Mapping]:
        """Repair a warm-start mapping for the current device subset: stages
        stranded on excluded devices (failed / cooling) move to the fitting
        device with the cheapest per-stage energy. Returns None when the
        mapping cannot be made memory-feasible."""
        dev_idx = {d.name: i for i, d in enumerate(devices)}
        used = [0.0] * len(devices)
        out: List[int] = []
        for st in stages:
            dev = mapping.get(st.name)
            di = dev_idx.get(dev.name) if dev is not None else None
            if di is not None and used[di] + st.param_bytes <= caps[di]:
                used[di] += st.param_bytes
                out.append(di)
                continue
            cands = [(execute_stage(st, devices[j], self.quant).energy_j, j)
                     for j in range(len(devices))
                     if used[j] + st.param_bytes <= caps[j]]
            if not cands:
                return None
            _, di = min(cands)
            used[di] += st.param_bytes
            out.append(di)
        return tuple(out)

    def reanneal(self, cfg: ArchConfig, workload: Workload,
                 warm_starts: Sequence[Dict[str, DeviceProfile]],
                 healthy: Optional[Sequence[str]] = None,
                 iters_max: Optional[int] = None) -> Assignment:
        """Bounded online re-anneal, warm-started from previously-annealed
        mappings (the current assignment plus the archive) instead of greedy
        seeds — the control loop's fast path after a drift event.

        Mappings that reference excluded devices are repaired stage-by-stage;
        ``iters_max`` bounds the walk (default: the configured budget). The
        refreshed archive replaces the cached frontier for this (cfg,
        workload, healthy) at the *current* epoch, so routers pick it up
        without a second anneal."""
        stages = decompose(cfg, workload)
        devices = [d for d in self.devices
                   if healthy is None or d.name in healthy]
        if not devices:
            raise RuntimeError("no healthy devices")
        caps = [d.mem_cap * self.constraints.memory_headroom for d in devices]
        seeds = []
        for m in warm_starts:
            s = self._patch_mapping(m, stages, devices, caps)
            if s is not None:
                seeds.append(s)
        seeds = list(dict.fromkeys(seeds))
        if not seeds:
            # nothing survives the device change: fall back to greedy seeding
            return self.assign(cfg, workload, healthy=healthy)
        cfg_sam = self.config if iters_max is None else \
            replace(self.config, iters_max=iters_max)
        temps = None
        if self.safety is not None and self.energy_model == "v2":
            temps = {n: tm.state.temp_c
                     for n, tm in self.safety.thermal.items()}
        sam = PGSAM(stages, devices, self.quant, workload,
                    config=cfg_sam,
                    memory_headroom=self.constraints.memory_headroom,
                    energy_model=self.energy_model, temps=temps,
                    latency_budget_s=latency_budget(
                        self.constraints, stages, devices, self.quant),
                    provider=self.provider)
        result = sam.optimize(seeds)
        self.last_result = result
        # the world changed enough to warrant a re-anneal, so any archive a
        # router pulled earlier is stale: bump the epoch first, then publish
        # the refreshed archive at the new epoch (routers key on the epoch,
        # not on cache object identity)
        self.invalidate_frontier()
        key = self._frontier_key(cfg, workload, healthy)
        self._frontier_cache[key] = self._materialize(
            stages, devices, result, cfg, workload)
        best = result.best_energy
        mapping = {st.name: devices[di]
                   for st, di in zip(stages, best.mapping)}
        violations = constraint_violations(self.constraints,
                                           best.objectives[1], cfg, workload)
        notes = [f"reanneal: {result.iterations} iters, "
                 f"{len(seeds)} warm seeds, archive {len(result.archive)}"]
        return Assignment(mapping, best.costs, not violations, violations,
                          notes)

    def reassign_on_failure(self, cfg: ArchConfig, workload: Workload,
                            failed: Sequence[str]) -> Assignment:
        healthy = [d.name for d in self.devices if d.name not in failed]
        return self.assign(cfg, workload, healthy=healthy)


class _Infeasible(Exception):
    def __init__(self, violations: List[str]):
        super().__init__("; ".join(violations))
        self.violations = violations
