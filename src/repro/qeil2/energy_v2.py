"""Unified v2 energy equation: DASI/CPQ/Phi-modulated dynamic power.

v1 (`repro.core.energy.execute_stage`) models a stage's dynamic power as

    p_v1 = (P_peak - P_idle) * util * lambda_eff * (0.55 + 0.45 * busy_frac)

where ``0.55 + 0.45 * busy_frac`` is a *static* activity heuristic: even a
fully memory-bound stage is charged 55% of peak dynamic power. v2 replaces the
heuristic with the physics-grounded signal triple of `repro.qeil2.signals`:

    p_dyn = (P_peak - P_idle) * util * lambda_eff
            * (W_COMPUTE * DASI + W_MEMORY * MSAT)     # subsystem duty cycles
            * (1 + CPQ_KAPPA * CPQ^2)                  # memory-pressure tax
    E     = t_roofline * p_dyn * f(Q) / Phi(T)         # leakage overhead

Coefficients (all documented at their definition):

* ``W_COMPUTE`` / ``W_MEMORY`` — the split of dynamic power between the
  compute datapath and the memory subsystem at full duty. 0.7/0.3 follows the
  standard accelerator power breakdown (MAC arrays and register files dominate;
  DRAM+controller draw ~30% at peak streaming).
* CPQ/Phi coefficients — see `repro.qeil2.signals`.

The v1 path stays untouched and remains the default everywhere
(``plan_costs(..., model="v1")``); v2 is opt-in via the ``model`` flag so the
seed benchmarks stay reproducible bit-for-bit.

Speculative decode is priced upstream, not here: `repro.spec.spec_workload`
rescales the decode-phase Workload (weight re-streams amortized across
``tokens_per_step`` committed tokens, per-query traffic multiplied by the
query factor) before `repro.core.decompose` builds the stages, so the FLOP/
byte counts arriving in each Stage already reflect drafting — DASI of decode
stages rises as verify batching lifts arithmetic intensity, and the shared
`boundary_transfer_bytes` scales cross-device decode activations by
``Workload.spec_query_factor``. No equation in this module changes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.decomposition import Stage, Workload
from repro.core.devices import DeviceProfile
from repro.core.energy import (PlanCosts, StageExecution,
                               TRANSFER_ENERGY_PER_BYTE,
                               boundary_transfer_bytes)
from repro.core.formalisms import quant_factor
from repro.qeil2.signals import SignalSet, cpq_power_factor, signals_for

# Dynamic-power split between compute datapath and memory subsystem at full
# duty cycle (see module docstring for provenance).
W_COMPUTE = 0.70
W_MEMORY = 0.30


@dataclass
class StageExecutionV2(StageExecution):
    """StageExecution plus the signal triple that produced its energy."""
    signals: Optional[SignalSet] = None


def execute_stage_v2(stage: Stage, device: DeviceProfile,
                     quant: str = "bf16",
                     throttle: float = 1.0,
                     resident_bytes: float = 0.0,
                     temp_c: Optional[float] = None,
                     headroom: float = 0.9,
                     provider=None) -> StageExecutionV2:
    """Roofline time (identical to v1) + DASI/CPQ/Phi-modulated energy.

    ``resident_bytes`` — device working set under the candidate assignment
    (drives CPQ); ``temp_c`` — device junction temperature from the safety
    monitor's RC model (drives Phi; ambient when None). ``provider`` — an
    optional `repro.qeil2.telemetry.CalibratedSignalProvider`: signals come
    from its fitted coefficients, and where a measured Pallas kernel backs
    the stage, execution time stretches to the measured time (roofline /
    eta) while both duty cycles shrink by eta. ``provider=None`` is the
    analytic path, bit-for-bit unchanged.
    """
    eff = device.util * throttle
    t_c = stage.flops / (device.peak_flops * eff)
    t_m = stage.bytes_moved / (device.mem_bw * eff)
    t = max(t_c, t_m)
    if provider is None:
        sig = signals_for(stage, device, resident_bytes, temp_c, headroom)
        cpq_factor = cpq_power_factor(sig.cpq)
    else:
        sig = provider.signals_for(stage, device, resident_bytes, temp_c,
                                   headroom)
        cpq_factor = provider.cpq_power_factor(sig.cpq)
        t = t * provider.time_scale(stage)
    activity = W_COMPUTE * sig.dasi + W_MEMORY * sig.msat
    p_dyn = (device.power_peak - device.power_idle) * device.util * \
        device.lambda_eff * activity * cpq_factor * throttle
    energy = t * p_dyn * quant_factor(quant) / sig.phi
    return StageExecutionV2(stage, device, t, energy,
                            "compute" if t_c >= t_m else "memory",
                            signals=sig)


def plan_costs_v2(stages: List[Stage],
                  assignment: Dict[str, DeviceProfile],
                  quant: str = "bf16",
                  workload: Optional[Workload] = None,
                  throttle: Optional[Dict[str, float]] = None,
                  temps: Optional[Dict[str, float]] = None,
                  headroom: float = 0.9,
                  provider=None) -> PlanCosts:
    """v2 counterpart of `repro.core.energy.plan_costs`.

    Resident bytes per device are accumulated from the full assignment first,
    so every stage on a device sees the same (final) capacity pressure — the
    steady-state working set, which is what the allocator actually holds
    during pipelined execution. ``temps`` maps device name -> junction degC
    (e.g. from ``SafetyMonitor.thermal[...].state.temp_c``); ``provider``
    an optional `repro.qeil2.telemetry.CalibratedSignalProvider` (fitted
    coefficients + measured kernel times; None = analytic, bit-for-bit).
    """
    throttle = throttle or {}
    temps = temps or {}
    resident: Dict[str, float] = {}
    for st in stages:
        dev = assignment[st.name]
        resident[dev.name] = resident.get(dev.name, 0.0) + st.param_bytes

    execs: List[StageExecution] = []
    for st in stages:
        dev = assignment[st.name]
        execs.append(execute_stage_v2(
            st, dev, quant,
            throttle=throttle.get(dev.name, 1.0),
            resident_bytes=resident[dev.name],
            temp_c=temps.get(dev.name),
            headroom=headroom,
            provider=provider))

    transfer_bytes = boundary_transfer_bytes(execs, workload)
    link_bw = min(d.link_bw for d in assignment.values())
    t_io = transfer_bytes / link_bw if transfer_bytes else 0.0
    e_io = transfer_bytes * TRANSFER_ENERGY_PER_BYTE
    return PlanCosts(execs, transfer_bytes, t_io, e_io,
                     devices=list({d.name: d
                                   for d in assignment.values()}.values()))
