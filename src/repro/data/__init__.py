from repro.data.pipeline import (ArithGenerator, CopyGenerator, DataConfig,
                                 MarkovGenerator, data_iterator, make_generator)

__all__ = ["DataConfig", "MarkovGenerator", "ArithGenerator", "CopyGenerator",
           "data_iterator", "make_generator"]
