"""Deterministic synthetic LM data pipeline.

No external datasets exist in this environment, so the pipeline generates
structured synthetic corpora:

* ``markov``  — an order-2 Markov chain over the vocabulary with a sparse,
  seeded transition table. Learnable: a model reduces loss well below uniform
  because transitions are low-entropy. This is the stand-in for WikiText-103.
* ``arith``   — tokenized modular-arithmetic problems "a+b=c" with a verifiable
  answer. Pass@k over this task drives the coverage/repeated-sampling benches
  (the stand-in for GSM8K), via ``repro.core.sampling``.
* ``copy``    — needle-in-haystack copy task exercising long-context recall.

Batches are dicts {"tokens", "labels"} with labels already shifted; every batch
is a pure function of (seed, step), so multi-host sharding is trivial (each data
shard draws its slice of the global batch deterministically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    kind: str = "markov"        # markov | arith | copy
    seed: int = 0
    n_codebooks: int = 1        # musicgen
    branching: int = 4          # markov out-degree


class MarkovGenerator:
    """Order-2 Markov chain with `branching` successors per state pair."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        self.n_states = min(V * 8, 65536)
        self.succ = rng.integers(0, V, size=(self.n_states, cfg.branching),
                                 dtype=np.int32)
        self.probs = rng.dirichlet(np.ones(cfg.branching) * 0.5,
                                   size=self.n_states).astype(np.float32)

    def _state(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return (a * 31 + b * 7) % self.n_states

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, :2] = rng.integers(0, cfg.vocab_size, size=(B, 2))
        for t in range(2, S + 1):
            st = self._state(toks[:, t - 2], toks[:, t - 1])
            choice = (rng.random(B)[:, None] >
                      np.cumsum(self.probs[st], -1)).sum(-1)
            choice = np.minimum(choice, cfg.branching - 1)
            toks[:, t] = self.succ[st, choice]
        return _finish(toks, cfg)


class ArithGenerator:
    """`a + b = c (mod m)` sequences; answer verifiable by re-parsing.

    Token layout per problem (digits=2):
        [a_hi a_lo PLUS b_hi b_lo EQ c_hi c_lo SEP]
    or (digits=1, the easy variant used by fast tests):
        [a PLUS b EQ c SEP]
    Digits are base-`base` tokens; special tokens live at the top of the vocab.
    """

    def __init__(self, cfg: DataConfig, digits: int = 1):
        self.cfg = cfg
        self.digits = digits
        self.base = max(2, min(cfg.vocab_size - 3, 10))
        self.PLUS = cfg.vocab_size - 3
        self.EQ = cfg.vocab_size - 2
        self.SEP = cfg.vocab_size - 1
        self.mod = self.base ** digits

    def _digits_of(self, x: int) -> list:
        out = []
        for i in reversed(range(self.digits)):
            out.append((x // self.base ** i) % self.base)
        return out

    def problem(self, rng) -> Tuple[np.ndarray, int]:
        a = int(rng.integers(0, self.mod))
        b = int(rng.integers(0, self.mod))
        c = (a + b) % self.mod
        seq = (self._digits_of(a) + [self.PLUS] + self._digits_of(b) +
               [self.EQ] + self._digits_of(c) + [self.SEP])
        return np.array(seq, np.int32), c

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 1))
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.zeros((B, S + 1), np.int32)
        for i in range(B):
            buf = []
            while len(buf) < S + 1:
                seq, _ = self.problem(rng)
                buf.extend(seq.tolist())
            toks[i] = np.array(buf[: S + 1], np.int32)
        return _finish(toks, cfg)

    # -- verification used by the sampling engine's cascade
    def answer_of_prompt(self, a: int, b: int) -> int:
        return (a + b) % self.mod

    def make_prompt(self, rng) -> Tuple[np.ndarray, int]:
        """Prompt ends right after EQ; target is the answer digits."""
        a = int(rng.integers(0, self.mod))
        b = int(rng.integers(0, self.mod))
        prompt = np.array(self._digits_of(a) + [self.PLUS] +
                          self._digits_of(b) + [self.EQ], np.int32)
        return prompt, (a + b) % self.mod

    def verify(self, completion: np.ndarray, answer: int) -> bool:
        if completion.shape[0] < self.digits:
            return False
        got = 0
        for i in range(self.digits):
            got = got * self.base + int(completion[i])
        return got == answer


class CopyGenerator:
    """needle copy: [needle ... SEP needle] — long-range recall."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.SEP = cfg.vocab_size - 1

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 2))
        B, S = cfg.batch_size, cfg.seq_len
        toks = rng.integers(0, cfg.vocab_size - 1,
                            size=(B, S + 1)).astype(np.int32)
        klen = min(8, S // 4)
        toks[:, -klen - 1] = self.SEP
        toks[:, -klen:] = toks[:, :klen]
        return _finish(toks, cfg)


def _finish(toks: np.ndarray, cfg: DataConfig) -> Dict[str, jnp.ndarray]:
    inp, lab = toks[:, :-1], toks[:, 1:]
    if cfg.n_codebooks > 1:
        inp = np.stack([(inp + k * 7) % cfg.vocab_size
                        for k in range(cfg.n_codebooks)], axis=-1)
        lab = np.stack([(lab + k * 7) % cfg.vocab_size
                        for k in range(cfg.n_codebooks)], axis=-1)
    return {"tokens": jnp.asarray(inp), "labels": jnp.asarray(lab)}


_GENS = {"markov": MarkovGenerator, "arith": ArithGenerator,
         "copy": CopyGenerator}


def make_generator(cfg: DataConfig):
    return _GENS[cfg.kind](cfg)


def data_iterator(cfg: DataConfig, start_step: int = 0
                  ) -> Iterator[Dict[str, jnp.ndarray]]:
    gen = make_generator(cfg)
    step = start_step
    while True:
        yield gen.batch(step)
        step += 1
