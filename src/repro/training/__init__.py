from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_schedule,
                                      opt_state_specs)
from repro.training.loop import make_train_step, train
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule",
           "opt_state_specs", "make_train_step", "train", "save_checkpoint",
           "restore_checkpoint", "latest_checkpoint"]
