"""Checkpointing: msgpack-serialized pytrees with shape/dtype manifest.

No orbax in this environment; this implements the standard pattern — flatten the
pytree to (path, array) pairs, save raw bytes + a manifest, restore with validation.
Atomic via write-to-tmp + rename.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, step: int, params: Any,
                    opt_state: Any = None) -> str:
    os.makedirs(path, exist_ok=True)
    payload = {"params": _flatten(params)}
    if opt_state is not None:
        payload["opt_state"] = _flatten(opt_state)
    manifest = {
        "step": step,
        "arrays": {
            f"{group}:{k}": {"shape": list(v.shape), "dtype": str(v.dtype)}
            for group, arrs in payload.items() for k, v in arrs.items()
        },
    }
    tmp = tempfile.mkdtemp(dir=path)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"{g}:{k}": v for g, arrs in payload.items()
                for k, v in arrs.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(path, f"step_{step:08d}")
    if os.path.exists(final):
        raise FileExistsError(final)
    os.rename(tmp, final)
    return final


def latest_checkpoint(path: str) -> str | None:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    return os.path.join(path, steps[-1]) if steps else None


def restore_checkpoint(ckpt_dir: str, params_template: Any,
                       opt_template: Any = None
                       ) -> Tuple[int, Any, Any]:
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(ckpt_dir, "arrays.npz"))

    def rebuild(template: Any, group: str) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = arrays[f"{group}:{key}"]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: checkpoint {arr.shape} != model {want}")
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = rebuild(params_template, "params")
    opt_state = rebuild(opt_template, "opt_state") if opt_template is not None else None
    return manifest["step"], params, opt_state
