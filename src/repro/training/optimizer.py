"""AdamW + learning-rate schedules, from scratch (no optax in this environment).

The optimizer state is a pytree mirroring params (m, v moments in f32 regardless of
param dtype — the standard mixed-precision recipe), plus a scalar step counter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def init_opt_state(params: Any) -> Dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs: Any) -> Dict:
    zeros = lambda p: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return {"m": zeros(param_specs), "v": zeros(param_specs),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: Dict) -> Tuple[Any, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
