"""Training loop: jitted train_step factory, metrics, host loop.

``make_train_step`` is the function the multi-pod dry-run lowers for the
``train_4k`` shape: (params, opt_state, batch) -> (params, opt_state, metrics).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_loss_fn(model: Model) -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """microbatches > 1: gradient accumulation via lax.scan — the global batch
    splits into `microbatches` slices processed sequentially, dividing peak
    activation memory by the same factor at the cost of `microbatches` weight
    passes (§Perf pair 2's memory-term optimization)."""
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches) +
                                 x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                g_sum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    g_sum, grads)
                return (loss_sum + loss / microbatches, g_sum), None

            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero_grads), micro)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(model: Model, opt_cfg: AdamWConfig, data_iter, n_steps: int,
          params=None, rng=None, log_every: int = 10,
          checkpoint_fn: Optional[Callable] = None,
          checkpoint_every: int = 0) -> Tuple[Any, Dict]:
    """Single-host training loop (the examples / smoke tests use this; the
    multi-pod launcher in repro.launch.train shards the same train_step)."""
    if params is None:
        params = model.init(rng if rng is not None else jax.random.key(0))
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    history = []
    t0 = time.perf_counter()
    for step in range(n_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
        if checkpoint_fn and checkpoint_every and (step + 1) % checkpoint_every == 0:
            checkpoint_fn(step + 1, params, opt_state)
    return params, {"history": history,
                    "final_loss": history[-1]["loss"] if history else None}
