"""Draft policies for speculative decode.

A draft policy proposes ``n`` continuation tokens per sequence from the
request's committed token history; the backend then scores all of them (plus
the last committed token) in ONE verify forward and keeps the longest
accepted prefix (`repro.spec.verify`). Two policies ship behind the
`DraftPolicy` protocol:

* `NGramDraftPolicy` — model-free self-speculation (prompt lookup): the
  longest recent suffix of the sequence's own history that re-occurs earlier
  predicts its historical continuation. Zero extra FLOPs; accept rate is
  whatever self-similarity the stream actually has.
* `DraftModelPolicy` — a (smaller) model from the serving zoo rolls out
  greedily over the committed context. Stateless by construction: every
  propose left-pads contexts into a fixed width bucket and runs cache-free
  forwards, so there is no draft-side KV cache to roll back on rejection and
  jit recompiles are bounded by the bucket count.

Policies are host-side (numpy in / numpy out); only the verify forward runs
against the target model's paged cache.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.models.config import ArchConfig


@runtime_checkable
class DraftPolicy(Protocol):
    """Proposes draft continuations from per-sequence token histories."""
    name: str

    def propose(self, histories: Sequence[np.ndarray],
                n: int) -> np.ndarray:
        """histories: one 1-D int array per sequence (prompt + committed
        tokens, oldest first). Returns proposed continuations (B, n) int32.
        Proposals are *deterministic* given the histories — the verify step's
        accept/reject treats them as point-mass distributions."""
        ...


def spec_supported(cfg: ArchConfig) -> bool:
    """Speculative verify covers the same shape of stack as paged caching
    plus single-codebook heads: every mixer is attention (SSM state updates
    are inherently one-token-sequential), no MLA, no sliding window (a ring
    cache of width ``window`` would let a verify step's tail writes evict
    slots earlier query tokens in the same step still attend to), no
    cross-attention, one codebook."""
    return (all(m == "a" for m in cfg.pattern)
            and cfg.mla is None
            and cfg.attn_window is None
            and not cfg.cross_attention
            and cfg.n_codebooks == 1)


class NGramDraftPolicy:
    """Self-speculative prompt-lookup drafting.

    For each sequence, find the longest suffix (length ``max_ngram`` down to
    ``min_ngram``) of its history that also occurs earlier, and propose the
    tokens that followed that earlier occurrence (latest match wins — recent
    repetition is the better predictor). Falls back to repeating the last
    token, which the verify step then rejects at the model's discretion:
    a bad draft costs compute, never correctness.
    """

    def __init__(self, max_ngram: int = 4, min_ngram: int = 1):
        if min_ngram < 1 or max_ngram < min_ngram:
            raise ValueError(f"bad ngram range [{min_ngram}, {max_ngram}]")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.name = "ngram"

    def propose(self, histories: Sequence[np.ndarray],
                n: int) -> np.ndarray:
        out = np.zeros((len(histories), n), np.int32)
        for b, h in enumerate(histories):
            out[b] = self._propose_one(np.asarray(h, np.int64).ravel(), n)
        return out

    def _propose_one(self, h: np.ndarray, n: int) -> np.ndarray:
        draft = np.zeros((n,), np.int32)
        L = len(h)
        if L == 0:
            return draft
        draft[:] = h[-1]                      # fallback: repeat last token
        for k in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            sfx = h[L - k:]
            # latest earlier occurrence whose continuation is non-empty
            for i in range(L - k - 1, -1, -1):
                if np.array_equal(h[i:i + k], sfx):
                    cont = h[i + k:i + k + n]
                    draft[:len(cont)] = cont
                    if 0 < len(cont) < n:
                        draft[len(cont):] = cont[-1]
                    return draft
        return draft


class DraftModelPolicy:
    """Greedy rollout of a draft model (usually a reduced config from the
    same zoo) over the committed context.

    Layout per propose: contexts right-align into a fixed-width bucket with
    ``n`` rollout columns on the right; pad columns carry negative positions,
    which `repro.models.attention.causal_mask` masks out, so padding never
    leaks into real positions. One jitted cache-free forward per rollout
    column, recompiled only per (bucket width, n) pair.
    """

    def __init__(self, model, params, bucket: int = 64):
        import jax
        self.model = model
        self.params = params
        self.bucket = max(int(bucket), 8)
        self.name = "draft"
        self._rollout = jax.jit(self._rollout_impl,
                                static_argnames=("start_col", "n"))

    def _rollout_impl(self, params, toks, positions, *, start_col: int,
                      n: int):
        import jax
        import jax.numpy as jnp

        def body(j, t):
            logits, _, _ = self.model.forward(params, {"tokens": t,
                                                       "positions": positions})
            lg = jnp.take(logits.astype(jnp.float32), start_col - 1 + j,
                          axis=1)                      # (B, V)
            nxt = jnp.argmax(lg, axis=-1).astype(t.dtype)
            return jax.lax.dynamic_update_slice(t, nxt[:, None],
                                                (0, start_col + j))

        toks = jax.lax.fori_loop(0, n, body, toks)
        return jax.lax.dynamic_slice(
            toks, (0, start_col), (toks.shape[0], n))

    def propose(self, histories: Sequence[np.ndarray],
                n: int) -> np.ndarray:
        import jax.numpy as jnp
        B = len(histories)
        hs = [np.asarray(h, np.int64).ravel() for h in histories]
        l_max = max((len(h) for h in hs), default=0)
        width = -(-(l_max + n) // self.bucket) * self.bucket
        start_col = width - n
        toks = np.zeros((B, width), np.int32)
        positions = np.zeros((B, width), np.int32)
        for b, h in enumerate(hs):
            L = len(h)
            toks[b, start_col - L:start_col] = h
            positions[b] = np.arange(width) - (start_col - L)
        out = self._rollout(self.params, jnp.asarray(toks),
                            jnp.asarray(positions), start_col=start_col, n=n)
        return np.asarray(out, np.int32)


def make_draft_policy(kind: str, *, draft_model=None, draft_params=None,
                      max_ngram: int = 4,
                      bucket: int = 64) -> Optional[DraftPolicy]:
    """Policy factory for the launcher / benches: ``off`` -> None,
    ``ngram`` -> `NGramDraftPolicy`, ``draft`` -> `DraftModelPolicy`
    (requires the draft model + params)."""
    if kind == "off":
        return None
    if kind == "ngram":
        return NGramDraftPolicy(max_ngram=max_ngram)
    if kind == "draft":
        if draft_model is None or draft_params is None:
            raise ValueError("draft policy needs draft_model and draft_params")
        return DraftModelPolicy(draft_model, draft_params, bucket=bucket)
    raise ValueError(f"unknown draft policy {kind!r}")
