"""Distribution-preserving accept/reject for speculative verify.

One verify forward scores S = n + 1 query tokens — the last committed token
plus n drafted ones — yielding ``logits[:, j]`` = the target model's
next-token distribution *after* draft prefix ``d_1..d_j``. Draft proposals
are deterministic (point-mass proposals q = delta(d)), so the exact
rejection-sampling rule collapses to:

  accept d_{j+1}  with probability  p_j(d_{j+1})      (p_j = target at step j)
  on the first rejection at j = L, emit one corrective token sampled from
  the residual  p_L(x) * 1[x != d_{L+1}] / (1 - p_L(d_{L+1}))
  if all n drafts survive, emit one bonus token sampled from p_n.

Marginally every emitted token is distributed exactly as sequential sampling
from the target: P(emit x at step j) = p_j(d)*1[x=d] + (1-p_j(d)) * p_j(x) *
1[x!=d] / (1-p_j(d)) = p_j(x). The greedy path (temperature == 0) replaces
"accept w.p. p(d)" with "accept iff d == argmax p" and the correction/bonus
with argmax — which commits exactly the token chain sequential greedy decode
would produce, giving bit-identical tokens by construction.

Reported logprobs are ``log_softmax(logits)`` (untempered), matching
`ExecutionBackend._decode_step`; acceptance and resampling use the tempered
distribution ``softmax(logits / temperature)`` — the distribution
non-speculative decode actually samples from.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def verify_tokens(logits: jnp.ndarray, drafts: jnp.ndarray, rng,
                  temperature, greedy: bool
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Accept/reject n drafted tokens against target logits.

    logits (B, n+1, V) float32; drafts (B, n) int32; rng a jax PRNG key
    (unused on the greedy path); ``greedy`` is static.

    Returns (accept_len (B,) int32 in [0, n], out_tokens (B, n+1) int32,
    out_logps (B, n+1) float32): row b emits ``out_tokens[b, :accept_len[b]
    + 1]`` — the accepted draft prefix plus one correction/bonus token.
    Entries past that prefix are garbage and must not be read.
    """
    B, n_q, _V = logits.shape
    n = n_q - 1
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)            # reported logprobs
    if greedy:
        top = jnp.argmax(lf, axis=-1)                 # (B, n+1)
        acc = drafts == top[:, :n] if n else jnp.zeros((B, 0), bool)
        accepted = jnp.cumprod(acc.astype(jnp.int32), axis=1) \
            if n else jnp.zeros((B, 0), jnp.int32)
        accept_len = jnp.sum(accepted, axis=1).astype(jnp.int32)
        final = jnp.take_along_axis(top, accept_len[:, None],
                                    axis=1)[:, 0].astype(jnp.int32)
    else:
        logp_t = jax.nn.log_softmax(lf / temperature, axis=-1)
        u_key, cat_key = jax.random.split(rng)
        if n:
            p_draft = jnp.exp(jnp.take_along_axis(
                logp_t[:, :n], drafts[..., None].astype(jnp.int32),
                axis=-1)[..., 0])                     # (B, n)
            u = jax.random.uniform(u_key, (B, n))
            accepted = jnp.cumprod((u < p_draft).astype(jnp.int32), axis=1)
            accept_len = jnp.sum(accepted, axis=1).astype(jnp.int32)
        else:
            accept_len = jnp.zeros((B,), jnp.int32)
        # correction (L < n: residual — draft token masked out) or bonus
        # (L == n: plain target sample) from one categorical call
        scores = jnp.take_along_axis(
            lf / temperature, accept_len[:, None, None], axis=1)[:, 0]
        if n:
            d_next = jnp.take_along_axis(
                drafts, jnp.minimum(accept_len, n - 1)[:, None],
                axis=1)[:, 0]                          # draft at L (clamped)
            mask = (jnp.arange(scores.shape[-1])[None] == d_next[:, None]) \
                & (accept_len < n)[:, None]
            scores = jnp.where(mask, NEG_INF, scores)
        final = jax.random.categorical(cat_key, scores,
                                       axis=-1).astype(jnp.int32)

    pad = jnp.zeros((B, 1), jnp.int32)
    chain = jnp.concatenate([drafts.astype(jnp.int32), pad], axis=1) \
        if n else pad
    out_tokens = jnp.where(
        jnp.arange(n + 1)[None] == accept_len[:, None],
        final[:, None], chain)
    out_logps = jnp.take_along_axis(logp, out_tokens[..., None],
                                    axis=-1)[..., 0]
    return accept_len, out_tokens, out_logps


def emission_distribution(probs_next, draft_token: int):
    """Analytic marginal of the accept/reject rule at one step (numpy):
    accept the point-mass draft w.p. p(d), else sample the renormalized
    residual. Equals ``probs_next`` identically — the algebra the
    distribution-preservation tests pin against the sampled implementation.
    """
    import numpy as np
    p = np.asarray(probs_next, np.float64)
    out = np.zeros_like(p)
    pd = p[draft_token]
    out[draft_token] = pd
    if pd < 1.0:
        resid = p.copy()
        resid[draft_token] = 0.0
        out += (1.0 - pd) * resid / max(1.0 - pd, 1e-300)
    return out
