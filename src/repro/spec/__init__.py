"""Speculative multi-token decode: draft policies, distribution-preserving
verify, and roofline-priced routing of draft depth (see README
"Speculative decode").

Flow per decode step of a speculating batch:

  policy.propose(histories, n)          host-side drafts        (B, n)
    -> one verify forward over the paged cache scores 1 + n query tokens
    -> verify_tokens accepts the longest draft prefix + 1 correction/bonus
    -> commit: rejected tail KV entries stay in place, masked by position
       and overwritten by the next step (rollback costs zero block traffic)

`SpecPlanner` picks n per routed batch by re-pricing the batch workload
through `spec_workload` at the fitted accept rate; `CalibrationFitter`
learns those rates from "spec" trace records the scheduler emits.
"""
from repro.spec.policy import (DraftModelPolicy, DraftPolicy,
                               NGramDraftPolicy, make_draft_policy,
                               spec_supported)
from repro.spec.routing import (DEFAULT_ACCEPT_RATE, DEFAULT_DEPTHS,
                                SpecPlan, SpecPlanner,
                                expected_tokens_per_step, spec_workload)
from repro.spec.verify import emission_distribution, verify_tokens

__all__ = [
    "DraftModelPolicy", "DraftPolicy", "NGramDraftPolicy",
    "make_draft_policy", "spec_supported",
    "DEFAULT_ACCEPT_RATE", "DEFAULT_DEPTHS", "SpecPlan", "SpecPlanner",
    "expected_tokens_per_step", "spec_workload",
    "emission_distribution", "verify_tokens",
]
