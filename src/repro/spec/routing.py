"""Roofline-priced speculative routing.

Decode is memory-bound: each step's cost is dominated by re-streaming the
(active) weights, so verifying n drafted tokens in one forward costs barely
more than emitting one. At accept rate ``a`` and depth ``n``, one verify
step commits

    E[tokens] = 1 + a + a^2 + ... + a^n = (1 - a^(n+1)) / (1 - a)

tokens while scoring n + 1 queries. `spec_workload` rewrites a `Workload`
(exactly like `repro.quant.quant_workload` does for formats) so
`repro.core.decompose` divides decode weight re-streams by E[tokens] and
multiplies per-query compute/activation traffic by (n+1)/E[tokens] — DASI
rises, decode bytes fall, and `plan_costs`/`plan_costs_v2` price the trade
without speculation-specific branches.

`SpecPlanner` closes the loop: per candidate depth it asks
`ParetoRouter.route_batch` for the batch's cost under the spec-rewritten
workload (accept rate from fitted calibration, per (model, tier, policy))
and keeps the depth whose chosen operating point scores best under the
merged tier's scalarization — depth 0 is always a candidate, so a low
fitted accept rate flips drafting off by losing the price comparison, not
by a special case.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.core.decomposition import Workload

DEFAULT_DEPTHS: Tuple[int, ...] = (0, 2, 4)
DEFAULT_ACCEPT_RATE = 0.7


def expected_tokens_per_step(n: int, accept_rate: float) -> float:
    """E[committed tokens per verify step] at draft depth n, per-token
    accept rate a: (1 - a^(n+1)) / (1 - a); n+1 as a -> 1."""
    if n <= 0:
        return 1.0
    a = min(max(float(accept_rate), 0.0), 1.0)
    if a >= 1.0 - 1e-12:
        return float(n + 1)
    return (1.0 - a ** (n + 1)) / (1.0 - a)


def spec_workload(w: Workload, n: int, accept_rate: float) -> Workload:
    """Rewrite a workload for speculative decode at depth ``n``: decode
    weight re-streams drop to one per E[tokens] committed, scored queries
    rise to (n+1) per verify step. n <= 0 returns ``w`` unchanged (off)."""
    if n <= 0:
        return w
    tps = expected_tokens_per_step(n, accept_rate)
    return dataclasses.replace(w, spec_tokens_per_step=tps,
                               spec_queries_per_step=float(n + 1))


@dataclass(frozen=True)
class SpecPlan:
    """The speculation decision attached to a routed batch: which policy
    drafts, at what depth (0 = off), priced at which accept rate."""
    policy: str
    n: int
    accept_rate: float

    @property
    def tokens_per_step(self) -> float:
        return expected_tokens_per_step(self.n, self.accept_rate)

    @property
    def enabled(self) -> bool:
        return self.n > 0


class SpecPlanner:
    """Chooses the draft depth per routed batch from predicted cost.

    ``accept_rate`` seeds the prediction; a fitted `CalibrationProfile`
    (``profile=``) overrides it per (model, tier, policy) once "spec" trace
    records have been fitted — `refresh(profile)` swaps the estimate in
    live, closing the measure -> fit -> route loop for speculation.
    """

    def __init__(self, policy_name: str,
                 depths: Sequence[int] = DEFAULT_DEPTHS,
                 accept_rate: float = DEFAULT_ACCEPT_RATE,
                 profile=None, model_name: Optional[str] = None):
        self.policy_name = policy_name
        self.depths = tuple(sorted({0, *(int(d) for d in depths)}))
        self.default_accept_rate = float(accept_rate)
        self.profile = profile
        self.model_name = model_name

    def refresh(self, profile) -> None:
        """Adopt a newly fitted calibration profile (accept rates)."""
        self.profile = profile

    def accept_rate_for(self, tier_name: Optional[str] = None) -> float:
        """Fitted accept rate for (model, tier, policy) with the profile's
        fallback chain; the constructor default when nothing is fitted."""
        if self.profile is not None:
            r = self.profile.accept_rate_for(
                model=self.model_name, tier=tier_name,
                policy=self.policy_name, default=None)
            if r is not None:
                return float(r)
        return self.default_accept_rate

    def route_batch(self, router, tiers: Sequence, samples=None,
                    prompt_tokens=None, decode_tokens=None):
        """`ParetoRouter.route_batch` swept over candidate depths.

        Returns the winning `BatchRoutingDecision` with ``decision.spec``
        set to the chosen `SpecPlan`. Cap-feasible depths beat infeasible
        ones; ties break toward smaller n (less speculative exposure).
        """
        base = router.route_batch(tiers, samples=samples,
                                  prompt_tokens=prompt_tokens,
                                  decode_tokens=decode_tokens)
        merged = base.tier
        e0 = max(base.energy_j, 1e-12)
        t0 = max(base.latency_s, 1e-12)

        def score(d) -> float:
            # normalized by the spec-off decision so the unitless tier
            # weights blend joules and seconds sensibly across depths
            return (merged.energy_weight * d.energy_j / e0 +
                    merged.latency_weight * d.latency_s / t0)

        best = ((not base.meets_caps, score(base), 0), base,
                SpecPlan("off", 0, 1.0))
        # member tiers name the accept-rate key; batches are usually
        # tier-homogeneous per key, so the first member stands in
        t0m = tiers[0]
        tier_name = t0m if isinstance(t0m, str) else t0m.name
        rate = self.accept_rate_for(tier_name)
        for n in self.depths:
            if n == 0:
                continue
            d = router.route_batch(
                tiers, samples=samples, prompt_tokens=prompt_tokens,
                decode_tokens=decode_tokens,
                workload_map=lambda w, _n=n: spec_workload(w, _n, rate))
            key = (not d.meets_caps, score(d), n)
            if key < best[0]:
                best = (key, d, SpecPlan(self.policy_name, n, float(rate)))
        decision, plan = best[1], best[2]
        decision.spec = plan
        if plan.enabled:
            decision.notes.append(
                f"spec {plan.policy} n={plan.n} "
                f"accept_rate={plan.accept_rate:.2f} "
                f"E[tok/step]={plan.tokens_per_step:.2f}")
        return decision
