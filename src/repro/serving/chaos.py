"""Deterministic fault injection for the serving stack.

The paper's reliability claims (100% fault recovery, zero query loss, zero
thermal throttling) need faults that land on *live* requests, not just on
the planner. A `FaultPlan` is a seeded, JSON-serializable schedule of fault
actions on the simulated clock; a `ChaosDriver` replays it through the
REAL control surface — `HealthMonitor.fail_device` / `recover_device` and
`SafetyMonitor.emit` — so every injected fault reaches the scheduler and
control loop over the same `DriftEvent` bus production drift does. Nothing
here reaches into scheduler internals: if an event kind is unhandled in
`ContinuousBatchingScheduler.on_drift`, the chaos bench fails, which is
the point.

Action kinds (JSON ``kind`` field):

* ``device_fail``    — `HealthMonitor.fail_device(device)`: the monitor's
  ``on_event`` hook emits ``DriftEvent(kind="device_failed")``, which
  preempts and re-queues every in-flight batch routed onto the device.
* ``device_recover`` — `HealthMonitor.recover_device(device)`: device
  reintroduced (degraded), ``device_recovered`` restores routing.
* ``thermal_spike``  — emits ``thermal_margin`` (value = junction temp,
  degC): the control loop re-anneals, the scheduler re-pulls the frontier
  at the next batch boundary.
* ``kv_squeeze``     — emits ``kv_squeeze`` (value = blocks withheld): the
  scheduler subtracts the reserve from admission capacity, modeling a
  co-tenant stealing KV memory. value 0 releases the squeeze.
* ``slow_kernel``    — emits ``slow_kernel`` (value = service-time
  factor >= 1): batch makespans stretch by the factor, modeling thermal
  clamps / background contention. value 1 restores nominal speed.

Plan JSON schema::

    {"seed": 0, "actions": [
        {"t_s": 2.5, "kind": "device_fail", "device": "edge-npu"},
        {"t_s": 4.0, "kind": "device_recover", "device": "edge-npu"},
        {"t_s": 3.0, "kind": "thermal_spike", "device": "soc-gpu",
         "value": 96.0},
        {"t_s": 1.0, "kind": "kv_squeeze", "value": 48},
        {"t_s": 5.0, "kind": "slow_kernel", "value": 1.5}]}
"""
from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass, field
from typing import List, Optional, Sequence

from repro.core.safety import DriftEvent

ACTION_KINDS = ("device_fail", "device_recover", "thermal_spike",
                "kv_squeeze", "slow_kernel")


@dataclass(frozen=True)
class FaultAction:
    """One scheduled fault on the simulated clock."""
    t_s: float
    kind: str
    device: str = ""
    value: float = 0.0
    detail: str = ""

    def __post_init__(self):
        if self.kind not in ACTION_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(supported: {ACTION_KINDS})")
        if self.kind in ("device_fail", "device_recover") and not self.device:
            raise ValueError(f"{self.kind} needs a device name")


@dataclass
class FaultPlan:
    """A deterministic fault schedule: actions sorted by injection time.

    ``seed`` names the plan (and seeds `FaultPlan.random`); two runs of the
    same plan against the same request stream see identical fault timing.
    """
    seed: int = 0
    actions: List[FaultAction] = field(default_factory=list)

    def __post_init__(self):
        self.actions = sorted(self.actions, key=lambda a: a.t_s)

    # ------------------------------------------------------------- (de)ser
    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "actions": [asdict(a) for a in self.actions]},
                          indent=2)

    @staticmethod
    def from_json(text: str) -> "FaultPlan":
        doc = json.loads(text)
        return FaultPlan(seed=int(doc.get("seed", 0)),
                         actions=[FaultAction(**a)
                                  for a in doc.get("actions", [])])

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as f:
            return FaultPlan.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    # ------------------------------------------------------------ generator
    @staticmethod
    def random(seed: int, devices: Sequence[str], horizon_s: float,
               n_failures: int = 1, n_spikes: int = 1,
               kv_squeeze_blocks: int = 0, slow_factor: float = 0.0,
               recover_after_s: float = 1.0) -> "FaultPlan":
        """Seeded plan generator: ``n_failures`` fail/recover pairs,
        ``n_spikes`` thermal spikes, plus an optional mid-run KV squeeze and
        kernel slowdown window, all inside ``[0.1, 0.9] * horizon_s``."""
        rng = random.Random(seed)
        actions: List[FaultAction] = []
        lo, hi = 0.1 * horizon_s, 0.9 * horizon_s
        for _ in range(n_failures):
            dev = rng.choice(list(devices))
            t = rng.uniform(lo, hi)
            actions.append(FaultAction(t, "device_fail", device=dev,
                                       detail="injected"))
            actions.append(FaultAction(t + recover_after_s, "device_recover",
                                       device=dev))
        for _ in range(n_spikes):
            dev = rng.choice(list(devices))
            actions.append(FaultAction(rng.uniform(lo, hi), "thermal_spike",
                                       device=dev,
                                       value=rng.uniform(90.0, 105.0)))
        if kv_squeeze_blocks > 0:
            t = rng.uniform(lo, hi)
            actions.append(FaultAction(t, "kv_squeeze",
                                       value=float(kv_squeeze_blocks)))
            actions.append(FaultAction(t + recover_after_s, "kv_squeeze",
                                       value=0.0))
        if slow_factor > 1.0:
            t = rng.uniform(lo, hi)
            actions.append(FaultAction(t, "slow_kernel", value=slow_factor))
            actions.append(FaultAction(t + recover_after_s, "slow_kernel",
                                       value=1.0))
        return FaultPlan(seed=seed, actions=actions)


class ChaosDriver:
    """Replays a `FaultPlan` through a `SafetyMonitor` as the simulated
    clock advances. Call ``apply_due(now_s)`` once per scheduler step (or
    arrival); every action with ``t_s <= now_s`` fires, in order, through
    the monitor's real event paths — consumers (scheduler ``on_drift``,
    `ControlLoop`) cannot tell injected faults from organic ones."""

    def __init__(self, plan: FaultPlan, safety):
        self.plan = plan
        self.safety = safety
        self._pending: List[FaultAction] = list(plan.actions)
        self.applied: List[FaultAction] = []

    @property
    def done(self) -> bool:
        return not self._pending

    def apply_due(self, now_s: float) -> List[FaultAction]:
        fired: List[FaultAction] = []
        while self._pending and self._pending[0].t_s <= now_s:
            a = self._pending.pop(0)
            self._apply(a)
            fired.append(a)
            self.applied.append(a)
        return fired

    def _apply(self, a: FaultAction) -> None:
        mon = self.safety
        # events carry the injection time: align the monitor's clock so
        # organic emissions that follow do not time-travel backwards
        mon.clock_s = max(mon.clock_s, a.t_s)
        if a.kind == "device_fail":
            mon.health.fail_device(a.device, a.t_s)
        elif a.kind == "device_recover":
            mon.health.recover_device(a.device)
        elif a.kind == "thermal_spike":
            mon.emit(DriftEvent(a.t_s, a.device, "thermal_margin",
                                value=a.value,
                                detail=a.detail or "injected spike"))
        elif a.kind == "kv_squeeze":
            mon.emit(DriftEvent(a.t_s, a.device, "kv_squeeze",
                                value=a.value,
                                detail=a.detail or "injected squeeze"))
        elif a.kind == "slow_kernel":
            mon.emit(DriftEvent(a.t_s, a.device, "slow_kernel",
                                value=a.value,
                                detail=a.detail or "injected slowdown"))


def attach(plan: FaultPlan, safety, scheduler) -> ChaosDriver:
    """Wire a plan into a live scheduler: subscribes the scheduler's
    ``on_drift`` to the monitor's event bus (idempotence is the caller's
    concern) and returns the driver to pump from the arrival loop."""
    safety.subscribe(scheduler.on_drift)
    return ChaosDriver(plan, safety)
