"""Serving engine: batched prefill + autoregressive decode with KV caches.

This is the substrate under the paper's repeated-sampling experiments. Since
the scheduler refactor the engine is a thin *blocking* loop over
`repro.serving.backend.ExecutionBackend`: one ``generate`` call groups its
prompts by length, runs each group start-to-finish through the backend's
step API, and returns. The QEIL orchestrator (repro.core.orchestrator)
decides *where* prefill and decode run (device profiles / mesh slices); the
backend is the *how*; mixed-tier continuous batching across calls lives in
`repro.serving.scheduler.ContinuousBatchingScheduler`.

Requests inside one ``generate`` call are grouped by prompt length (static-
shape jit); repeated sampling tiles each prompt ``n_samples`` times so all
samples of a request decode in one batch — the batched-inference pattern the
paper assumes when it amortizes prefill energy across samples.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.models.model import Model
from repro.serving.backend import ExecutionBackend, GenerationResult


class ServingEngine:
    def __init__(self, model: Model, params, max_new_tokens: int = 32,
                 temperature: float = 0.8, eos_token: Optional[int] = None,
                 placement_provider: Optional[Callable] = None,
                 backend: Optional[ExecutionBackend] = None, obs=None):
        self.model = model
        self.params = params
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.eos_token = eos_token
        # placement hook: called once per `generate` with (n_prompts,
        # n_samples) and returns the orchestrator's operating point for the
        # call (an Assignment, or None). The QEIL split of labor: the
        # orchestrator decides *where* (simulated stage->device plan), the
        # engine the *how*. Scheduler-driven serving routes per *batch*
        # instead (the scheduler notes decisions on the backend directly);
        # this per-call hook remains for direct engine use.
        self.placement_provider = placement_provider
        # obs only shapes the default-constructed backend; an explicit
        # backend keeps whatever bundle it was built with (one backend, one
        # bundle — the scheduler and engine paths share both)
        self.backend = backend if backend is not None else \
            ExecutionBackend(model, params, eos_token=eos_token, obs=obs)

    # placement history lives on the backend so scheduler-driven and
    # call-driven serving share one record; these views keep the old API.
    @property
    def last_placement(self):
        return self.backend.last_placement

    @property
    def placements(self):
        return self.backend.placements

    # ------------------------------------------------------------------ public
    def generate(self, prompts: Sequence[np.ndarray], n_samples: int = 1,
                 max_new_tokens: Optional[int] = None,
                 temperature: Optional[float] = None,
                 rng: Optional[jax.Array] = None,
                 extras: Optional[Dict] = None) -> List[GenerationResult]:
        """Generate ``n_samples`` completions per prompt."""
        max_new = max_new_tokens or self.max_new_tokens
        temp = temperature if temperature is not None else self.temperature
        rng = rng if rng is not None else jax.random.key(0)
        extras = extras or {}

        if self.placement_provider is not None:
            self.backend.note_placement(
                self.placement_provider(len(prompts), n_samples))

        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        by_len: Dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            by_len.setdefault(len(p), []).append(i)

        for plen, idxs in by_len.items():
            for chunk in self._budget_chunks(idxs, plen, n_samples, max_new):
                rng, sub = jax.random.split(rng)
                row_extras = {k: np.asarray(v)[chunk]
                              for k, v in extras.items()}
                h = self.backend.start_batch([prompts[i] for i in chunk],
                                             n_samples, max_new, temp, sub,
                                             row_extras)
                while self.backend.decode_step(h):
                    pass
                for i, r in zip(chunk, self.backend.finalize(h)):
                    results[i] = r
        return results  # type: ignore[return-value]

    def _budget_chunks(self, idxs: List[int], plen: int, n_samples: int,
                       max_new: int) -> List[List[int]]:
        """Split one prompt-length group so every chunk fits the backend's
        KV budget (blocks or slots); an unbounded backend keeps the whole
        group as one batch (the pre-refactor behaviour, bit-identical rng
        stream)."""
        capacity = getattr(self.backend, "capacity_total", None)
        if capacity is None:
            return [idxs]
        cost = self.backend.request_cost(plen, max_new, n_samples)
        if cost > capacity:
            raise ValueError(
                f"one request needs {cost} KV budget units but the backend "
                f"only has {capacity}; lower n_samples/max_new_tokens or "
                "raise the budget")
        per_chunk = max(1, capacity // cost)
        return [idxs[i:i + per_chunk] for i in range(0, len(idxs), per_chunk)]
