"""Serving engine: batched prefill + autoregressive decode with KV caches.

This is the substrate under the paper's repeated-sampling experiments: the engine
prefills a batch of prompts once, then runs jitted single-token decode steps. The
QEIL orchestrator (repro.core.orchestrator) decides *where* prefill and decode run
(device profiles / mesh slices); the engine is the *how*.

Requests inside one ``generate`` call are grouped by prompt length (static-shape
jit); repeated sampling tiles each prompt ``n_samples`` times so all samples of a
request decode in one batch — the batched-inference pattern the paper assumes when
it amortizes prefill energy across samples.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class GenerationResult:
    prompt: np.ndarray
    samples: List[np.ndarray]          # n_samples completions (token arrays)
    logprobs: List[float]              # mean per-token logprob per sample
    prefill_tokens: int = 0
    decode_tokens: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, max_new_tokens: int = 32,
                 temperature: float = 0.8, eos_token: Optional[int] = None,
                 placement_provider: Optional[Callable] = None):
        self.model = model
        self.params = params
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.eos_token = eos_token
        # placement hook: called once per `generate` with (n_prompts,
        # n_samples) and returns the orchestrator's operating point for the
        # call (an Assignment, or None). The QEIL split of labor: the
        # orchestrator decides *where* (simulated stage->device plan), the
        # engine the *how* — this hook is what lets the plan be chosen
        # per-call from a live Pareto frontier
        # (`repro.qeil2.runtime.RoutedServingEngine`) instead of once at
        # startup. The engine records it; execution itself runs on whatever
        # accelerator JAX sees.
        self.placement_provider = placement_provider
        self.last_placement = None
        # bounded history: each entry holds a full plan (per-stage costs);
        # a long-lived server must not grow linearly with request count
        self.placements: Deque = deque(maxlen=256)
        self._prefill_jit = jax.jit(self._prefill)
        self._decode_jit = jax.jit(self._decode_step)

    # ------------------------------------------------------------------ jitted
    def _prefill(self, params, tokens, cache, extras):
        batch = {"tokens": tokens, **extras}
        logits, cache, _ = self.model.forward(params, batch, cache)
        return logits[:, -1], cache

    def _decode_step(self, params, tok, pos, cache, rng, temperature, extras):
        b = {"tokens": tok, "positions": pos, **extras}
        logits, cache, _ = self.model.forward(params, b, cache)
        logits = logits[:, 0].astype(jnp.float32)          # (B, V) or (B, K, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        sample = jax.random.categorical(rng, logits / temperature, axis=-1)
        chosen_logp = jnp.take_along_axis(logp, sample[..., None],
                                          axis=-1)[..., 0]
        return sample, chosen_logp, cache

    # ------------------------------------------------------------------ public
    def generate(self, prompts: Sequence[np.ndarray], n_samples: int = 1,
                 max_new_tokens: Optional[int] = None,
                 temperature: Optional[float] = None,
                 rng: Optional[jax.Array] = None,
                 extras: Optional[Dict] = None) -> List[GenerationResult]:
        """Generate ``n_samples`` completions per prompt."""
        max_new = max_new_tokens or self.max_new_tokens
        temp = temperature if temperature is not None else self.temperature
        rng = rng if rng is not None else jax.random.key(0)
        extras = extras or {}

        if self.placement_provider is not None:
            self.last_placement = self.placement_provider(len(prompts),
                                                          n_samples)
            self.placements.append(self.last_placement)

        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        by_len: Dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            by_len.setdefault(len(p), []).append(i)

        for plen, idxs in by_len.items():
            rng, sub = jax.random.split(rng)
            group = [prompts[i] for i in idxs]
            group_res = self._generate_equal_len(group, n_samples, max_new,
                                                 temp, sub, extras)
            for i, r in zip(idxs, group_res):
                results[i] = r
        return results  # type: ignore[return-value]

    def _generate_equal_len(self, prompts, n_samples, max_new, temp, rng,
                            extras) -> List[GenerationResult]:
        mc = self.model.cfg.n_codebooks > 1
        plen = len(prompts[0])
        base = np.stack(prompts)                            # (R, L[,K])
        tokens = np.repeat(base, n_samples, axis=0)         # (R*S, L[,K])
        B = tokens.shape[0]
        tiled_extras = {k: jnp.repeat(jnp.asarray(v), n_samples, axis=0)
                        for k, v in extras.items()}

        cache = self.model.init_cache(B, plen + max_new)
        last_logits, cache = self._prefill_jit(
            self.params, jnp.asarray(tokens), cache, tiled_extras)

        # first sampled token comes from the prefill logits
        rng, sub = jax.random.split(rng)
        lf = last_logits.astype(jnp.float32)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        tok = jax.random.categorical(sub, lf / temp, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]

        out_toks = [np.asarray(tok)]
        out_lps = [np.asarray(lp if not mc else lp.mean(-1))]
        for t in range(1, max_new):
            rng, sub = jax.random.split(rng)
            pos = jnp.full((B, 1), plen + t - 1, jnp.int32)
            if self.model.cfg.mrope_sections:
                pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
            tok_in = tok[:, None] if not mc else tok[:, None, :]
            tok, lp, cache = self._decode_jit(self.params, tok_in, pos, cache,
                                              sub, temp, tiled_extras)
            out_toks.append(np.asarray(tok))
            out_lps.append(np.asarray(lp if not mc else lp.mean(-1)))

        toks = np.stack(out_toks, axis=1)                   # (B, T[,K])
        lps = np.stack(out_lps, axis=1)                     # (B, T)

        results = []
        for r in range(len(prompts)):
            sl = slice(r * n_samples, (r + 1) * n_samples)
            samples = [toks[i] for i in range(sl.start, sl.stop)]
            if self.eos_token is not None and not mc:
                samples = [self._truncate(s) for s in samples]
            results.append(GenerationResult(
                prompt=prompts[r],
                samples=samples,
                logprobs=[float(lps[i].mean())
                          for i in range(sl.start, sl.stop)],
                prefill_tokens=plen,
                decode_tokens=int(np.prod(toks.shape[1:2])) * n_samples,
            ))
        return results

    def _truncate(self, sample: np.ndarray) -> np.ndarray:
        hits = np.nonzero(sample == self.eos_token)[0]
        return sample[: hits[0]] if hits.size else sample
