"""Scheduler-centric serving: admission -> mixed-tier batching -> backend.

The pre-refactor serving stack picked one operating point per blocking
``generate`` call, so mixed-tier request streams serialized and prefill /
weight-streaming energy was never amortized across tiers. This module is the
policy layer that closes that gap:

* ``RequestQueue`` — tier-aware admission control: unknown tiers are
  rejected at the door, per-tier queue depth is bounded, and a tier's
  coverage floor (``SLATier.min_quality``) raises the request's sampling
  budget on admission. Admitted requests wait in per-bucket FIFO order
  (bucket = prompt length x decode horizon x temperature — the static
  shapes the backend jits on).
* ``ContinuousBatchingScheduler`` — forms mixed-tier batches from the
  oldest bucket, routes each batch to ONE shared operating point via the
  router's batch-aware ``route_batch`` (caps merge to the tightest member
  tier; every frontier point is re-costed under the batch workload, so
  decode weight-streaming amortization is priced in), and interleaves
  prefill of new batches with decode steps of in-flight ones. Batches
  shrink until the merged caps are satisfiable whenever the frontier admits
  any feasible point at that size — a tight-SLA member caps how much
  batching its batch can absorb instead of silently blowing its cap.

Admission is costed in the backend's own KV currency: a paged backend
prices a request in *blocks* at shared-prefix cost (`request_cost` — the
k repeats a tier's coverage floor demands share their prefix blocks, so a
raised sampling budget is far cheaper than k dense slots), a dense backend
in sequence slots; `early_stop` releases a request's remaining samples'
blocks the moment a CSVET verifier confirms a pass, instead of waiting for
batch retirement.

Routing happens only at batch *formation*: a drift-triggered re-anneal
(`ControlLoop` calls ``on_reorchestrate``) therefore takes effect at the
next batch boundary — in-flight batches finish on the plan they were priced
against, *unless* the drift is a device failure: ``on_drift`` (subscribed
to the `SafetyMonitor` event bus) preempts every in-flight batch whose
routed assignment includes the failed device and re-queues its requests
with retry backoff, so nothing runs to completion against a dead placement.

Preemption (``SchedulerConfig.preempt``)
----------------------------------------
``preempt(entry)`` snapshots a victim batch at a decode-step boundary: each
member request's generated tokens/logprobs become a `ResumeState` (its
per-sample histories are the new effective prompts), the backend parks the
victim's filled KV blocks in the resident `PrefixPool` (``park_batch`` —
resume is then a trie hit that prefills only the post-preemption tail) or
releases them, and the requests return to the queue head with their
original ``arrival_s``/``seq`` (queue delay stays total wall time).
Victim selection is by tier scalarization — a waiting request whose tier's
``latency_weight`` outranks every member of the simulated-pipeline tail
entry may cut it — bounded by a per-request preemption cap and an optional
age bound so economy work cannot starve. Lifecycle policies ride the same
machinery: per-tier deadlines cancel overdue queued requests, device-fault
evictions retry with exponential backoff, and queue-depth / KV-watermark
load shedding drops the oldest lowest-priority work first.

Simulated time: placement is the orchestrator's simulated stage->device
plan, so service time is simulated too (execution itself runs on whatever
accelerator JAX sees). Batches serialize on one simulated pipeline: a batch
formed at clock ``t`` starts at ``max(t, pipeline_free_t)`` and occupies the
pipeline for its re-costed makespan. Per-request queue delay and latency in
`CompletedRequest` are in this simulated clock, which is what the SLA caps
and `benchmarks/serving_schedule.py` measure. The real decode interleaving
across in-flight batches exists so wall-clock work overlaps; it does not
change simulated accounting.

The backend is duck-typed (``start_batch`` / ``decode_step`` / ``finalize``
/ ``slots_free`` / ``note_placement``), so pure scheduling-policy tests run
against a stub without touching JAX; the router likewise only needs
``route_batch`` / ``resolve_tier`` / ``required_samples``.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_OBS
from repro.serving.backend import bucket_key as _default_bucket_key

_MISSING = object()    # getattr sentinel: absent attr vs attr that is None


def tier_priority(tier) -> float:
    """Scalarized service priority of a request class: its latency weight
    (interactive = 1.0 outranks economy = 0.0). Used for preemption victim
    selection and load-shedding order — economy before interactive."""
    return float(getattr(tier, "latency_weight", 0.0))


@dataclass
class ResumeState:
    """Decode-boundary snapshot of a preempted request.

    ``prompts[i]`` is sample *i*'s full token history (original prompt +
    every committed token) — the effective prompt a resumed run prefills;
    with the resident prefix pool the parked chain makes that prefill a
    trie hit on everything but the tail. ``toks``/``lps`` are the committed
    decode tokens and their logprobs, merged back into the final
    `GenerationResult` at retirement. Speculative victims are trimmed to
    the request's minimum committed count so the resumed bucket stays
    rectangular (greedy regenerates the trimmed tail identically)."""
    prompts: List[np.ndarray]          # per-sample history, equal lengths
    toks: List[np.ndarray]             # committed tokens per sample
    lps: List[np.ndarray]              # committed logprobs per sample


@dataclass
class ServeRequest:
    id: int
    prompt: np.ndarray
    tier: Any                          # resolved SLATier
    n_samples: int
    max_new_tokens: int
    temperature: float
    rng: Optional[Any] = None          # jax PRNG key (single-request parity)
    extras: Optional[Dict[str, np.ndarray]] = None   # per-request rows
    arrival_s: float = 0.0
    seq: int = 0                       # admission order (FIFO key)
    deadline_s: Optional[float] = None     # sim-clock completion deadline
    resume: Optional[ResumeState] = None   # set while preempted/resumed
    preemptions: int = 0               # times evicted mid-decode
    retries: int = 0                   # fault-eviction retry count
    not_before_s: float = 0.0          # retry backoff: earliest re-service

    @property
    def tier_name(self) -> str:
        return self.tier.name

    @property
    def effective_prompt(self) -> np.ndarray:
        """The prompt a (re)formed batch actually prefills: the original
        prompt, or the preemption snapshot's per-sample history."""
        return self.prompt if self.resume is None else self.resume.prompts[0]

    @property
    def emitted_tokens(self) -> int:
        return 0 if self.resume is None else len(self.resume.toks[0])

    @property
    def remaining_new(self) -> int:
        return self.max_new_tokens - self.emitted_tokens


@dataclass
class AdmissionResult:
    admitted: bool
    request_id: Optional[int] = None
    reason: str = ""                   # human-readable rejection detail
    reason_code: str = "ok"            # stable label: ok | unknown_tier |
    #                                    queue_full | kv_budget (metrics key)
    raised_samples: Optional[int] = None   # coverage floor raised the budget


@dataclass(frozen=True)
class SchedulerConfig:
    max_batch_requests: int = 8        # requests per formed batch
    max_inflight_batches: int = 2      # prefill/decode interleave width
    max_queue_depth: Optional[int] = 256   # per-tier admission bound
    max_new_tokens: int = 32           # defaults mirror ServingEngine
    temperature: float = 0.8
    seed: int = 0                      # batch rng stream (multi-request)
    respect_caps: bool = True          # shrink batches to keep caps feasible
    # --- decode-boundary preemption (off by default: legacy run-to-
    # completion scheduling is the baseline every earlier bench pins) ---
    preempt: bool = False              # tier-priority pipeline-tail cutting
    preempt_min_gain_s: float = 0.0    # only cut when the projected wait
    #                                    behind the tail exceeds this
    preempt_max_per_request: int = 4   # no-starvation cap per victim
    preempt_age_bound_s: Optional[float] = None  # victims older than this
    #                                    (sim wait) are preemption-exempt
    # --- request lifecycle policies ---
    deadline_factor: Optional[float] = None   # deadline = arrival +
    #                                    factor * tier.latency_p99_s
    retry_backoff_s: float = 0.05      # fault-eviction backoff base (2^k)
    max_retries: int = 3               # fault retries before the request
    #                                    is cancelled as failed
    shed_queue_depth: Optional[int] = None    # total-queued shed watermark
    shed_kv_free_frac: Optional[float] = None  # KV free-fraction watermark


@dataclass(eq=False)
class BatchRecord:
    """One formed batch — the scheduler's telemetry unit (`TraceStore`
    kind ``"serve"`` via `ingest_serve`)."""
    batch_id: int
    t_s: float                         # simulated service start
    bucket: int                        # prompt length
    n_requests: int
    n_sequences: int
    tier_mix: Dict[str, int]
    queue_delay_s: float               # max member wait before service
    point_index: int
    energy_j: float                    # batch energy at the routed point
    latency_s: float                   # batch service makespan
    meets_caps: bool
    reroute: bool                      # first batch after a re-anneal
    kv_blocks_in_use: Optional[int] = None   # paged backend occupancy
    prefill_bytes_saved: float = 0.0   # KV bytes prefix sharing avoided
    # resident prefix pool (cross-batch block reuse): trie-cached blocks
    # this batch reused / idle blocks it evicted to fit its tails.
    # stats() accumulates these (and prefill_bytes_saved) across batches.
    pool_hit_blocks: int = 0
    pool_evictions: int = 0
    quant: str = "bf16"                # weight serving format (repro.quant)
    kv_format: str = "bf16"            # KV-cache element format
    weight_bytes: Optional[int] = None       # resident (packed) weight bytes
    kv_bytes_in_use: Optional[int] = None    # occupied KV bytes at service
    # speculative decode: the routed plan at formation, measured counts
    # filled in at retirement (the "spec" trace record the accept-rate
    # fitter reads carries the measured pair)
    spec_policy: str = "off"           # draft policy name ("off" = none)
    spec_n: int = 0                    # draft depth this batch ran at
    spec_accept_rate: Optional[float] = None   # planned -> measured
    spec_proposed: int = 0             # draft tokens offered to verify
    spec_accepted: int = 0             # draft tokens verify accepted
    # preemption: set when this batch was cut at a decode boundary instead
    # of retiring (reason: tier | fault | shed); the pipeline clock rolls
    # back to the preemption instant, so latency_s overstates what ran
    preempted: Optional[str] = None
    preempted_t_s: Optional[float] = None
    # resume accounting: requests in this batch re-admitted after a
    # preemption, and their history prefill split (full = what a pool-less
    # re-prefill would move, tail = what was actually prefilled after
    # parked-chain trie hits)
    resume_requests: int = 0
    resume_full_tokens: int = 0
    resume_tail_tokens: int = 0
    # per-member accounting on the simulated clock: queue_delay_s above is
    # the max over members; p95 queue delay needs every member's own wait
    request_entries: List[Dict[str, Any]] = field(default_factory=list)


@dataclass(eq=False)
class CompletedRequest:
    request: ServeRequest
    result: Any                        # GenerationResult
    batch_id: int
    queue_delay_s: float
    latency_s: float                   # simulated completion - arrival
    decision: Any                      # BatchRoutingDecision


class RequestQueue:
    """Tier-aware admission + per-bucket FIFO.

    ``router`` supplies the tier registry (`resolve_tier`) and the coverage
    floor (`required_samples`); pass None for a policy-free queue (any tier
    object accepted verbatim).
    """

    def __init__(self, router=None, max_queue_depth: Optional[int] = 256,
                 bucket_key=None, obs=None,
                 deadline_factor: Optional[float] = None):
        self.router = router
        self.max_queue_depth = max_queue_depth
        self.bucket_key = bucket_key or _default_bucket_key
        # per-tier deadline: arrival + factor * tier.latency_p99_s (tiers
        # without a latency cap are deadline-exempt)
        self.deadline_factor = deadline_factor
        self.obs = obs if obs is not None else NULL_OBS
        self._buckets: Dict[Tuple, Deque[ServeRequest]] = {}
        self._depth: Dict[str, int] = {}
        self._seq = 0
        self._next_id = 0
        # bounded: rejections are diagnostics, not an audit log
        self.rejections: Deque[AdmissionResult] = deque(maxlen=256)
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "admissions": reg.counter(
                    "serving_admission_total",
                    "Admission outcomes by rejection reason code",
                    labelnames=("outcome", "reason")),
                "depth": reg.gauge(
                    "serving_queue_depth",
                    "Admitted requests waiting, per tier",
                    labelnames=("tier",)),
            }

    def _reject(self, reason: str, code: str,
                arrival_s: float, tier_name: Optional[str]) -> AdmissionResult:
        res = AdmissionResult(False, reason=reason, reason_code=code)
        self.rejections.append(res)
        if self._m is not None:
            self._m["admissions"].inc(outcome="rejected", reason=code)
        if self.obs.tracer.enabled:
            self.obs.tracer.emit("admit", arrival_s, admitted=False,
                                 reason=code, tier=tier_name)
        return res

    def _note_depth(self, tier_name: str) -> None:
        if self._m is not None:
            self._m["depth"].set(self._depth.get(tier_name, 0),
                                 tier=tier_name)

    # ----------------------------------------------------------- admission
    def submit(self, prompt: np.ndarray, tier, n_samples: int = 1,
               max_new_tokens: int = 32, temperature: float = 0.8,
               rng=None, extras: Optional[Dict] = None,
               arrival_s: float = 0.0,
               budget: Optional[int] = None,
               cost=None) -> AdmissionResult:
        """``budget``/``cost`` bound admission in the backend's KV currency:
        ``cost(plen, max_new, n_samples)`` (default: ``n_samples``, the
        dense slot count) is priced *after* any coverage-floor raise and
        rejected at the door when it can never fit ``budget``."""
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1 (got {n_samples})")
        if self.router is not None and isinstance(tier, str):
            try:
                tier = self.router.resolve_tier(tier)
            except KeyError:
                return self._reject(f"unknown tier {tier!r}", "unknown_tier",
                                    arrival_s, str(tier))
        elif isinstance(tier, str):
            raise ValueError("string tier names need a router to resolve")
        name = tier.name
        if self.max_queue_depth is not None and \
                self._depth.get(name, 0) >= self.max_queue_depth:
            return self._reject(
                f"tier {name!r} queue full ({self.max_queue_depth})",
                "queue_full", arrival_s, name)
        raised = None
        if self.router is not None:
            floor = self.router.required_samples(tier)
            if floor is not None and floor > n_samples:
                n_samples, raised = floor, floor
        if budget is not None:
            c = (cost(len(prompt), max_new_tokens, n_samples)
                 if cost is not None else n_samples)
            if c > budget:
                # a request that can never fit the backend's KV budget is
                # rejected at the door instead of wedging the batch former
                return self._reject(
                    f"admission cost {c} (n_samples={n_samples}) exceeds "
                    f"the KV budget ({budget})", "kv_budget", arrival_s,
                    name)
        deadline = None
        if self.deadline_factor is not None:
            cap = getattr(tier, "latency_p99_s", None)
            if cap is not None:
                deadline = arrival_s + self.deadline_factor * cap
        req = ServeRequest(self._next_id, prompt, tier, n_samples,
                           max_new_tokens, temperature, rng=rng,
                           extras=extras, arrival_s=arrival_s,
                           seq=self._seq, deadline_s=deadline)
        self._next_id += 1
        self._seq += 1
        self._depth[name] = self._depth.get(name, 0) + 1
        self._buckets.setdefault(self._key(req), deque()).append(req)
        if self._m is not None:
            self._m["admissions"].inc(outcome="admitted", reason="ok")
            self._note_depth(name)
        if self.obs.tracer.enabled:
            # the request's root span (a point on the sim clock); queue /
            # release spans auto-parent under it via request_id
            self.obs.tracer.emit("admit", arrival_s, request_id=req.id,
                                 admitted=True, tier=name,
                                 n_samples=n_samples)
        return AdmissionResult(True, req.id, raised_samples=raised)

    # ------------------------------------------------------------- queries
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def __len__(self) -> int:
        return self.pending

    def depth(self, tier_name: str) -> int:
        return self._depth.get(tier_name, 0)

    def _key(self, req: ServeRequest) -> Tuple:
        """A request's current bucket: the *effective* prompt length and
        remaining decode horizon — a resumed request lives in the bucket of
        its history, not its original shape."""
        return self.bucket_key(req.effective_prompt, req.remaining_new,
                               req.temperature)

    def _oldest_bucket(self, now: Optional[float] = None) -> Optional[Tuple]:
        live = {k: q for k, q in self._buckets.items()
                if q and (now is None or q[0].not_before_s <= now)}
        if not live:
            return None
        return min(live, key=lambda k: live[k][0].seq)

    def peek_ready(self, now: Optional[float] = None
                   ) -> Optional[ServeRequest]:
        """Highest-priority serviceable bucket head (ties: oldest), or None.
        Priority is the tier's latency-weight scalarization — the same key
        preemption victim selection uses."""
        heads = [q[0] for q in self._buckets.values()
                 if q and (now is None or q[0].not_before_s <= now)]
        if not heads:
            return None
        return max(heads, key=lambda r: (tier_priority(r.tier), -r.seq))

    def earliest_not_before(self) -> Optional[float]:
        """Soonest retry-backoff release among queued requests (idle-clock
        target when everything pending is backoff-blocked)."""
        ts = [r.not_before_s for q in self._buckets.values() for r in q
              if r.not_before_s > 0.0]
        return min(ts) if ts else None

    # ----------------------------------------------------------- batching
    def pop_batch(self, max_requests: int,
                  budget: Optional[int] = None,
                  cost=None, now: Optional[float] = None,
                  bucket: Optional[Tuple] = None) -> List[ServeRequest]:
        """Pop the next batch: oldest bucket first, FIFO within it (which is
        FIFO within every tier), bounded by request count and the backend's
        free KV budget — ``cost(req)`` prices each member (default: its
        sample count, the dense slot cost; a paged backend prices blocks at
        shared-prefix cost). Never mixes buckets. ``now`` respects retry
        backoff (a head whose ``not_before_s`` is in the future blocks its
        bucket, preserving FIFO); ``bucket`` targets a specific bucket (the
        preemption path forms the preempting tier's batch first)."""
        if bucket is not None and self._buckets.get(bucket):
            key: Optional[Tuple] = bucket
        else:
            key = self._oldest_bucket(now)
        if key is None:
            return []
        q = self._buckets[key]
        out: List[ServeRequest] = []
        used = 0
        while q and len(out) < max_requests:
            head = q[0]
            if now is not None and head.not_before_s > now:
                break      # backoff: FIFO within the bucket is preserved
            c = cost(head) if cost is not None else head.n_samples
            if budget is not None and used + c > budget:
                break      # head waits for budget to free (retiring batches)
            out.append(q.popleft())
            used += c
            self._depth[head.tier_name] -= 1
            self._note_depth(head.tier_name)
        return out

    def push_front(self, requests: Sequence[ServeRequest]) -> None:
        """Return popped requests to the head of their bucket, order
        preserved (cap-aware batch shrinking, preemption re-admission).
        Requests keep their original ``arrival_s``/``seq``, so FIFO age,
        deadline math and queue-delay accounting reflect total wall time —
        never time-since-requeue."""
        for req in reversed(list(requests)):
            self._buckets.setdefault(self._key(req), deque()).appendleft(req)
            self._depth[req.tier_name] = self._depth.get(req.tier_name, 0) + 1
            self._note_depth(req.tier_name)

    def expire(self, now: float) -> List[ServeRequest]:
        """Remove and return every queued request whose deadline has passed
        (the scheduler cancels them — they hold no KV, so nothing leaks)."""
        out: List[ServeRequest] = []
        for key, q in list(self._buckets.items()):
            if not any(r.deadline_s is not None and now > r.deadline_s
                       for r in q):
                continue
            keep: Deque[ServeRequest] = deque()
            for r in q:
                if r.deadline_s is not None and now > r.deadline_s:
                    out.append(r)
                    self._depth[r.tier_name] -= 1
                    self._note_depth(r.tier_name)
                else:
                    keep.append(r)
            self._buckets[key] = keep
        return out

    def shed_oldest(self, priority_of=None) -> Optional[ServeRequest]:
        """Remove and return the oldest request of the lowest-priority tier
        present (load shedding: oldest-economy-first)."""
        priority_of = priority_of or tier_priority
        victim: Optional[ServeRequest] = None
        vkey: Optional[Tuple] = None
        for key, q in self._buckets.items():
            for r in q:
                if victim is None or \
                        (priority_of(r.tier), r.seq) < \
                        (priority_of(victim.tier), victim.seq):
                    victim, vkey = r, key
        if victim is None:
            return None
        self._buckets[vkey].remove(victim)
        self._depth[victim.tier_name] -= 1
        self._note_depth(victim.tier_name)
        return victim


@dataclass(eq=False)
class _InflightEntry:
    handle: Any
    requests: List[ServeRequest]
    decision: Any
    record: BatchRecord
    start_t: float
    done_t: float


class ContinuousBatchingScheduler:
    """Mixed-tier continuous batching over an execution backend.

    One ``step()`` forms new batches while capacity allows (admission ->
    route_batch -> backend prefill) and advances every in-flight batch by
    one decode token; finished batches retire into ``completed`` keyed by
    request id. ``run_until_idle`` drains everything queued.
    """

    def __init__(self, backend, router,
                 config: SchedulerConfig = SchedulerConfig(),
                 queue: Optional[RequestQueue] = None, trace=None, obs=None,
                 spec_planner=None):
        self.backend = backend
        self.router = router
        self.config = config
        # optional repro.spec.SpecPlanner: batch formation then sweeps draft
        # depths through the router's spec-priced workload and notes the
        # winning depth on the backend (note_spec) before prefill; a backend
        # without speculative support simply never receives a note
        self.spec_planner = spec_planner
        # one obs bundle serves the whole pipeline: the scheduler emits
        # sim-clock lifecycle spans + batch metrics, its queue the admission
        # side, and the backend wall-clock prefill/decode spans (spans meet
        # through tracer.batch_context — see repro.obs.tracer)
        self.obs = obs if obs is not None else NULL_OBS
        self.queue = queue if queue is not None else \
            RequestQueue(router, config.max_queue_depth, obs=self.obs)
        # optional repro.qeil2.telemetry.TraceStore: one "serve" record per
        # formed batch (tier mix, queue delay, operating point, SignalSet
        # snapshots) — serving's side of the calibration measurement loop.
        self.trace = trace
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "occupancy": reg.histogram(
                    "serving_batch_occupancy",
                    "Requests per formed batch",
                    buckets=(1, 2, 4, 8, 16, 32, 64)),
                "queue_delay": reg.histogram(
                    "serving_queue_delay_s",
                    "Per-request simulated wait before batch service",
                    labelnames=("tier",)),
                "batch_latency": reg.histogram(
                    "serving_batch_latency_s",
                    "Routed batch service makespan (simulated)"),
                "energy": reg.counter(
                    "serving_energy_j_total",
                    "Batch energy attributed per member tier",
                    labelnames=("tier",)),
                "sequences": reg.counter(
                    "serving_sequences_total",
                    "Sequences entering service per tier",
                    labelnames=("tier",)),
                "ipw": reg.gauge(
                    "serving_ipw_seq_per_j",
                    "Cumulative inferences-per-watt-second (sequences/J) "
                    "per tier",
                    labelnames=("tier",)),
                "prefill_saved": reg.counter(
                    "serving_prefill_bytes_saved_total",
                    "KV bytes prefix sharing did not re-prefill"),
                "completed": reg.counter(
                    "serving_requests_completed_total",
                    "Requests retired per tier", labelnames=("tier",)),
                "early_stop": reg.counter(
                    "serving_early_stop_released_total",
                    "KV budget units (blocks/slots) released by CSVET "
                    "early stops"),
                "reanneal": reg.counter(
                    "serving_reanneal_boundaries_total",
                    "Drift re-anneal notifications from the control loop"),
                "inflight": reg.gauge(
                    "serving_inflight_batches",
                    "Batches mid-decode right now"),
                "preempt": reg.counter(
                    "serving_preemptions_total",
                    "In-flight batches cut at a decode boundary, by cause",
                    labelnames=("reason",)),
                "deadline_miss": reg.counter(
                    "serving_deadline_miss_total",
                    "Queued requests cancelled past their tier deadline",
                    labelnames=("tier",)),
                "retries": reg.counter(
                    "serving_retries_total",
                    "Fault-evicted requests re-queued with backoff",
                    labelnames=("tier",)),
                "shed": reg.counter(
                    "serving_load_shed_total",
                    "Queued requests dropped at a shed watermark",
                    labelnames=("tier",)),
                "resume_saved": reg.counter(
                    "serving_resume_prefill_bytes_saved_total",
                    "Resume-prefill KV bytes served from parked "
                    "prefix-pool chains instead of re-prefilled"),
            }
        # per-tier running totals behind the IPW attribution gauge
        self._tier_energy: Dict[str, float] = {}
        self._tier_seqs: Dict[str, int] = {}
        self.clock = 0.0               # simulated now
        self.pipeline_free_t = 0.0     # simulated pipeline horizon
        self.inflight: List[_InflightEntry] = []
        # completed results are the caller's to drain: pop entries after
        # reading them (the RoutedServingEngine shim does) — a long-lived
        # server must not retain every GenerationResult forever
        self.completed: Dict[int, CompletedRequest] = {}
        # requests removed without a result: deadline misses, shed load,
        # retry-budget exhaustion — keyed by id, with the cancel reason
        self.cancelled: Dict[int, Tuple[ServeRequest, str]] = {}
        self.records: Deque[BatchRecord] = deque(maxlen=1024)
        self.reroute_boundaries = 0    # ControlLoop re-anneal notifications
        self._reroute_pending = False
        self._batch_id = 0
        self._base_rng = None          # lazily: jax import only when needed
        # the lifecycle policies' own ledgers (metrics may be disabled)
        self.preemptions: Dict[str, int] = {}      # reason -> count
        self.deadline_misses = 0
        self.retries_total = 0
        self.shed_total = 0
        self.resume_full_tokens = 0    # re-prefill tokens a pool-less
        self.resume_tail_tokens = 0    # resume would have moved vs moved
        # fault state pushed in over the DriftEvent bus (chaos harness /
        # SafetyMonitor): failed devices, KV squeeze, kernel slowdown
        self._failed_devices: set = set()
        self.kv_reserve = 0            # blocks withheld from admission
        self.latency_inflation = 1.0   # slow-kernel service-time factor
        if config.deadline_factor is not None and \
                self.queue.deadline_factor is None:
            self.queue.deadline_factor = config.deadline_factor

    # ----------------------------------------------------------- admission
    def _capacity_free(self) -> Optional[int]:
        """Backend KV budget remaining (blocks or slots); falls back to the
        legacy ``slots_free`` for duck-typed stub backends."""
        cap = getattr(self.backend, "capacity_free", _MISSING)
        if cap is _MISSING:
            cap = self.backend.slots_free
        if cap is not None and self.kv_reserve:
            cap = max(0, cap - self.kv_reserve)
        return cap

    def _capacity_total(self) -> Optional[int]:
        cap = getattr(self.backend, "capacity_total", _MISSING)
        if cap is _MISSING:
            cap = getattr(self.backend, "max_slots", None)
        return cap

    def _request_cost(self, req: ServeRequest) -> int:
        # marginal (post-dedup) pricing: a backend with a resident prefix
        # pool charges only the tail blocks a request would actually
        # allocate — its trie-cached prefix is free — so cache-hot requests
        # admit cheaply and the block budget reflects real memory. A
        # resumed request prices each sample's own history (divergent after
        # the original prompt); its parked chains make those near-free.
        mrc = getattr(self.backend, "marginal_request_cost", None)
        if mrc is not None:
            if req.resume is not None:
                return sum(mrc(p, req.remaining_new, 1)
                           for p in req.resume.prompts)
            return mrc(req.prompt, req.max_new_tokens, req.n_samples)
        rc = getattr(self.backend, "request_cost", None)
        if rc is None:
            return req.n_samples
        if req.resume is not None:
            return sum(rc(len(p), req.remaining_new, 1)
                       for p in req.resume.prompts)
        return rc(len(req.prompt), req.max_new_tokens, req.n_samples)

    def _kv_bytes_in_use(self) -> Optional[int]:
        """Occupied KV bytes right now, priced at the backend's actual cache
        element format (int8 KV halves this per block)."""
        blocks = getattr(self.backend, "blocks_in_use", None)
        alloc = getattr(self.backend, "allocator", None)
        ktb = getattr(self.backend, "kv_token_bytes", None)
        if blocks is None or alloc is None or ktb is None:
            return None
        return int(blocks * alloc.block_size * ktb)

    def submit(self, prompt: np.ndarray, tier, n_samples: int = 1,
               max_new_tokens: Optional[int] = None,
               temperature: Optional[float] = None, rng=None,
               extras: Optional[Dict] = None,
               arrival_s: Optional[float] = None) -> AdmissionResult:
        return self.queue.submit(
            prompt, tier, n_samples=n_samples,
            max_new_tokens=(max_new_tokens if max_new_tokens is not None
                            else self.config.max_new_tokens),
            temperature=(temperature if temperature is not None
                         else self.config.temperature),
            rng=rng, extras=extras,
            arrival_s=self.clock if arrival_s is None else arrival_s,
            budget=self._capacity_total(),
            cost=getattr(self.backend, "request_cost", None))

    # ------------------------------------------------------------- control
    def on_reorchestrate(self, healthy: Optional[Sequence[str]] = None
                         ) -> None:
        """ControlLoop hook: a drift-triggered re-anneal landed. The
        post-drift healthy set is pushed into the router (idempotent when
        the loop already synced a shared router), and the next batch
        *formation* re-pulls the refreshed frontier; the boundary is marked
        so telemetry shows where placement changed."""
        if healthy is not None and hasattr(self.router, "set_healthy"):
            self.router.set_healthy(healthy)
        self.reroute_boundaries += 1
        self._reroute_pending = True
        if self._m is not None:
            self._m["reanneal"].inc()

    def advance_to(self, t_s: float) -> None:
        """Move the simulated clock forward (idle time between arrivals)."""
        self.clock = max(self.clock, t_s)

    def _router_devices(self) -> Optional[List[str]]:
        orch = getattr(self.router, "orchestrator", None)
        devices = getattr(orch, "devices", None)
        if devices is None:
            return None
        return [d.name for d in devices]

    def _push_healthy(self) -> None:
        names = self._router_devices()
        if names is not None and hasattr(self.router, "set_healthy"):
            self.router.set_healthy(
                [n for n in names if n not in self._failed_devices])
            self._reroute_pending = True

    def on_drift(self, event) -> None:
        """`DriftEvent`-bus consumer (``safety.subscribe(sched.on_drift)``).

        ``device_failed`` is the one that matters for in-flight work: every
        batch whose routed assignment includes the failed device is
        preempted at the next decode boundary and its requests re-queued
        with retry backoff — they must not run to completion against a dead
        placement. ``device_recovered`` restores the routing surface. The
        chaos-harness kinds ``kv_squeeze`` (blocks withheld from admission)
        and ``slow_kernel`` (service-time inflation) adjust the admission /
        pricing state the next formations see. Re-anneal decisions stay the
        `ControlLoop`'s job — this hook only keeps the scheduler's own
        state (in-flight batches, admission headroom) consistent with the
        event."""
        kind = getattr(event, "kind", None)
        if kind == "device_failed":
            self._failed_devices.add(event.device)
            for entry in list(self.inflight):
                assignment = getattr(entry.decision, "assignment", None)
                names = getattr(assignment, "device_names", None)
                if names is not None and event.device in names():
                    self.preempt(entry, "fault")
            self._push_healthy()
        elif kind == "device_recovered":
            self._failed_devices.discard(event.device)
            self._push_healthy()
        elif kind == "kv_squeeze":
            self.kv_reserve = max(0, int(event.value))
        elif kind == "slow_kernel":
            self.latency_inflation = max(1.0, float(event.value))

    # ---------------------------------------------------------- preemption
    def _consumed_fraction(self, entry: _InflightEntry) -> float:
        """Share of the batch's routed service time already spent, on the
        decode-progress clock (a batch still chunk-prefilling has step 0)."""
        h = entry.handle
        mx = max(int(getattr(h, "max_new", 1) or 1), 1)
        return min(1.0, int(getattr(h, "step", 0)) / mx)

    def _snapshot(self, entry: _InflightEntry) -> List[Optional[ResumeState]]:
        """Decode-boundary snapshot: per-request sample histories + emitted
        tokens/logprobs, composed over any earlier preemption's state.
        Speculative rows are trimmed to the request's minimum committed
        count so the resumed bucket stays rectangular. ``None`` for a
        request with nothing emitted yet (it resumes as a fresh request)."""
        h = entry.handle
        sp = getattr(h, "spec", None)
        out_toks = getattr(h, "out_toks", None) or []
        if sp is not None:
            toks = [np.asarray(row, np.int64) for row in sp.toks]
            lps = [np.asarray(row, np.float64) for row in sp.lps]
        elif out_toks:
            stacked = np.stack(out_toks, axis=1)       # (B, T[, K])
            lstack = np.stack(h.out_lps, axis=1)       # (B, T)
            toks = [stacked[i] for i in range(stacked.shape[0])]
            lps = [lstack[i].astype(np.float64)
                   for i in range(lstack.shape[0])]
        else:
            n = sum(r.n_samples for r in entry.requests)
            toks = [np.zeros(0, np.int64)] * n
            lps = [np.zeros(0, np.float64)] * n
        states: List[Optional[ResumeState]] = []
        off = 0
        for req in entry.requests:
            k = req.n_samples
            rows_t, rows_l = toks[off:off + k], lps[off:off + k]
            off += k
            keep = min(len(r) for r in rows_t)
            prev = req.resume
            if keep == 0 and prev is None:
                states.append(None)
                continue
            prompts, full_t, full_l = [], [], []
            for i in range(k):
                nt, nl = rows_t[i][:keep], rows_l[i][:keep]
                hist = prev.prompts[i] if prev is not None else \
                    np.asarray(req.prompt)
                prompts.append(np.concatenate([hist, nt.astype(hist.dtype)],
                                              axis=0) if keep else hist)
                if prev is not None:
                    full_t.append(np.concatenate(
                        [prev.toks[i], nt.astype(prev.toks[i].dtype)],
                        axis=0) if keep else prev.toks[i])
                    full_l.append(np.concatenate([prev.lps[i], nl]))
                else:
                    full_t.append(nt)
                    full_l.append(nl)
            states.append(ResumeState(prompts=prompts, toks=full_t,
                                      lps=full_l))
        return states

    def preempt(self, entry: _InflightEntry, reason: str
                ) -> List[ServeRequest]:
        """Cut an in-flight batch at the current decode-step boundary.

        The victim's per-request state becomes `ResumeState` snapshots, the
        backend parks its filled blocks in the resident prefix pool
        (``park_batch`` — resume prefills only the post-preemption tail) or
        releases them, and the requests return to the *head* of the queue
        with their original arrival/seq. The simulated pipeline rolls back
        to the preemption instant, which is where the interactive-tier p95
        win comes from. Fault evictions (+``reason="fault"``) additionally
        arm exponential retry backoff; a request past ``max_retries`` is
        cancelled as failed instead of re-queued."""
        if entry not in self.inflight:
            raise ValueError("preempt of a batch that is not in flight")
        frac = self._consumed_fraction(entry)
        t_p = min(entry.done_t,
                  max(self.clock,
                      entry.start_t + entry.decision.latency_s * frac))
        states = self._snapshot(entry)
        park = getattr(self.backend, "park_batch", None)
        if park is not None:
            # one history per sequence row, aligned with the handle's
            # (prompt x repeat) order — a request with nothing committed
            # contributes its bare prompt (already trie-indexed at prefill)
            histories = []
            for req, st in zip(entry.requests, states):
                if st is not None:
                    histories.extend(st.prompts)
                else:
                    histories.extend([np.asarray(req.prompt)]
                                     * req.n_samples)
            park(entry.handle, histories)
        else:
            release = getattr(self.backend, "release", None)
            if release is not None:
                release(entry.handle)
        self.inflight.remove(entry)
        # the victim held the pipeline tail: service time it will not use
        # returns to the pipeline (preemption of an interior entry cannot
        # shorten later entries' already-fixed start times)
        if entry.done_t >= self.pipeline_free_t - 1e-12:
            self.pipeline_free_t = t_p
        self.clock = max(self.clock, t_p)
        entry.record.preempted = reason
        entry.record.preempted_t_s = t_p
        self.preemptions[reason] = self.preemptions.get(reason, 0) + 1
        tracer = self.obs.tracer
        requeue: List[ServeRequest] = []
        for req, st in zip(entry.requests, states):
            req.resume = st
            req.preemptions += 1
            if reason == "fault":
                req.retries += 1
                self.retries_total += 1
                if self._m is not None:
                    self._m["retries"].inc(tier=req.tier_name)
                if req.retries > self.config.max_retries:
                    self._cancel(req, "retry_exhausted")
                    continue
                req.not_before_s = t_p + self.config.retry_backoff_s * \
                    (2 ** (req.retries - 1))
            requeue.append(req)
            if tracer.enabled:
                tracer.emit("preempt", t_p, request_id=req.id,
                            batch_id=entry.record.batch_id, reason=reason,
                            tier=req.tier_name,
                            emitted=req.emitted_tokens)
        self.queue.push_front(requeue)
        if self._m is not None:
            self._m["preempt"].inc(reason=reason)
            self._m["inflight"].set(len(self.inflight))
        return requeue

    def _cancel(self, req: ServeRequest, reason: str) -> None:
        """Drop a request without a result (deadline / shed / retry budget).
        Only queued (KV-less) requests are cancelled, so nothing can leak;
        a parked resume chain stays an idle, LRU-evictable trie entry."""
        self.cancelled[req.id] = (req, reason)
        if reason == "deadline":
            self.deadline_misses += 1
            if self._m is not None:
                self._m["deadline_miss"].inc(tier=req.tier_name)
        elif reason == "shed":
            self.shed_total += 1
            if self._m is not None:
                self._m["shed"].inc(tier=req.tier_name)
        if self.obs.tracer.enabled:
            self.obs.tracer.emit("cancel", self.clock, request_id=req.id,
                                 reason=reason, tier=req.tier_name,
                                 deadline_s=req.deadline_s)

    def _maybe_preempt_for_tier(self) -> Optional[Tuple]:
        """Tier-priority preemption check: when the best waiting request
        outranks every member of the simulated-pipeline tail batch (and the
        projected wait is worth it), cut the tail. Returns the waiting
        request's bucket key so the caller forms its batch first — the
        victims re-queued at their original seq would otherwise win the
        FIFO pop right back."""
        cfg = self.config
        if not cfg.preempt or not self.inflight:
            return None
        if getattr(self.backend, "release", None) is None:
            return None                     # backend cannot release mid-batch
        head = self.queue.peek_ready(self.clock)
        if head is None:
            return None
        entry = max(self.inflight, key=lambda e: e.done_t)
        if tier_priority(head.tier) <= max(tier_priority(r.tier)
                                           for r in entry.requests):
            return None
        gain = entry.done_t - max(self.clock, head.arrival_s)
        if gain <= cfg.preempt_min_gain_s:
            return None
        if any(r.preemptions >= cfg.preempt_max_per_request
               for r in entry.requests):
            return None                     # no-starvation: victim cap hit
        if cfg.preempt_age_bound_s is not None and \
                any(self.clock - r.arrival_s > cfg.preempt_age_bound_s
                    for r in entry.requests):
            return None                     # no-starvation: victim too old
        self.preempt(entry, "tier")
        return self.queue._key(head)

    # ------------------------------------------------------------ batching
    def _batch_rng(self, requests: List[ServeRequest]):
        import jax
        carried = [r.rng for r in requests if r.rng is not None]
        if len(requests) == 1 and carried:
            # parity path: a single-request batch follows the exact split
            # sequence of the pre-refactor generate (call key -> group key)
            base = carried[0]
        elif carried:
            # caller-seeded stream: vary with the caller's key (two runs
            # differing only in rng must produce different samples), folded
            # with the batch index to decorrelate batches of one call
            base = jax.random.fold_in(carried[0], self._batch_id)
        else:
            if self._base_rng is None:
                self._base_rng = jax.random.key(self.config.seed)
            base = jax.random.fold_in(self._base_rng, self._batch_id)
        return jax.random.split(base)[1]

    def _form_batch(self, bucket: Optional[Tuple] = None
                    ) -> Optional[_InflightEntry]:
        free = self._capacity_free()
        if free is not None and free <= 0:
            return None
        reqs = self.queue.pop_batch(self.config.max_batch_requests, free,
                                    self._request_cost, now=self.clock,
                                    bucket=bucket)
        if not reqs:
            return None
        # extras compatibility: one batch stacks one set of per-request
        # extras rows, so a request with different (or no) extras keys
        # splits the batch there (it heads the next one — FIFO preserved)
        keys0 = frozenset(reqs[0].extras or ())
        cut = next((i for i, r in enumerate(reqs)
                    if frozenset(r.extras or ()) != keys0), None)
        if cut is not None:
            self.queue.push_front(reqs[cut:])
            reqs = reqs[:cut]
        # cap-aware sizing: merged caps tighten to the strictest member, and
        # feasibility depends on batch size (re-costed makespan grows with
        # it) — shed the newest half back to the queue until the routed
        # point meets caps or the batch is a single request. Each candidate
        # is routed/costed at what would actually execute: the members'
        # (possibly admission-raised) mean sampling budget and the bucket's
        # prompt length / decode horizon, not the router's canonical
        # workload — SLA caps must hold for the real batch.
        while True:
            route_kwargs = dict(
                samples=math.ceil(sum(r.n_samples for r in reqs)
                                  / len(reqs)),
                prompt_tokens=len(reqs[0].effective_prompt),
                decode_tokens=reqs[0].remaining_new)
            if self.spec_planner is not None:
                decision = self.spec_planner.route_batch(
                    self.router, [r.tier for r in reqs], **route_kwargs)
            else:
                decision = self.router.route_batch(
                    [r.tier for r in reqs], **route_kwargs)
            if decision.meets_caps or len(reqs) == 1 or \
                    not self.config.respect_caps:
                break
            keep = max(1, len(reqs) // 2)
            self.queue.push_front(reqs[keep:])
            reqs = reqs[:keep]
        # the routed draft depth applies to THIS batch only: the backend
        # consumes the note at its next start_batch
        spec_plan = getattr(decision, "spec", None)
        if spec_plan is not None and hasattr(self.backend, "note_spec"):
            self.backend.note_spec(spec_plan.n)

        start = max(self.clock, self.pipeline_free_t)
        # latency_inflation > 1 models injected slow-kernel faults (chaos
        # harness): the routed estimate stands, service time stretches
        done_t = start + decision.latency_s * self.latency_inflation
        self.pipeline_free_t = done_t
        # a resumed request contributes its n_samples per-sample histories
        # as distinct prompt rows (repeat 1 each): samples may have diverged
        prompts: List[np.ndarray] = []
        nsamps: List[int] = []
        for r in reqs:
            if r.resume is not None:
                prompts.extend(r.resume.prompts)
                nsamps.extend([1] * r.n_samples)
            else:
                prompts.append(r.prompt)
                nsamps.append(r.n_samples)
        extras = None
        if reqs[0].extras:
            extras = {k: np.stack([r.extras[k]
                                   for r in reqs
                                   for _ in range(r.n_samples
                                                  if r.resume is not None
                                                  else 1)])
                      for k in reqs[0].extras}
        tracer = self.obs.tracer
        tracer.batch_context = self._batch_id
        try:
            handle = self.backend.start_batch(
                prompts, nsamps, reqs[0].remaining_new,
                reqs[0].temperature, self._batch_rng(reqs), extras)
        finally:
            tracer.batch_context = None
        self.backend.note_placement(decision.assignment)

        # resume accounting: a parked victim's full-prefix chains come back
        # as trie hits, so only the post-preemption tail prefills — the
        # bytes-saved counter is the robustness claim's receipt
        resume_reqs = resume_full = resume_tail = 0
        layout = getattr(handle, "paged", None)
        hit_counts = layout.hit_counts if layout is not None else []
        bs = layout.block_size if layout is not None else 1
        row = 0
        for r in reqs:
            n_rows = r.n_samples if r.resume is not None else 1
            if r.resume is not None:
                resume_reqs += 1
                for i in range(row, row + n_rows):
                    full = len(prompts[i])
                    hits = hit_counts[i] if i < len(hit_counts) else 0
                    resume_full += full
                    resume_tail += full - hits * bs
                if tracer.enabled:
                    tracer.emit("resume", start, request_id=r.id,
                                batch_id=self._batch_id,
                                tier=r.tier_name,
                                emitted=r.emitted_tokens,
                                preemptions=r.preemptions)
            row += n_rows
        if resume_reqs:
            self.resume_full_tokens += resume_full
            self.resume_tail_tokens += resume_tail
            if self._m is not None:
                ktb = getattr(self.backend, "kv_token_bytes", 0) or 0
                self._m["resume_saved"].inc(
                    (resume_full - resume_tail) * int(ktb))

        tier_mix: Dict[str, int] = {}
        for r in reqs:
            tier_mix[r.tier_name] = tier_mix.get(r.tier_name, 0) + 1
        # the batch's ACTUAL speculation state comes off the handle (the
        # backend may run at its default depth with no planner attached)
        hspec = getattr(handle, "spec", None)
        record = BatchRecord(
            batch_id=self._batch_id, t_s=start,
            bucket=len(reqs[0].effective_prompt), n_requests=len(reqs),
            n_sequences=sum(r.n_samples for r in reqs), tier_mix=tier_mix,
            queue_delay_s=max(start - r.arrival_s for r in reqs),
            point_index=decision.point_index,
            energy_j=decision.energy_j, latency_s=decision.latency_s,
            meets_caps=decision.meets_caps, reroute=self._reroute_pending,
            kv_blocks_in_use=getattr(self.backend, "blocks_in_use", None),
            prefill_bytes_saved=float(getattr(handle, "prefill_bytes_saved",
                                              0.0)),
            pool_hit_blocks=int(getattr(handle, "pool_hit_blocks", 0)),
            pool_evictions=int(getattr(handle, "pool_evictions", 0)),
            quant=getattr(self.backend, "quant_format", "bf16"),
            kv_format=getattr(self.backend, "kv_format", "bf16"),
            weight_bytes=getattr(self.backend, "weight_bytes", None),
            kv_bytes_in_use=self._kv_bytes_in_use(),
            spec_policy=hspec.policy.name if hspec is not None else "off",
            spec_n=hspec.n if hspec is not None else 0,
            spec_accept_rate=(spec_plan.accept_rate
                              if spec_plan is not None and spec_plan.enabled
                              else None),
            resume_requests=resume_reqs,
            resume_full_tokens=resume_full,
            resume_tail_tokens=resume_tail,
            request_entries=[{"id": r.id, "tier": r.tier_name,
                              "n_samples": r.n_samples,
                              "queue_delay_s": start - r.arrival_s,
                              "resumed": r.resume is not None}
                             for r in reqs])
        self._reroute_pending = False
        self._batch_id += 1
        self.records.append(record)
        if self.trace is not None:
            self.trace.ingest_serve(record,
                                    signals=plan_signals(decision))
        if tracer.enabled:
            tracer.emit("schedule", start, batch_id=record.batch_id,
                        point_index=record.point_index,
                        energy_j=record.energy_j,
                        latency_s=record.latency_s,
                        meets_caps=record.meets_caps,
                        n_requests=record.n_requests,
                        tier_mix=dict(tier_mix))
            for r in reqs:
                # per-member wait on the sim clock; batch_id joins the
                # request to its batch-level schedule/prefill/decode spans
                tracer.emit("queue", r.arrival_s, start, request_id=r.id,
                            batch_id=record.batch_id, tier=r.tier_name)
        if self._m is not None:
            self._observe_batch(record, decision, reqs)
        return _InflightEntry(handle, reqs, decision, record, start, done_t)

    def _observe_batch(self, record: BatchRecord, decision,
                       reqs: List[ServeRequest]) -> None:
        m = self._m
        m["occupancy"].observe(record.n_requests)
        m["batch_latency"].observe(record.latency_s)
        m["prefill_saved"].inc(record.prefill_bytes_saved)
        for r in reqs:
            m["queue_delay"].observe(record.t_s - r.arrival_s,
                                     tier=r.tier_name)
            m["sequences"].inc(r.n_samples, tier=r.tier_name)
            self._tier_seqs[r.tier_name] = \
                self._tier_seqs.get(r.tier_name, 0) + r.n_samples
        # per-tier energy attribution when the router prices it (v2-costed
        # batch decisions); stub routers without it attribute nothing
        per_tier = getattr(decision, "per_tier_energy_j", None) or {}
        for tier, e in per_tier.items():
            m["energy"].inc(e, tier=tier)
            self._tier_energy[tier] = self._tier_energy.get(tier, 0.0) + e
        for tier in {r.tier_name for r in reqs}:
            e = self._tier_energy.get(tier, 0.0)
            if e > 0.0:
                m["ipw"].set(self._tier_seqs.get(tier, 0) / e, tier=tier)

    def early_stop(self, request_id: int,
                   sample_indices: Optional[Sequence[int]] = None) -> int:
        """CSVET early-stop hook: a verified pass makes a request's
        remaining samples moot (pass@k is ``any(pass)``), so release their
        KV budget *now* instead of at batch retirement. ``sample_indices``
        selects which of the request's samples to release (default: all).
        Returns the blocks/slots actually returned to the budget (0 when
        the request is not in flight or the backend has no early release)."""
        rel = getattr(self.backend, "release_sequences", None)
        if rel is None:
            return 0
        for entry in self.inflight:
            off = 0
            for r in entry.requests:
                if r.id == request_id:
                    idxs = (range(r.n_samples) if sample_indices is None
                            else list(sample_indices))
                    bad = [i for i in idxs if not 0 <= i < r.n_samples]
                    if bad:
                        # an out-of-range index would map into a *different*
                        # request's rows and release its KV budget under it
                        raise ValueError(
                            f"sample indices {bad} out of range for request "
                            f"{request_id} with {r.n_samples} samples")
                    freed = rel(entry.handle, [off + i for i in idxs])
                    if self.obs.tracer.enabled:
                        self.obs.tracer.emit(
                            "early_stop", self.clock, request_id=request_id,
                            batch_id=entry.record.batch_id, freed=freed,
                            n_released=len(list(idxs)))
                    if self._m is not None and freed:
                        self._m["early_stop"].inc(freed)
                    return freed
                off += r.n_samples
        return 0

    def _merge_resumed(self, req: ServeRequest, tails) -> Any:
        """Splice a resumed request's pre-preemption snapshot onto its
        post-resume tail results: token streams concatenate (then re-truncate
        at the first eos over the WHOLE stream, matching an uninterrupted
        run), per-sample mean logprobs merge as the token-count-weighted
        mean — exactly the uninterrupted mean for deterministic decode."""
        from repro.serving.backend import GenerationResult
        st = req.resume
        eos = getattr(self.backend, "eos_token", None)
        samples, logprobs = [], []
        for i, tail in enumerate(tails):
            pre_t, pre_l = st.toks[i], st.lps[i]
            new = tail.samples[0]
            full = np.concatenate([pre_t.astype(new.dtype), new], axis=0) \
                if len(pre_t) else new
            if eos is not None and full.ndim == 1:
                hits = np.nonzero(full == eos)[0]
                if hits.size:
                    full = full[: hits[0]]
            samples.append(full)
            # tail.logprobs[0] averaged over the tail's full decode horizon
            n_new = req.max_new_tokens - len(pre_l)
            tot = len(pre_l) + n_new
            logprobs.append(float(
                (pre_l.sum() + tail.logprobs[0] * n_new) / max(tot, 1)))
        req.resume = None
        return GenerationResult(
            prompt=req.prompt, samples=samples, logprobs=logprobs,
            prefill_tokens=len(req.prompt),
            decode_tokens=req.max_new_tokens * req.n_samples)

    def _enforce_deadlines(self) -> None:
        for req in self.queue.expire(self.clock):
            self._cancel(req, "deadline")

    def _enforce_shedding(self) -> None:
        """Watermark-driven load shedding, oldest-economy-first. Queue-depth
        and evictable-KV watermarks both shed from the *queue* (cancelled
        requests hold no blocks, so shedding can never leak); when the KV
        watermark trips with an empty queue the pressure is in-flight, and
        the lowest-priority pipeline-tail batch is preempted instead (its
        parked chains are evictable, which is what the watermark wants)."""
        cfg = self.config
        if cfg.shed_queue_depth is not None:
            while self.queue.pending > cfg.shed_queue_depth:
                victim = self.queue.shed_oldest(tier_priority)
                if victim is None:
                    break
                self._cancel(victim, "shed")
        if cfg.shed_kv_free_frac is not None:
            total = self._capacity_total()
            free = self._capacity_free()
            if total and free is not None and \
                    free < cfg.shed_kv_free_frac * total:
                victim = self.queue.shed_oldest(tier_priority)
                if victim is not None:
                    self._cancel(victim, "shed")
                elif self.inflight and cfg.preempt and \
                        getattr(self.backend, "release", None) is not None:
                    entry = min(
                        self.inflight,
                        key=lambda e: (max(tier_priority(r.tier)
                                           for r in e.requests), -e.done_t))
                    if all(r.preemptions < cfg.preempt_max_per_request
                           for r in entry.requests):
                        self.preempt(entry, "shed")

    def _retire(self, entry: _InflightEntry) -> None:
        results = self.backend.finalize(entry.handle)
        self.clock = max(self.clock, entry.done_t)
        tracer = self.obs.tracer
        sp = getattr(entry.handle, "spec", None)
        if sp is not None:
            # measured accept counts land on the record, and a "spec" trace
            # record closes the loop: CalibrationFitter turns these into
            # per-(model, tier, policy) accept rates for SpecPlanner.refresh
            entry.record.spec_proposed = int(sp.proposed)
            entry.record.spec_accepted = int(sp.accepted)
            entry.record.spec_accept_rate = float(sp.accept_rate)
            if self.trace is not None and sp.proposed:
                cfg = getattr(getattr(self.backend, "model", None),
                              "cfg", None)
                merged = getattr(entry.decision, "tier", None)
                rec = {"kind": "spec", "t_s": float(entry.done_t),
                       "policy": str(sp.policy.name), "n": int(sp.n),
                       "proposed": int(sp.proposed),
                       "accepted": int(sp.accepted)}
                if cfg is not None:
                    rec["model"] = str(cfg.name)
                if merged is not None:
                    rec["tier"] = str(merged.name)
                self.trace.ingest(rec)
        off = 0
        for req in entry.requests:
            if req.resume is not None:
                # resumed request: n_samples single-sample results, each the
                # post-preemption tail — splice onto the snapshot so the
                # caller sees one uninterrupted completion
                k = req.n_samples
                res = self._merge_resumed(req, results[off:off + k])
                off += k
            else:
                res = results[off]
                off += 1
            self.completed[req.id] = CompletedRequest(
                request=req, result=res, batch_id=entry.record.batch_id,
                queue_delay_s=entry.start_t - req.arrival_s,
                latency_s=entry.done_t - req.arrival_s,
                decision=entry.decision)
            if tracer.enabled:
                tracer.emit("release", entry.done_t, request_id=req.id,
                            batch_id=entry.record.batch_id,
                            tier=req.tier_name,
                            queue_delay_s=entry.start_t - req.arrival_s,
                            latency_s=entry.done_t - req.arrival_s)
            if self._m is not None:
                self._m["completed"].inc(tier=req.tier_name)

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler iteration: enforce lifecycle policies (deadlines,
        shedding), check tier preemption, form batches while capacity
        allows, then one decode token per in-flight batch; retire finished
        batches. Returns False when there was nothing to do."""
        progressed = False
        self._enforce_deadlines()
        self._enforce_shedding()
        # tier preemption returns the outranking waiter's bucket so its
        # batch forms FIRST — the re-queued victims hold older seqs and
        # would win the FIFO pop right back otherwise
        bucket_pref = self._maybe_preempt_for_tier()
        while len(self.inflight) < self.config.max_inflight_batches:
            entry = self._form_batch(bucket_pref)
            bucket_pref = None
            if entry is None:
                break
            self.inflight.append(entry)
            progressed = True
        tracer = self.obs.tracer
        for entry in list(self.inflight):
            if not entry.handle.done:
                tracer.batch_context = entry.record.batch_id
                try:
                    self.backend.decode_step(entry.handle)
                finally:
                    tracer.batch_context = None
                progressed = True
            if entry.handle.done:
                self.inflight.remove(entry)
                self._retire(entry)
                progressed = True
        if not progressed and not self.inflight and self.queue.pending:
            # everything queued is backoff-parked: jump the sim clock to the
            # earliest retry instant instead of reporting starvation
            nb = self.queue.earliest_not_before()
            if nb is not None and nb > self.clock:
                self.advance_to(nb)
                progressed = True
        if self._m is not None:
            self._m["inflight"].set(len(self.inflight))
        return progressed

    def run_until_idle(self, max_steps: int = 10 ** 6
                       ) -> Dict[int, CompletedRequest]:
        """Drain the queue and every in-flight batch; returns ``completed``
        (request id -> CompletedRequest)."""
        steps = 0
        while (self.queue.pending or self.inflight) and steps < max_steps:
            if not self.step():
                break                      # starved (e.g. zero slots free)
            steps += 1
        return self.completed

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        done = list(self.completed.values())
        per_tier: Dict[str, List[float]] = {}
        for c in done:
            per_tier.setdefault(c.request.tier_name, []).append(c.latency_s)
        return {
            "completed": len(done),
            "batches": len(self.records),
            "mean_batch_requests": (float(np.mean([r.n_requests
                                                   for r in self.records]))
                                    if self.records else 0.0),
            "caps_met_fraction": (float(np.mean([r.meets_caps
                                                 for r in self.records]))
                                  if self.records else 1.0),
            "energy_j": sum(r.energy_j for r in self.records),
            "sequences": sum(r.n_sequences for r in self.records),
            "makespan_s": self.pipeline_free_t,
            "latency_p95_s": {t: float(np.percentile(v, 95))
                              for t, v in sorted(per_tier.items())},
            "reroute_boundaries": self.reroute_boundaries,
            "spec_proposed": sum(r.spec_proposed for r in self.records),
            "spec_accepted": sum(r.spec_accepted for r in self.records),
            # steady-state prefix-pool accounting, accumulated across
            # batches (per-batch values ride each BatchRecord)
            "pool_hit_blocks": sum(r.pool_hit_blocks for r in self.records),
            "pool_evictions": sum(r.pool_evictions for r in self.records),
            "prefill_bytes_saved": sum(r.prefill_bytes_saved
                                       for r in self.records),
            # robustness ledger: preemption / lifecycle-policy outcomes
            "preemptions": dict(self.preemptions),
            "preemptions_total": sum(self.preemptions.values()),
            "deadline_misses": self.deadline_misses,
            "retries_total": self.retries_total,
            "shed_total": self.shed_total,
            "cancelled": len(self.cancelled),
            "resume_full_tokens": self.resume_full_tokens,
            "resume_tail_tokens": self.resume_tail_tokens,
        }


def plan_signals(decision) -> Dict[str, dict]:
    """Per-stage `SignalSet.as_dict()` snapshots of a routed batch — present
    when the orchestrator costs plans with the v2 model (`StageExecutionV2`
    records carry the signal triple). Mirrors the control loop's per-step
    snapshot so serve traces feed the same `CalibrationFitter`."""
    out: Dict[str, dict] = {}
    costs = getattr(decision, "batch_costs", None)
    if costs is None:
        return out
    for e in costs.executions:
        sig = getattr(e, "signals", None)
        if sig is not None:
            out[e.stage.name] = sig.as_dict()
    return out
