"""Scheduler-centric serving: admission -> mixed-tier batching -> backend.

The pre-refactor serving stack picked one operating point per blocking
``generate`` call, so mixed-tier request streams serialized and prefill /
weight-streaming energy was never amortized across tiers. This module is the
policy layer that closes that gap:

* ``RequestQueue`` — tier-aware admission control: unknown tiers are
  rejected at the door, per-tier queue depth is bounded, and a tier's
  coverage floor (``SLATier.min_quality``) raises the request's sampling
  budget on admission. Admitted requests wait in per-bucket FIFO order
  (bucket = prompt length x decode horizon x temperature — the static
  shapes the backend jits on).
* ``ContinuousBatchingScheduler`` — forms mixed-tier batches from the
  oldest bucket, routes each batch to ONE shared operating point via the
  router's batch-aware ``route_batch`` (caps merge to the tightest member
  tier; every frontier point is re-costed under the batch workload, so
  decode weight-streaming amortization is priced in), and interleaves
  prefill of new batches with decode steps of in-flight ones. Batches
  shrink until the merged caps are satisfiable whenever the frontier admits
  any feasible point at that size — a tight-SLA member caps how much
  batching its batch can absorb instead of silently blowing its cap.

Admission is costed in the backend's own KV currency: a paged backend
prices a request in *blocks* at shared-prefix cost (`request_cost` — the
k repeats a tier's coverage floor demands share their prefix blocks, so a
raised sampling budget is far cheaper than k dense slots), a dense backend
in sequence slots; `early_stop` releases a request's remaining samples'
blocks the moment a CSVET verifier confirms a pass, instead of waiting for
batch retirement.

Routing happens only at batch *formation*: a drift-triggered re-anneal
(`ControlLoop` calls ``on_reorchestrate``) therefore takes effect at the
next batch boundary — in-flight batches finish on the plan they were priced
against.

Simulated time: placement is the orchestrator's simulated stage->device
plan, so service time is simulated too (execution itself runs on whatever
accelerator JAX sees). Batches serialize on one simulated pipeline: a batch
formed at clock ``t`` starts at ``max(t, pipeline_free_t)`` and occupies the
pipeline for its re-costed makespan. Per-request queue delay and latency in
`CompletedRequest` are in this simulated clock, which is what the SLA caps
and `benchmarks/serving_schedule.py` measure. The real decode interleaving
across in-flight batches exists so wall-clock work overlaps; it does not
change simulated accounting.

The backend is duck-typed (``start_batch`` / ``decode_step`` / ``finalize``
/ ``slots_free`` / ``note_placement``), so pure scheduling-policy tests run
against a stub without touching JAX; the router likewise only needs
``route_batch`` / ``resolve_tier`` / ``required_samples``.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_OBS
from repro.serving.backend import bucket_key as _default_bucket_key

_MISSING = object()    # getattr sentinel: absent attr vs attr that is None


@dataclass
class ServeRequest:
    id: int
    prompt: np.ndarray
    tier: Any                          # resolved SLATier
    n_samples: int
    max_new_tokens: int
    temperature: float
    rng: Optional[Any] = None          # jax PRNG key (single-request parity)
    extras: Optional[Dict[str, np.ndarray]] = None   # per-request rows
    arrival_s: float = 0.0
    seq: int = 0                       # admission order (FIFO key)

    @property
    def tier_name(self) -> str:
        return self.tier.name


@dataclass
class AdmissionResult:
    admitted: bool
    request_id: Optional[int] = None
    reason: str = ""                   # human-readable rejection detail
    reason_code: str = "ok"            # stable label: ok | unknown_tier |
    #                                    queue_full | kv_budget (metrics key)
    raised_samples: Optional[int] = None   # coverage floor raised the budget


@dataclass(frozen=True)
class SchedulerConfig:
    max_batch_requests: int = 8        # requests per formed batch
    max_inflight_batches: int = 2      # prefill/decode interleave width
    max_queue_depth: Optional[int] = 256   # per-tier admission bound
    max_new_tokens: int = 32           # defaults mirror ServingEngine
    temperature: float = 0.8
    seed: int = 0                      # batch rng stream (multi-request)
    respect_caps: bool = True          # shrink batches to keep caps feasible


@dataclass(eq=False)
class BatchRecord:
    """One formed batch — the scheduler's telemetry unit (`TraceStore`
    kind ``"serve"`` via `ingest_serve`)."""
    batch_id: int
    t_s: float                         # simulated service start
    bucket: int                        # prompt length
    n_requests: int
    n_sequences: int
    tier_mix: Dict[str, int]
    queue_delay_s: float               # max member wait before service
    point_index: int
    energy_j: float                    # batch energy at the routed point
    latency_s: float                   # batch service makespan
    meets_caps: bool
    reroute: bool                      # first batch after a re-anneal
    kv_blocks_in_use: Optional[int] = None   # paged backend occupancy
    prefill_bytes_saved: float = 0.0   # KV bytes prefix sharing avoided
    # resident prefix pool (cross-batch block reuse): trie-cached blocks
    # this batch reused / idle blocks it evicted to fit its tails.
    # stats() accumulates these (and prefill_bytes_saved) across batches.
    pool_hit_blocks: int = 0
    pool_evictions: int = 0
    quant: str = "bf16"                # weight serving format (repro.quant)
    kv_format: str = "bf16"            # KV-cache element format
    weight_bytes: Optional[int] = None       # resident (packed) weight bytes
    kv_bytes_in_use: Optional[int] = None    # occupied KV bytes at service
    # speculative decode: the routed plan at formation, measured counts
    # filled in at retirement (the "spec" trace record the accept-rate
    # fitter reads carries the measured pair)
    spec_policy: str = "off"           # draft policy name ("off" = none)
    spec_n: int = 0                    # draft depth this batch ran at
    spec_accept_rate: Optional[float] = None   # planned -> measured
    spec_proposed: int = 0             # draft tokens offered to verify
    spec_accepted: int = 0             # draft tokens verify accepted
    # per-member accounting on the simulated clock: queue_delay_s above is
    # the max over members; p95 queue delay needs every member's own wait
    request_entries: List[Dict[str, Any]] = field(default_factory=list)


@dataclass(eq=False)
class CompletedRequest:
    request: ServeRequest
    result: Any                        # GenerationResult
    batch_id: int
    queue_delay_s: float
    latency_s: float                   # simulated completion - arrival
    decision: Any                      # BatchRoutingDecision


class RequestQueue:
    """Tier-aware admission + per-bucket FIFO.

    ``router`` supplies the tier registry (`resolve_tier`) and the coverage
    floor (`required_samples`); pass None for a policy-free queue (any tier
    object accepted verbatim).
    """

    def __init__(self, router=None, max_queue_depth: Optional[int] = 256,
                 bucket_key=None, obs=None):
        self.router = router
        self.max_queue_depth = max_queue_depth
        self.bucket_key = bucket_key or _default_bucket_key
        self.obs = obs if obs is not None else NULL_OBS
        self._buckets: Dict[Tuple, Deque[ServeRequest]] = {}
        self._depth: Dict[str, int] = {}
        self._seq = 0
        self._next_id = 0
        # bounded: rejections are diagnostics, not an audit log
        self.rejections: Deque[AdmissionResult] = deque(maxlen=256)
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "admissions": reg.counter(
                    "serving_admission_total",
                    "Admission outcomes by rejection reason code",
                    labelnames=("outcome", "reason")),
                "depth": reg.gauge(
                    "serving_queue_depth",
                    "Admitted requests waiting, per tier",
                    labelnames=("tier",)),
            }

    def _reject(self, reason: str, code: str,
                arrival_s: float, tier_name: Optional[str]) -> AdmissionResult:
        res = AdmissionResult(False, reason=reason, reason_code=code)
        self.rejections.append(res)
        if self._m is not None:
            self._m["admissions"].inc(outcome="rejected", reason=code)
        if self.obs.tracer.enabled:
            self.obs.tracer.emit("admit", arrival_s, admitted=False,
                                 reason=code, tier=tier_name)
        return res

    def _note_depth(self, tier_name: str) -> None:
        if self._m is not None:
            self._m["depth"].set(self._depth.get(tier_name, 0),
                                 tier=tier_name)

    # ----------------------------------------------------------- admission
    def submit(self, prompt: np.ndarray, tier, n_samples: int = 1,
               max_new_tokens: int = 32, temperature: float = 0.8,
               rng=None, extras: Optional[Dict] = None,
               arrival_s: float = 0.0,
               budget: Optional[int] = None,
               cost=None) -> AdmissionResult:
        """``budget``/``cost`` bound admission in the backend's KV currency:
        ``cost(plen, max_new, n_samples)`` (default: ``n_samples``, the
        dense slot count) is priced *after* any coverage-floor raise and
        rejected at the door when it can never fit ``budget``."""
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1 (got {n_samples})")
        if self.router is not None and isinstance(tier, str):
            try:
                tier = self.router.resolve_tier(tier)
            except KeyError:
                return self._reject(f"unknown tier {tier!r}", "unknown_tier",
                                    arrival_s, str(tier))
        elif isinstance(tier, str):
            raise ValueError("string tier names need a router to resolve")
        name = tier.name
        if self.max_queue_depth is not None and \
                self._depth.get(name, 0) >= self.max_queue_depth:
            return self._reject(
                f"tier {name!r} queue full ({self.max_queue_depth})",
                "queue_full", arrival_s, name)
        raised = None
        if self.router is not None:
            floor = self.router.required_samples(tier)
            if floor is not None and floor > n_samples:
                n_samples, raised = floor, floor
        if budget is not None:
            c = (cost(len(prompt), max_new_tokens, n_samples)
                 if cost is not None else n_samples)
            if c > budget:
                # a request that can never fit the backend's KV budget is
                # rejected at the door instead of wedging the batch former
                return self._reject(
                    f"admission cost {c} (n_samples={n_samples}) exceeds "
                    f"the KV budget ({budget})", "kv_budget", arrival_s,
                    name)
        req = ServeRequest(self._next_id, prompt, tier, n_samples,
                           max_new_tokens, temperature, rng=rng,
                           extras=extras, arrival_s=arrival_s,
                           seq=self._seq)
        self._next_id += 1
        self._seq += 1
        self._depth[name] = self._depth.get(name, 0) + 1
        key = self.bucket_key(prompt, max_new_tokens, temperature)
        self._buckets.setdefault(key, deque()).append(req)
        if self._m is not None:
            self._m["admissions"].inc(outcome="admitted", reason="ok")
            self._note_depth(name)
        if self.obs.tracer.enabled:
            # the request's root span (a point on the sim clock); queue /
            # release spans auto-parent under it via request_id
            self.obs.tracer.emit("admit", arrival_s, request_id=req.id,
                                 admitted=True, tier=name,
                                 n_samples=n_samples)
        return AdmissionResult(True, req.id, raised_samples=raised)

    # ------------------------------------------------------------- queries
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def __len__(self) -> int:
        return self.pending

    def depth(self, tier_name: str) -> int:
        return self._depth.get(tier_name, 0)

    def _oldest_bucket(self) -> Optional[Tuple]:
        live = {k: q for k, q in self._buckets.items() if q}
        if not live:
            return None
        return min(live, key=lambda k: live[k][0].seq)

    # ----------------------------------------------------------- batching
    def pop_batch(self, max_requests: int,
                  budget: Optional[int] = None,
                  cost=None) -> List[ServeRequest]:
        """Pop the next batch: oldest bucket first, FIFO within it (which is
        FIFO within every tier), bounded by request count and the backend's
        free KV budget — ``cost(req)`` prices each member (default: its
        sample count, the dense slot cost; a paged backend prices blocks at
        shared-prefix cost). Never mixes buckets."""
        key = self._oldest_bucket()
        if key is None:
            return []
        q = self._buckets[key]
        out: List[ServeRequest] = []
        used = 0
        while q and len(out) < max_requests:
            head = q[0]
            c = cost(head) if cost is not None else head.n_samples
            if budget is not None and used + c > budget:
                break      # head waits for budget to free (retiring batches)
            out.append(q.popleft())
            used += c
            self._depth[head.tier_name] -= 1
            self._note_depth(head.tier_name)
        return out

    def push_front(self, requests: Sequence[ServeRequest]) -> None:
        """Return popped requests to the head of their bucket, order
        preserved (cap-aware batch shrinking)."""
        for req in reversed(list(requests)):
            key = self.bucket_key(req.prompt, req.max_new_tokens,
                                  req.temperature)
            self._buckets.setdefault(key, deque()).appendleft(req)
            self._depth[req.tier_name] = self._depth.get(req.tier_name, 0) + 1
            self._note_depth(req.tier_name)


@dataclass(eq=False)
class _InflightEntry:
    handle: Any
    requests: List[ServeRequest]
    decision: Any
    record: BatchRecord
    start_t: float
    done_t: float


class ContinuousBatchingScheduler:
    """Mixed-tier continuous batching over an execution backend.

    One ``step()`` forms new batches while capacity allows (admission ->
    route_batch -> backend prefill) and advances every in-flight batch by
    one decode token; finished batches retire into ``completed`` keyed by
    request id. ``run_until_idle`` drains everything queued.
    """

    def __init__(self, backend, router,
                 config: SchedulerConfig = SchedulerConfig(),
                 queue: Optional[RequestQueue] = None, trace=None, obs=None,
                 spec_planner=None):
        self.backend = backend
        self.router = router
        self.config = config
        # optional repro.spec.SpecPlanner: batch formation then sweeps draft
        # depths through the router's spec-priced workload and notes the
        # winning depth on the backend (note_spec) before prefill; a backend
        # without speculative support simply never receives a note
        self.spec_planner = spec_planner
        # one obs bundle serves the whole pipeline: the scheduler emits
        # sim-clock lifecycle spans + batch metrics, its queue the admission
        # side, and the backend wall-clock prefill/decode spans (spans meet
        # through tracer.batch_context — see repro.obs.tracer)
        self.obs = obs if obs is not None else NULL_OBS
        self.queue = queue if queue is not None else \
            RequestQueue(router, config.max_queue_depth, obs=self.obs)
        # optional repro.qeil2.telemetry.TraceStore: one "serve" record per
        # formed batch (tier mix, queue delay, operating point, SignalSet
        # snapshots) — serving's side of the calibration measurement loop.
        self.trace = trace
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "occupancy": reg.histogram(
                    "serving_batch_occupancy",
                    "Requests per formed batch",
                    buckets=(1, 2, 4, 8, 16, 32, 64)),
                "queue_delay": reg.histogram(
                    "serving_queue_delay_s",
                    "Per-request simulated wait before batch service",
                    labelnames=("tier",)),
                "batch_latency": reg.histogram(
                    "serving_batch_latency_s",
                    "Routed batch service makespan (simulated)"),
                "energy": reg.counter(
                    "serving_energy_j_total",
                    "Batch energy attributed per member tier",
                    labelnames=("tier",)),
                "sequences": reg.counter(
                    "serving_sequences_total",
                    "Sequences entering service per tier",
                    labelnames=("tier",)),
                "ipw": reg.gauge(
                    "serving_ipw_seq_per_j",
                    "Cumulative inferences-per-watt-second (sequences/J) "
                    "per tier",
                    labelnames=("tier",)),
                "prefill_saved": reg.counter(
                    "serving_prefill_bytes_saved_total",
                    "KV bytes prefix sharing did not re-prefill"),
                "completed": reg.counter(
                    "serving_requests_completed_total",
                    "Requests retired per tier", labelnames=("tier",)),
                "early_stop": reg.counter(
                    "serving_early_stop_released_total",
                    "KV budget units (blocks/slots) released by CSVET "
                    "early stops"),
                "reanneal": reg.counter(
                    "serving_reanneal_boundaries_total",
                    "Drift re-anneal notifications from the control loop"),
                "inflight": reg.gauge(
                    "serving_inflight_batches",
                    "Batches mid-decode right now"),
            }
        # per-tier running totals behind the IPW attribution gauge
        self._tier_energy: Dict[str, float] = {}
        self._tier_seqs: Dict[str, int] = {}
        self.clock = 0.0               # simulated now
        self.pipeline_free_t = 0.0     # simulated pipeline horizon
        self.inflight: List[_InflightEntry] = []
        # completed results are the caller's to drain: pop entries after
        # reading them (the RoutedServingEngine shim does) — a long-lived
        # server must not retain every GenerationResult forever
        self.completed: Dict[int, CompletedRequest] = {}
        self.records: Deque[BatchRecord] = deque(maxlen=1024)
        self.reroute_boundaries = 0    # ControlLoop re-anneal notifications
        self._reroute_pending = False
        self._batch_id = 0
        self._base_rng = None          # lazily: jax import only when needed

    # ----------------------------------------------------------- admission
    def _capacity_free(self) -> Optional[int]:
        """Backend KV budget remaining (blocks or slots); falls back to the
        legacy ``slots_free`` for duck-typed stub backends."""
        cap = getattr(self.backend, "capacity_free", _MISSING)
        if cap is _MISSING:
            cap = self.backend.slots_free
        return cap

    def _capacity_total(self) -> Optional[int]:
        cap = getattr(self.backend, "capacity_total", _MISSING)
        if cap is _MISSING:
            cap = getattr(self.backend, "max_slots", None)
        return cap

    def _request_cost(self, req: ServeRequest) -> int:
        # marginal (post-dedup) pricing: a backend with a resident prefix
        # pool charges only the tail blocks a request would actually
        # allocate — its trie-cached prefix is free — so cache-hot requests
        # admit cheaply and the block budget reflects real memory
        mrc = getattr(self.backend, "marginal_request_cost", None)
        if mrc is not None:
            return mrc(req.prompt, req.max_new_tokens, req.n_samples)
        rc = getattr(self.backend, "request_cost", None)
        if rc is None:
            return req.n_samples
        return rc(len(req.prompt), req.max_new_tokens, req.n_samples)

    def _kv_bytes_in_use(self) -> Optional[int]:
        """Occupied KV bytes right now, priced at the backend's actual cache
        element format (int8 KV halves this per block)."""
        blocks = getattr(self.backend, "blocks_in_use", None)
        alloc = getattr(self.backend, "allocator", None)
        ktb = getattr(self.backend, "kv_token_bytes", None)
        if blocks is None or alloc is None or ktb is None:
            return None
        return int(blocks * alloc.block_size * ktb)

    def submit(self, prompt: np.ndarray, tier, n_samples: int = 1,
               max_new_tokens: Optional[int] = None,
               temperature: Optional[float] = None, rng=None,
               extras: Optional[Dict] = None,
               arrival_s: Optional[float] = None) -> AdmissionResult:
        return self.queue.submit(
            prompt, tier, n_samples=n_samples,
            max_new_tokens=(max_new_tokens if max_new_tokens is not None
                            else self.config.max_new_tokens),
            temperature=(temperature if temperature is not None
                         else self.config.temperature),
            rng=rng, extras=extras,
            arrival_s=self.clock if arrival_s is None else arrival_s,
            budget=self._capacity_total(),
            cost=getattr(self.backend, "request_cost", None))

    # ------------------------------------------------------------- control
    def on_reorchestrate(self, healthy: Optional[Sequence[str]] = None
                         ) -> None:
        """ControlLoop hook: a drift-triggered re-anneal landed. The
        post-drift healthy set is pushed into the router (idempotent when
        the loop already synced a shared router), and the next batch
        *formation* re-pulls the refreshed frontier; the boundary is marked
        so telemetry shows where placement changed."""
        if healthy is not None and hasattr(self.router, "set_healthy"):
            self.router.set_healthy(healthy)
        self.reroute_boundaries += 1
        self._reroute_pending = True
        if self._m is not None:
            self._m["reanneal"].inc()

    def advance_to(self, t_s: float) -> None:
        """Move the simulated clock forward (idle time between arrivals)."""
        self.clock = max(self.clock, t_s)

    # ------------------------------------------------------------ batching
    def _batch_rng(self, requests: List[ServeRequest]):
        import jax
        carried = [r.rng for r in requests if r.rng is not None]
        if len(requests) == 1 and carried:
            # parity path: a single-request batch follows the exact split
            # sequence of the pre-refactor generate (call key -> group key)
            base = carried[0]
        elif carried:
            # caller-seeded stream: vary with the caller's key (two runs
            # differing only in rng must produce different samples), folded
            # with the batch index to decorrelate batches of one call
            base = jax.random.fold_in(carried[0], self._batch_id)
        else:
            if self._base_rng is None:
                self._base_rng = jax.random.key(self.config.seed)
            base = jax.random.fold_in(self._base_rng, self._batch_id)
        return jax.random.split(base)[1]

    def _form_batch(self) -> Optional[_InflightEntry]:
        free = self._capacity_free()
        if free is not None and free <= 0:
            return None
        reqs = self.queue.pop_batch(self.config.max_batch_requests, free,
                                    self._request_cost)
        if not reqs:
            return None
        # extras compatibility: one batch stacks one set of per-request
        # extras rows, so a request with different (or no) extras keys
        # splits the batch there (it heads the next one — FIFO preserved)
        keys0 = frozenset(reqs[0].extras or ())
        cut = next((i for i, r in enumerate(reqs)
                    if frozenset(r.extras or ()) != keys0), None)
        if cut is not None:
            self.queue.push_front(reqs[cut:])
            reqs = reqs[:cut]
        # cap-aware sizing: merged caps tighten to the strictest member, and
        # feasibility depends on batch size (re-costed makespan grows with
        # it) — shed the newest half back to the queue until the routed
        # point meets caps or the batch is a single request. Each candidate
        # is routed/costed at what would actually execute: the members'
        # (possibly admission-raised) mean sampling budget and the bucket's
        # prompt length / decode horizon, not the router's canonical
        # workload — SLA caps must hold for the real batch.
        while True:
            route_kwargs = dict(
                samples=math.ceil(sum(r.n_samples for r in reqs)
                                  / len(reqs)),
                prompt_tokens=len(reqs[0].prompt),
                decode_tokens=reqs[0].max_new_tokens)
            if self.spec_planner is not None:
                decision = self.spec_planner.route_batch(
                    self.router, [r.tier for r in reqs], **route_kwargs)
            else:
                decision = self.router.route_batch(
                    [r.tier for r in reqs], **route_kwargs)
            if decision.meets_caps or len(reqs) == 1 or \
                    not self.config.respect_caps:
                break
            keep = max(1, len(reqs) // 2)
            self.queue.push_front(reqs[keep:])
            reqs = reqs[:keep]
        # the routed draft depth applies to THIS batch only: the backend
        # consumes the note at its next start_batch
        spec_plan = getattr(decision, "spec", None)
        if spec_plan is not None and hasattr(self.backend, "note_spec"):
            self.backend.note_spec(spec_plan.n)

        start = max(self.clock, self.pipeline_free_t)
        done_t = start + decision.latency_s
        self.pipeline_free_t = done_t
        extras = None
        if reqs[0].extras:
            extras = {k: np.stack([r.extras[k] for r in reqs])
                      for k in reqs[0].extras}
        tracer = self.obs.tracer
        tracer.batch_context = self._batch_id
        try:
            handle = self.backend.start_batch(
                [r.prompt for r in reqs], [r.n_samples for r in reqs],
                reqs[0].max_new_tokens, reqs[0].temperature,
                self._batch_rng(reqs), extras)
        finally:
            tracer.batch_context = None
        self.backend.note_placement(decision.assignment)

        tier_mix: Dict[str, int] = {}
        for r in reqs:
            tier_mix[r.tier_name] = tier_mix.get(r.tier_name, 0) + 1
        # the batch's ACTUAL speculation state comes off the handle (the
        # backend may run at its default depth with no planner attached)
        hspec = getattr(handle, "spec", None)
        record = BatchRecord(
            batch_id=self._batch_id, t_s=start,
            bucket=len(reqs[0].prompt), n_requests=len(reqs),
            n_sequences=sum(r.n_samples for r in reqs), tier_mix=tier_mix,
            queue_delay_s=max(start - r.arrival_s for r in reqs),
            point_index=decision.point_index,
            energy_j=decision.energy_j, latency_s=decision.latency_s,
            meets_caps=decision.meets_caps, reroute=self._reroute_pending,
            kv_blocks_in_use=getattr(self.backend, "blocks_in_use", None),
            prefill_bytes_saved=float(getattr(handle, "prefill_bytes_saved",
                                              0.0)),
            pool_hit_blocks=int(getattr(handle, "pool_hit_blocks", 0)),
            pool_evictions=int(getattr(handle, "pool_evictions", 0)),
            quant=getattr(self.backend, "quant_format", "bf16"),
            kv_format=getattr(self.backend, "kv_format", "bf16"),
            weight_bytes=getattr(self.backend, "weight_bytes", None),
            kv_bytes_in_use=self._kv_bytes_in_use(),
            spec_policy=hspec.policy.name if hspec is not None else "off",
            spec_n=hspec.n if hspec is not None else 0,
            spec_accept_rate=(spec_plan.accept_rate
                              if spec_plan is not None and spec_plan.enabled
                              else None),
            request_entries=[{"id": r.id, "tier": r.tier_name,
                              "n_samples": r.n_samples,
                              "queue_delay_s": start - r.arrival_s}
                             for r in reqs])
        self._reroute_pending = False
        self._batch_id += 1
        self.records.append(record)
        if self.trace is not None:
            self.trace.ingest_serve(record,
                                    signals=plan_signals(decision))
        if tracer.enabled:
            tracer.emit("schedule", start, batch_id=record.batch_id,
                        point_index=record.point_index,
                        energy_j=record.energy_j,
                        latency_s=record.latency_s,
                        meets_caps=record.meets_caps,
                        n_requests=record.n_requests,
                        tier_mix=dict(tier_mix))
            for r in reqs:
                # per-member wait on the sim clock; batch_id joins the
                # request to its batch-level schedule/prefill/decode spans
                tracer.emit("queue", r.arrival_s, start, request_id=r.id,
                            batch_id=record.batch_id, tier=r.tier_name)
        if self._m is not None:
            self._observe_batch(record, decision, reqs)
        return _InflightEntry(handle, reqs, decision, record, start, done_t)

    def _observe_batch(self, record: BatchRecord, decision,
                       reqs: List[ServeRequest]) -> None:
        m = self._m
        m["occupancy"].observe(record.n_requests)
        m["batch_latency"].observe(record.latency_s)
        m["prefill_saved"].inc(record.prefill_bytes_saved)
        for r in reqs:
            m["queue_delay"].observe(record.t_s - r.arrival_s,
                                     tier=r.tier_name)
            m["sequences"].inc(r.n_samples, tier=r.tier_name)
            self._tier_seqs[r.tier_name] = \
                self._tier_seqs.get(r.tier_name, 0) + r.n_samples
        # per-tier energy attribution when the router prices it (v2-costed
        # batch decisions); stub routers without it attribute nothing
        per_tier = getattr(decision, "per_tier_energy_j", None) or {}
        for tier, e in per_tier.items():
            m["energy"].inc(e, tier=tier)
            self._tier_energy[tier] = self._tier_energy.get(tier, 0.0) + e
        for tier in {r.tier_name for r in reqs}:
            e = self._tier_energy.get(tier, 0.0)
            if e > 0.0:
                m["ipw"].set(self._tier_seqs.get(tier, 0) / e, tier=tier)

    def early_stop(self, request_id: int,
                   sample_indices: Optional[Sequence[int]] = None) -> int:
        """CSVET early-stop hook: a verified pass makes a request's
        remaining samples moot (pass@k is ``any(pass)``), so release their
        KV budget *now* instead of at batch retirement. ``sample_indices``
        selects which of the request's samples to release (default: all).
        Returns the blocks/slots actually returned to the budget (0 when
        the request is not in flight or the backend has no early release)."""
        rel = getattr(self.backend, "release_sequences", None)
        if rel is None:
            return 0
        for entry in self.inflight:
            off = 0
            for r in entry.requests:
                if r.id == request_id:
                    idxs = (range(r.n_samples) if sample_indices is None
                            else list(sample_indices))
                    bad = [i for i in idxs if not 0 <= i < r.n_samples]
                    if bad:
                        # an out-of-range index would map into a *different*
                        # request's rows and release its KV budget under it
                        raise ValueError(
                            f"sample indices {bad} out of range for request "
                            f"{request_id} with {r.n_samples} samples")
                    freed = rel(entry.handle, [off + i for i in idxs])
                    if self.obs.tracer.enabled:
                        self.obs.tracer.emit(
                            "early_stop", self.clock, request_id=request_id,
                            batch_id=entry.record.batch_id, freed=freed,
                            n_released=len(list(idxs)))
                    if self._m is not None and freed:
                        self._m["early_stop"].inc(freed)
                    return freed
                off += r.n_samples
        return 0

    def _retire(self, entry: _InflightEntry) -> None:
        results = self.backend.finalize(entry.handle)
        self.clock = max(self.clock, entry.done_t)
        tracer = self.obs.tracer
        sp = getattr(entry.handle, "spec", None)
        if sp is not None:
            # measured accept counts land on the record, and a "spec" trace
            # record closes the loop: CalibrationFitter turns these into
            # per-(model, tier, policy) accept rates for SpecPlanner.refresh
            entry.record.spec_proposed = int(sp.proposed)
            entry.record.spec_accepted = int(sp.accepted)
            entry.record.spec_accept_rate = float(sp.accept_rate)
            if self.trace is not None and sp.proposed:
                cfg = getattr(getattr(self.backend, "model", None),
                              "cfg", None)
                merged = getattr(entry.decision, "tier", None)
                rec = {"kind": "spec", "t_s": float(entry.done_t),
                       "policy": str(sp.policy.name), "n": int(sp.n),
                       "proposed": int(sp.proposed),
                       "accepted": int(sp.accepted)}
                if cfg is not None:
                    rec["model"] = str(cfg.name)
                if merged is not None:
                    rec["tier"] = str(merged.name)
                self.trace.ingest(rec)
        for req, res in zip(entry.requests, results):
            self.completed[req.id] = CompletedRequest(
                request=req, result=res, batch_id=entry.record.batch_id,
                queue_delay_s=entry.start_t - req.arrival_s,
                latency_s=entry.done_t - req.arrival_s,
                decision=entry.decision)
            if tracer.enabled:
                tracer.emit("release", entry.done_t, request_id=req.id,
                            batch_id=entry.record.batch_id,
                            tier=req.tier_name,
                            queue_delay_s=entry.start_t - req.arrival_s,
                            latency_s=entry.done_t - req.arrival_s)
            if self._m is not None:
                self._m["completed"].inc(tier=req.tier_name)

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler iteration: form batches while capacity allows, then
        one decode token per in-flight batch; retire finished batches.
        Returns False when there was nothing to do."""
        progressed = False
        while len(self.inflight) < self.config.max_inflight_batches:
            entry = self._form_batch()
            if entry is None:
                break
            self.inflight.append(entry)
            progressed = True
        tracer = self.obs.tracer
        for entry in list(self.inflight):
            if not entry.handle.done:
                tracer.batch_context = entry.record.batch_id
                try:
                    self.backend.decode_step(entry.handle)
                finally:
                    tracer.batch_context = None
                progressed = True
            if entry.handle.done:
                self.inflight.remove(entry)
                self._retire(entry)
                progressed = True
        if self._m is not None:
            self._m["inflight"].set(len(self.inflight))
        return progressed

    def run_until_idle(self, max_steps: int = 10 ** 6
                       ) -> Dict[int, CompletedRequest]:
        """Drain the queue and every in-flight batch; returns ``completed``
        (request id -> CompletedRequest)."""
        steps = 0
        while (self.queue.pending or self.inflight) and steps < max_steps:
            if not self.step():
                break                      # starved (e.g. zero slots free)
            steps += 1
        return self.completed

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        done = list(self.completed.values())
        per_tier: Dict[str, List[float]] = {}
        for c in done:
            per_tier.setdefault(c.request.tier_name, []).append(c.latency_s)
        return {
            "completed": len(done),
            "batches": len(self.records),
            "mean_batch_requests": (float(np.mean([r.n_requests
                                                   for r in self.records]))
                                    if self.records else 0.0),
            "caps_met_fraction": (float(np.mean([r.meets_caps
                                                 for r in self.records]))
                                  if self.records else 1.0),
            "energy_j": sum(r.energy_j for r in self.records),
            "sequences": sum(r.n_sequences for r in self.records),
            "makespan_s": self.pipeline_free_t,
            "latency_p95_s": {t: float(np.percentile(v, 95))
                              for t, v in sorted(per_tier.items())},
            "reroute_boundaries": self.reroute_boundaries,
            "spec_proposed": sum(r.spec_proposed for r in self.records),
            "spec_accepted": sum(r.spec_accepted for r in self.records),
            # steady-state prefix-pool accounting, accumulated across
            # batches (per-batch values ride each BatchRecord)
            "pool_hit_blocks": sum(r.pool_hit_blocks for r in self.records),
            "pool_evictions": sum(r.pool_evictions for r in self.records),
            "prefill_bytes_saved": sum(r.prefill_bytes_saved
                                       for r in self.records),
        }


def plan_signals(decision) -> Dict[str, dict]:
    """Per-stage `SignalSet.as_dict()` snapshots of a routed batch — present
    when the orchestrator costs plans with the v2 model (`StageExecutionV2`
    records carry the signal triple). Mirrors the control loop's per-step
    snapshot so serve traces feed the same `CalibrationFitter`."""
    out: Dict[str, dict] = {}
    costs = getattr(decision, "batch_costs", None)
    if costs is None:
        return out
    for e in costs.executions:
        sig = getattr(e, "signals", None)
        if sig is not None:
            out[e.stage.name] = sig.as_dict()
    return out
