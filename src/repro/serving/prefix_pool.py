"""Global prefix-sharing KV pool: a radix-trie index over filled KV blocks.

PR 5's paged cache shares prefix blocks only *within* one batch of k
repeats — every new batch re-prefills system prompts the pool has already
paid for, exactly the prefill memory traffic the paper's roofline
decomposition says dominates edge decode. `PrefixPool` promotes the
`BlockAllocator` budget to a single resident pool that outlives batches:

* The trie is keyed on *token-id block chunks*: each node owns one physical
  KV block holding the keys/values of exactly ``block_size`` tokens, and the
  path from the root spells the token prefix those blocks encode. Causal
  attention makes block content a pure function of its token chain, so any
  request whose prompt extends a cached chain can reuse the chain's blocks
  verbatim and prefill only the tail.
* ``lookup`` resolves a prompt to the longest chain of already-filled
  blocks in one trie walk (unique by construction — children are keyed by
  chunk bytes, so at most one child matches each step).
* Residency holds ONE allocator reference per node (the "trie ref") and
  marks the block protected; live requests hold their own refs on top via
  ``acquire``. A block whose refcount has fallen back to 1 is *cached but
  idle* — reclaimable, never on the free list.
* Eviction (`ensure_free`) peels idle leaves in LRU order. Evicting a block
  with live holder refs is a hard error, as is returning a trie-resident
  block to the free list behind the pool's back (`BlockAllocator.free`
  raises, naming the block and its owning prefix).

Holders always reference whole chains from the root (``acquire`` forks every
block on the hit path; layouts built on top append the freshly filled tail
blocks), so a node with refcount 1 has no held descendants either — every
idle node is eventually reachable by leaf-first peeling.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def chunk_key(tokens: np.ndarray, start: int, block_size: int) -> bytes:
    """Canonical bytes key of one full block chunk of token ids.

    Token dtype is canonicalized (prompts arrive as int32 or int64 depending
    on the producer) so the same ids always hash to the same node; multi-
    codebook prompts of shape (L, K) chunk along the first axis.
    """
    chunk = np.ascontiguousarray(
        np.asarray(tokens)[start:start + block_size], dtype=np.int64)
    return chunk.tobytes()


class _TrieNode:
    __slots__ = ("chunk", "block", "children", "parent", "last_touch",
                 "depth", "preview")

    def __init__(self, chunk: bytes, block: int, parent: "_TrieNode",
                 depth: int, preview: tuple):
        self.chunk = chunk
        self.block = block
        self.children: Dict[bytes, _TrieNode] = {}
        self.parent = parent
        self.last_touch = 0
        self.depth = depth                 # 1-based chain position
        self.preview = preview             # first token ids of the chunk

    def describe(self) -> str:
        return f"depth {self.depth}, chunk tokens {list(self.preview)}..."


class PrefixPool:
    """Radix-trie block reuse across the request stream (see module doc).

    The pool layers cached-block state over a `BlockAllocator`; it never
    allocates blocks itself — callers fill blocks through their batch
    layouts and `insert` the completed full-prefix chains afterwards, so the
    trie only ever indexes blocks whose KV content is final.
    """

    EVICT_POLICIES = ("lru", "off")

    def __init__(self, allocator, evict: str = "lru"):
        if evict not in self.EVICT_POLICIES:
            raise ValueError(f"unknown eviction policy {evict!r} "
                             f"(supported: {self.EVICT_POLICIES})")
        self.allocator = allocator
        self.evict_policy = evict
        self._root = _TrieNode(b"", -1, None, 0, ())  # type: ignore[arg-type]
        self._by_block: Dict[int, _TrieNode] = {}
        self._clock = 0                    # LRU recency counter
        self.evictions = 0                 # lifetime evicted blocks

    # ------------------------------------------------------------- queries
    @property
    def blocks_resident(self) -> int:
        """Blocks currently indexed by the trie (held + idle)."""
        return len(self._by_block)

    @property
    def evictable_blocks(self) -> int:
        """Idle resident blocks (refcount == trie ref only) reclaimable by
        eviction — counted into the scheduler's admission headroom. All of
        them are reachable by leaf-first peeling (module doc), so the count
        is exact, not a bound."""
        if self.evict_policy == "off":
            return 0
        return sum(1 for n in self._by_block.values()
                   if self.allocator.refcount(n.block) == 1)

    def owner_of(self, bid: int) -> Optional[str]:
        node = self._by_block.get(bid)
        return node.describe() if node is not None else None

    def lookup(self, tokens: np.ndarray, max_blocks: int,
               touch: bool = True) -> List[int]:
        """Longest cached prefix of ``tokens``, as its chain of block ids
        (possibly empty), capped at ``max_blocks`` full blocks. One trie
        walk; ``touch=False`` for cost queries that must not perturb LRU."""
        bs = self.allocator.block_size
        node = self._root
        chain: List[int] = []
        for i in range(max(0, int(max_blocks))):
            child = node.children.get(chunk_key(tokens, i * bs, bs))
            if child is None:
                break
            chain.append(child.block)
            node = child
            if touch:
                self._clock += 1
                node.last_touch = self._clock
        return chain

    # ------------------------------------------------------------ mutation
    def acquire(self, tokens: np.ndarray, max_blocks: int,
                holders: int) -> List[int]:
        """Look up the longest cached prefix and take ``holders`` references
        on every block of the chain (one per sequence that will read through
        it). Pinning happens here, before any eviction the caller runs for
        its tail blocks, so a hit chain can never be evicted out from under
        the batch that just resolved it."""
        chain = self.lookup(tokens, max_blocks, touch=True)
        for bid in chain:
            for _ in range(holders):
                self.allocator.fork(bid)
        return chain

    def insert(self, tokens: np.ndarray, chain: List[int]) -> int:
        """Index a prompt's freshly filled full-prefix chain. ``chain[i]``
        must hold the KV of tokens ``[i*bs, (i+1)*bs)``; chunks already
        resident are kept (first writer wins — a same-prefix sibling in one
        batch filled a duplicate block, which simply stays a plain
        refcounted block). Returns blocks newly indexed; each takes one trie
        ref and protection."""
        bs = self.allocator.block_size
        node = self._root
        created = 0
        for i, bid in enumerate(chain):
            key = chunk_key(tokens, i * bs, bs)
            child = node.children.get(key)
            if child is None:
                preview = tuple(np.asarray(
                    np.frombuffer(key, np.int64)[:4]).tolist())
                child = _TrieNode(key, bid, node, node.depth + 1, preview)
                self.allocator.fork(bid)
                self.allocator.protect(bid, child.describe())
                node.children[key] = child
                self._by_block[bid] = child
                created += 1
            self._clock += 1
            child.last_touch = self._clock
            node = child
        return created

    def evict(self, bid: int) -> None:
        """Drop one resident block: release the trie ref (returning the
        block to the free list) and unlink its node. Hard errors: a block
        with live holder refs, or an interior node whose children would be
        orphaned — eviction is leaf-first by construction."""
        node = self._by_block.get(bid)
        if node is None:
            raise KeyError(f"evict of block {bid} not resident in the pool")
        live = self.allocator.refcount(bid) - 1
        if live > 0:
            raise RuntimeError(
                f"evicting trie-resident block {bid} (owning prefix: "
                f"{node.describe()}) with {live} live holder ref(s) — "
                "eviction requires zero-ref trie nodes")
        if node.children:
            raise RuntimeError(
                f"evicting interior trie block {bid} (owning prefix: "
                f"{node.describe()}) would orphan {len(node.children)} "
                "resident child block(s)")
        del node.parent.children[node.chunk]
        del self._by_block[bid]
        self.allocator.unprotect(bid)
        self.allocator.free(bid)
        self.evictions += 1

    def ensure_free(self, n_blocks: int) -> int:
        """Evict idle leaves (LRU order) until the allocator has
        ``n_blocks`` free, or no candidate remains. Returns blocks evicted;
        a no-op under the ``off`` policy — the caller's budget check then
        fails loudly instead of reclaiming."""
        evicted = 0
        if self.evict_policy == "off":
            return 0
        while self.allocator.blocks_free < n_blocks:
            victim: Optional[_TrieNode] = None
            for node in self._by_block.values():
                if node.children:
                    continue               # interior: peel its leaves first
                if self.allocator.refcount(node.block) != 1:
                    continue               # held by a live sequence
                if victim is None or node.last_touch < victim.last_touch:
                    victim = node
            if victim is None:
                break
            self.evict(victim.block)
            evicted += 1
        return evicted
