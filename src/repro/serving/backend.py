"""Execution backend: the *how* of serving, as a stepwise batch API.

Extracted from the original monolithic ``ServingEngine.generate`` so a
scheduler can interleave work across batches instead of blocking on one
call. The backend owns the jitted prefill/decode functions, the KV budget
(sequence slots, or paged blocks — see below), and static-shape bucketing;
policy (admission, batch formation, routing) lives in
`repro.serving.scheduler`.

The step API is deliberately small:

* ``start_batch`` — prefill a group of equal-length prompts (each tiled by
  its per-request sample count) and sample the first token; returns an
  `InFlightBatch` holding the KV cache and the rng stream.
* ``decode_step`` — advance an in-flight batch by one autoregressive token.
* ``finalize`` — stack the sampled tokens into per-request
  `GenerationResult`s and release the batch's KV budget.

Running ``start_batch`` + ``decode_step`` until done + ``finalize`` is
bit-identical to the pre-refactor monolith (same rng split sequence, same
jitted functions) — `ServingEngine.generate` is now exactly that loop, and
the parity test in ``tests/test_serving_scheduler.py`` pins it.

Batches are formed within a *bucket*: prompts of one length (the static
shape the jit specializes on) with one max-new-tokens horizon and one
temperature. ``bucket_key`` is the canonical key; the scheduler never mixes
buckets inside a batch.

Paged KV cache (``kv_blocks=`` in the constructor)
--------------------------------------------------
The dense cache allocates ``B x (plen + max_new)`` KV rows per batch and
holds them until the whole batch retires. For the paper's EAC/ARDE cascade
— k repeated samples per prompt, CSVET stopping early — that re-prefills
every repeat and re-buys the prefix k times, exactly the prefill memory
traffic the roofline model says dominates edge decode. Paged mode replaces
it:

* `BlockAllocator` — fixed-size KV blocks with refcounts, a free list and
  copy-on-write; ``kv_blocks`` is the *real* memory budget
  (``kv_blocks * block_size * kv_bytes_per_token``), and ``blocks_free`` is
  the admission currency the scheduler checks.
* Prefill runs once per *unique prompt*; the k repeats share the full
  prefix blocks by reference (`fork`). A partially-filled last prefix block
  is copy-on-write forked at ``start_batch`` — each repeat gets a private
  copy of the block its first divergent token lands in (`cow`), so the
  whole block schedule is known up front and decode steps never touch the
  allocator (jit-friendly static block tables).
* Decode attention reads through the per-sequence block table — the Pallas
  paged kernel gathers physical blocks via scalar-prefetched index maps;
  the jnp reference path gathers + slices so it is *bit-identical* to the
  dense path (pinned by ``tests/test_kv_paging.py``).
* `release_sequences` returns a finished sample's private blocks to the
  free list immediately (CSVET early-stop), instead of at batch retirement.

Paged mode is supported for the architectures
`repro.models.cache.paged_supported` accepts; everything else keeps the
dense layout.

Speculative decode (``spec_policy=`` in the constructor)
--------------------------------------------------------
With a `repro.spec` draft policy attached, ``decode_step`` on a drafting
batch runs draft -> verify -> commit instead of one-token sampling: the
policy proposes n tokens from each sequence's own history, ONE verify
forward scores them against the cache (``decode=True`` forces the
cache-attending branches at S = n + 1), and `repro.spec.verify_tokens`
keeps the longest accepted prefix plus a correction/bonus token —
distribution-preserving under sampling, bit-identical tokens under greedy.

Rollback is free by construction: every verify scatters its S query tokens
into positions ``[base, base + n]`` *before* attending, positions above a
query's own are masked, and the next verify re-writes the whole span — so
rejected-draft KV entries are dead weight that the following step
overwrites, with zero allocator traffic per step (`release_sequences`
machinery is only exercised by early stop, exactly as without drafting).
To make those tail writes safe for rows that finish while the batch is
still ragged, spec batches allocate a slack horizon of ``spec_n + 1`` extra
token slots per sequence (`request_blocks` prices it into admission).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Deque, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_mod
from repro.models.model import Model
from repro.obs import NULL_OBS
from repro.obs.metrics import RATIO_BUCKETS
from repro.serving.prefix_pool import PrefixPool
from repro.spec.policy import spec_supported
from repro.spec.verify import verify_tokens


@dataclass
class GenerationResult:
    prompt: np.ndarray
    samples: List[np.ndarray]          # n_samples completions (token arrays)
    logprobs: List[float]              # mean per-token logprob per sample
    prefill_tokens: int = 0
    decode_tokens: int = 0


# ============================================================ block allocator

class BlockAllocator:
    """Fixed-size KV block accounting: free list + refcounts + copy-on-write.

    This is the global *admission budget* for paged serving: every in-flight
    batch's physical pool layout is mirrored here block-for-block, double
    frees raise instead of silently corrupting the budget, and a shared
    prefix block only returns to the free list when its *last* holder
    releases it.

    Physical pools are per-batch arrays reclaimed whole at batch retirement
    (`ExecutionBackend.pool_blocks_resident` is the resident footprint), so
    budget freed mid-flight by an early release admits new work whose pool
    is *additional* memory until the donor batch retires — transient
    overcommit bounded by the early-released block count. A single resident
    pool shared across batches closes that gap (ROADMAP: cross-batch
    physical block sharing).
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError("n_blocks and block_size must be positive")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        # blocks indexed by a resident PrefixPool (bid -> owning-prefix
        # description): their cached KV content must never silently return
        # to the free list — the pool's evict path unprotects first
        self._protected: Dict[int, str] = {}

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def alloc(self) -> int:
        """Take one block off the free list (refcount 1)."""
        if not self._free:
            raise RuntimeError(
                f"KV block pool exhausted ({self.n_blocks} blocks; "
                "admission must check blocks_free)")
        bid = self._free.pop()
        self._ref[bid] = 1
        return bid

    def fork(self, bid: int) -> int:
        """Add a reference to a live block (prefix sharing across the
        repeated samples of one prompt)."""
        ref = self._ref.get(bid)
        if ref is None:
            raise KeyError(f"fork of unallocated block {bid}")
        self._ref[bid] = ref + 1
        return bid

    def cow(self, bid: int) -> Tuple[int, bool]:
        """Copy-on-write: the writable version of ``bid`` for one holder.
        Sole holder writes in place (``(bid, False)``); a shared block costs
        a fresh private block and drops one reference (``(new, True)`` — the
        caller must physically copy the contents)."""
        ref = self._ref.get(bid)
        if ref is None:
            raise KeyError(f"cow of unallocated block {bid}")
        if ref == 1:
            return bid, False
        new = self.alloc()              # may raise before any state changes
        self._ref[bid] = ref - 1
        return new, True

    def protect(self, bid: int, owner: str) -> None:
        """Mark a live block as trie-resident (`repro.serving.prefix_pool`):
        its last reference belongs to the pool's index, and `free` refuses
        to return it to the free list — eviction must go through the pool."""
        if bid not in self._ref:
            raise KeyError(f"protect of unallocated block {bid}")
        self._protected[bid] = owner

    def unprotect(self, bid: int) -> None:
        self._protected.pop(bid, None)

    def protected_owner(self, bid: int) -> Optional[str]:
        return self._protected.get(bid)

    def free(self, bid: int) -> bool:
        """Drop one reference; returns True when the block physically went
        back to the free list. Freeing an unallocated block raises — the
        double-free guard the invariant tests pin. Freeing the *last*
        reference of a trie-resident block also raises (with the block id
        and owning prefix named): cached KV returning to the free list
        behind the pool's back would corrupt the prefix index."""
        ref = self._ref.get(bid)
        if ref is None:
            raise RuntimeError(f"double free / free of unallocated block {bid}")
        owner = self._protected.get(bid)
        if owner is not None and ref == 1:
            raise RuntimeError(
                f"free of trie-resident block {bid} (owning prefix: "
                f"{owner}) would return an indexed block to the free list; "
                "evict it through PrefixPool.evict instead")
        if ref > 1:
            self._ref[bid] = ref - 1
            return False
        del self._ref[bid]
        self._free.append(bid)
        return True


@dataclass
class PagedBatchLayout:
    """Physical pool layout of one in-flight batch. Built once at
    ``start_batch`` (the whole block schedule — prefix sharing, CoW fan-out,
    decode blocks — is deterministic given the bucket geometry), static for
    the batch lifetime so decode steps stay pure jitted functions."""
    block_size: int
    n_pool_blocks: int                 # physical pool size (local ids)
    kv_len: int                        # logical slots per sequence
    prefill_table: np.ndarray          # (R, ceil(plen/bs)) local block ids
    decode_table: np.ndarray           # (B, ceil(kv_len/bs)) local block ids
    copy_src: np.ndarray               # CoW pairs: partial prefix block ->
    copy_dst: np.ndarray               #   each repeat's private copy
    seq_gids: List[List[int]]          # allocator ids referenced per sequence
    # prefix-pool path (`hit_chains` given): tables carry GLOBAL allocator
    # ids straight into the backend's resident cache, and each prompt's
    # leading ``hit_counts[i]`` prefill blocks are already filled — only the
    # tail from ``hit_counts[i] * block_size`` needs prefilling
    pooled: bool = False
    hit_counts: List[int] = field(default_factory=list)


def build_paged_layout(allocator: BlockAllocator, plen: int, max_new: int,
                       repeats: Sequence[int],
                       hit_chains: Optional[Sequence[List[int]]] = None
                       ) -> PagedBatchLayout:
    """Allocate one batch's blocks and build its tables.

    Per request: the ``plen // bs`` full prefix blocks are allocated once and
    forked to every repeat; a partially-filled last prefix block is CoW-forked
    per repeat (first divergent token lands there); decode blocks are private.
    The caller must have checked ``request_blocks`` against ``blocks_free`` —
    allocation never fails mid-build after that.

    Blocks cover written positions only: the final sampled token is returned,
    never cached, so the last position is ``plen + max_new - 2`` (prefill end
    for max_new == 1) and sequences never pay for a block that would hold
    only the unwritten ``plen + max_new - 1`` slot.

    Pool-lookup path: ``hit_chains[i]`` is prompt *i*'s longest cached
    full-prefix block chain from the resident `PrefixPool` — already filled,
    already holding one reference per repeat (``PrefixPool.acquire``). Those
    blocks head the prompt's tables instead of fresh allocations, only the
    tail is newly allocated, and every table carries GLOBAL allocator ids
    (the physical cache is the backend's single resident pool, so no local
    remap exists). The partial-tail CoW fork is unchanged — partial blocks
    are never pool-shared — so the whole block schedule stays static and
    decode jit-friendly.
    """
    bs = allocator.block_size
    n_logical = max(-(-(plen + max_new - 1) // bs), 1)
    full_prefix = plen // bs
    has_partial = plen % bs != 0
    pooled = hit_chains is not None
    if not pooled:
        hit_chains = [[] for _ in repeats]

    pool_gids: List[int] = []
    local_of: Dict[int, int] = {}

    def loc(gid: int) -> int:
        if pooled:                     # resident pool: global ids ARE the map
            return gid
        if gid not in local_of:
            local_of[gid] = len(pool_gids)
            pool_gids.append(gid)
        return local_of[gid]

    prefill_rows: List[List[int]] = []
    decode_rows: List[List[int]] = []
    seq_gids: List[List[int]] = []
    copy_src: List[int] = []
    copy_dst: List[int] = []

    for k, hits in zip(repeats, hit_chains):
        if len(hits) > full_prefix:
            raise ValueError(f"hit chain of {len(hits)} blocks exceeds the "
                             f"{full_prefix} full prefix blocks of plen={plen}")
        shared = list(hits) + [allocator.alloc()
                               for _ in range(full_prefix - len(hits))]
        part = allocator.alloc() if has_partial else None
        for _ in range(k - 1):
            for g in shared[len(hits):]:   # hit refs already taken by acquire
                allocator.fork(g)
            if part is not None:
                allocator.fork(part)
        prefill_rows.append([loc(g) for g in shared]
                            + ([loc(part)] if part is not None else []))
        for _ in range(k):
            gids = list(shared)
            row = [loc(g) for g in shared]
            if part is not None:
                wg, copied = allocator.cow(part)
                if copied:
                    copy_src.append(loc(part))
                    copy_dst.append(loc(wg))
                gids.append(wg)
                row.append(loc(wg))
            while len(row) < n_logical:
                g = allocator.alloc()
                gids.append(g)
                row.append(loc(g))
            decode_rows.append(row)
            seq_gids.append(gids)

    return PagedBatchLayout(
        block_size=bs,
        n_pool_blocks=allocator.n_blocks if pooled else len(pool_gids),
        kv_len=plen + max_new,
        prefill_table=np.asarray(prefill_rows, np.int32),
        decode_table=np.asarray(decode_rows, np.int32),
        copy_src=np.asarray(copy_src, np.int32),
        copy_dst=np.asarray(copy_dst, np.int32),
        seq_gids=seq_gids,
        pooled=pooled,
        hit_counts=[len(h) for h in hit_chains])


@dataclass
class SpecState:
    """Speculative decode state of one in-flight batch.

    Sequences progress *raggedly* — each verify step commits between 1 and
    n+1 tokens per row — so per-sequence token/logprob lists replace the
    per-step stacked arrays, ``committed`` tracks each row's emitted count,
    and ``InFlightBatch.step`` is ``committed.min()`` (the batch retires
    when the slowest row reaches the horizon; finished rows keep riding the
    static-shape verify forward, writing only into their slack slots).
    ``proposed``/``accepted`` feed the "spec" telemetry record the
    `CalibrationFitter` learns accept rates from.
    """
    policy: Any                        # DraftPolicy proposing the drafts
    n: int                             # draft depth for this batch
    committed: np.ndarray              # (B,) tokens emitted per sequence
    histories: List[np.ndarray]        # prompt + committed tokens, per seq
    toks: List[List[int]]              # emitted tokens per sequence
    lps: List[List[float]]             # emitted logprobs per sequence
    proposed: int = 0                  # draft tokens offered to verify
    accepted: int = 0                  # draft tokens verify accepted
    steps: int = 0                     # verify forwards run

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0


@dataclass
class PendingPrefill:
    """Chunked-prefill work of a batch that has its blocks but not yet its
    KV: ``jobs`` are (prompt idxs, start, end) token slices, drained one per
    ``decode_step`` call; the finish pass (CoW fan-out, pool indexing,
    first-token sampling) runs when the last job lands. The handle's
    ``step`` stays 0 throughout, so the scheduler never retires it early,
    and a preemption mid-prefill releases instead of parking (no block is
    guaranteed filled yet)."""
    jobs: Deque[Tuple[List[int], int, int]]
    base: np.ndarray                   # (R, L[,K]) stacked unique prompts
    extras: Dict[str, jax.Array]       # per-prompt rows (untiled)
    last_rows: List[Any]               # final-position logits per prompt
    rep: Union[int, np.ndarray]
    mc: bool
    full_prefix: int                   # blocks to trie-index at finish
    n_spec: int = 0                    # draft depth armed for this batch


@dataclass
class InFlightBatch:
    """One prefilled batch mid-decode: the unit the scheduler interleaves."""
    prompts: List[np.ndarray]
    repeats: List[int]                 # samples per prompt (KV budget held)
    plen: int
    max_new: int
    temperature: float
    rng: jax.Array                     # stream state: split once per token
    extras: Dict[str, jax.Array]       # already tiled to sequence count
    cache: Any
    tok: Optional[jax.Array]           # last sampled token (B,) or (B, K);
    #                                    None while chunk-prefilling
    step: int                          # tokens sampled so far (>= 1)
    out_toks: List[np.ndarray] = field(default_factory=list)
    out_lps: List[np.ndarray] = field(default_factory=list)
    # paged state (None in dense mode)
    paged: Optional[PagedBatchLayout] = None
    block_table: Optional[jax.Array] = None    # decode table on device
    prefill_bytes_saved: float = 0.0   # KV bytes prefix sharing did not move
    # prefix-pool accounting (pooled batches only; h.cache is None — the
    # physical cache is the backend's resident pool)
    pool_hit_blocks: int = 0           # trie-cached blocks this batch reused
    pool_evictions: int = 0            # idle blocks evicted to fit the tail
    freed_seqs: Set[int] = field(default_factory=set)   # early-released rows
    spec: Optional[SpecState] = None   # set when this batch drafts (n > 0)
    pending_prefill: Optional[PendingPrefill] = None

    @property
    def n_sequences(self) -> int:
        return sum(self.repeats)

    @property
    def done(self) -> bool:
        return self.step >= self.max_new


def bucket_key(prompt: np.ndarray, max_new: int,
               temperature: float) -> Tuple[int, int, float]:
    """Static-shape bucket: batches may only group requests that share the
    prompt length (the jit's shape key), decode horizon and temperature."""
    return (len(prompt), max_new, float(temperature))


class ExecutionBackend:
    """Owns model execution state: jitted step functions, KV budget,
    placement history.

    Dense mode: ``max_slots`` bounds concurrently resident sequences
    (prompt x samples rows); ``None`` means unbounded (the original engine's
    behaviour). Paged mode (``kv_blocks`` set): a `BlockAllocator` of
    ``kv_blocks`` blocks of ``kv_block_size`` token slots is the budget —
    admission prices a request at shared-prefix cost (`request_blocks`), so
    the k repeats of one prompt pay for their prefix once."""

    def __init__(self, model: Model, params, eos_token: Optional[int] = None,
                 max_slots: Optional[int] = None,
                 kv_blocks: Optional[int] = None, kv_block_size: int = 16,
                 kv_format: str = "bf16", obs=None,
                 spec_policy=None, spec_n: int = 0,
                 kv_pool: bool = False, pool_evict: str = "lru",
                 prefill_chunk: Optional[int] = None):
        self.model = model
        self.params = params
        self.eos_token = eos_token
        self.max_slots = max_slots
        self.slots_in_use = 0
        # speculative decode: spec_n is the MAXIMUM draft depth — it sizes
        # the per-sequence slack allocation, so per-batch depths noted via
        # note_spec may only go down from it
        self.spec_policy = spec_policy
        self.spec_n = int(spec_n)
        self._next_spec_n: Optional[int] = None
        if spec_policy is not None:
            if self.spec_n < 1:
                raise ValueError("spec_policy requires spec_n >= 1 "
                                 "(the maximum draft depth)")
            if not spec_supported(model.cfg):
                raise ValueError(
                    f"speculative decode unsupported for arch "
                    f"{model.cfg.name!r} (see repro.spec.spec_supported)")
        if kv_format not in ("bf16", "int8"):
            raise ValueError(f"unknown kv_format {kv_format!r} "
                             "(supported: bf16, int8)")
        if kv_format == "int8" and kv_blocks is None:
            raise ValueError("kv_format='int8' requires the paged cache "
                             "(set kv_blocks)")
        self.kv_format = kv_format
        # serving format of the loaded weights (repro.quant) + their actual
        # resident bytes — stamped on telemetry records
        from repro.quant.quantize import param_bytes, params_quant_format
        self.quant_format = params_quant_format(params)
        self.weight_bytes = param_bytes(params)
        self.allocator: Optional[BlockAllocator] = None
        self.prefix_pool = None
        self._pool_cache = None            # resident physical cache (pooled)
        if kv_blocks is not None:
            if not cache_mod.paged_supported(model.cfg):
                raise ValueError(
                    f"paged KV cache unsupported for arch "
                    f"{model.cfg.name!r} (see repro.models.cache."
                    "paged_supported); use the dense max_slots budget")
            self.allocator = BlockAllocator(kv_blocks, kv_block_size)
            if kv_pool:
                # global prefix-sharing pool: ONE resident physical cache of
                # kv_blocks blocks outlives every batch, and the radix trie
                # indexes filled full-prefix blocks for cross-batch reuse
                self.prefix_pool = PrefixPool(self.allocator,
                                              evict=pool_evict)
        elif kv_pool:
            raise ValueError("kv_pool requires the paged cache (set "
                             "kv_blocks)")
        # chunked prefill: split every prefill into <= prefill_chunk-token
        # slices, one slice per decode_step call, so a long prompt
        # interleaves with in-flight decode instead of stalling it. Rides
        # the tail-prefill kernel (explicit positions + block tables), so
        # paged mode only — and bit-identical to the one-shot prefill: the
        # kernel masks unwritten positions, so per-position attention sums
        # are over the same terms regardless of chunk boundaries.
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1 "
                                 f"(got {prefill_chunk})")
            if self.allocator is None:
                raise ValueError("prefill_chunk requires the paged cache "
                                 "(set kv_blocks)")
        self.prefill_chunk = prefill_chunk
        # live handles: release() must be called exactly once per started
        # batch — a second release raises instead of silently driving the
        # budget negative (the double-release regression).
        self._live: Dict[int, InFlightBatch] = {}
        # placement hook state (the orchestrator's simulated stage->device
        # plan for whatever is being executed): the scheduler notes the
        # routed operating point per batch; the legacy engine notes its
        # placement_provider's answer per generate call. Bounded history —
        # a long-lived server must not grow linearly with request count.
        self.last_placement = None
        self.placements: Deque = deque(maxlen=256)
        self.set_obs(obs)
        self._prefill_jit = jax.jit(self._prefill)
        self._decode_jit = jax.jit(self._decode_step,
                                   static_argnames=("kv_len", "greedy"))
        self._spec_verify_jit = jax.jit(self._spec_verify,
                                        static_argnames=("kv_len", "greedy"))
        self._tail_prefill_jit = jax.jit(self._tail_prefill,
                                         static_argnames=("kv_len",))
        self._copy_blocks_jit = jax.jit(cache_mod.copy_cache_blocks)
        self._reset_blocks_jit = jax.jit(
            cache_mod.reset_cache_block_positions)

    def set_obs(self, obs) -> None:
        """Attach (or detach, ``None``) a `repro.obs.Observability` bundle.

        Separate from the constructor so the overhead bench can flip
        instrumentation on a backend whose jit caches are already warm —
        metric handles are resolved here, once, and hot paths guard on
        ``self._m``/``tracer.enabled``; execution state is untouched, so
        attaching obs cannot perturb outputs (the bit-parity test pins it).
        """
        self.obs = obs if obs is not None else NULL_OBS
        self._m = None
        if self.obs.metrics.enabled:
            reg = self.obs.metrics
            self._m = {
                "tokens_in": reg.counter(
                    "serving_tokens_in_total",
                    "Prompt tokens prefilled (unique rows in paged mode)"),
                "tokens_out": reg.counter(
                    "serving_tokens_out_total",
                    "Tokens sampled across all sequences"),
                "kv_blocks": reg.gauge(
                    "serving_kv_blocks_in_use",
                    "Paged KV blocks currently allocated"),
                "kv_high": reg.gauge(
                    "serving_kv_blocks_high_water",
                    "Peak paged KV block occupancy"),
                "slots": reg.gauge(
                    "serving_slots_in_use",
                    "Dense KV sequence slots currently resident"),
                "spec_proposed": reg.counter(
                    "serving_spec_proposed_total",
                    "Draft tokens proposed to speculative verify"),
                "spec_accepted": reg.counter(
                    "serving_spec_accepted_total",
                    "Draft tokens accepted by speculative verify"),
                "spec_accept": reg.histogram(
                    "serving_spec_accept_rate",
                    "Per-verify-step draft token accept rate",
                    buckets=RATIO_BUCKETS),
                "spec_tps": reg.gauge(
                    "serving_spec_tokens_per_step",
                    "Tokens committed per decode step "
                    "(last speculative verify)"),
                "pool_hits": reg.counter(
                    "serving_prefix_pool_hits_total",
                    "Full prefix blocks resolved from the resident "
                    "prefix-pool trie (prefill skipped)"),
                "pool_misses": reg.counter(
                    "serving_prefix_pool_misses_total",
                    "Full prefix blocks prefilled fresh and inserted "
                    "into the trie"),
                "pool_evictions": reg.counter(
                    "serving_prefix_pool_evictions_total",
                    "Idle (zero-ref) trie blocks evicted LRU to fit "
                    "new tails"),
                "pool_resident": reg.gauge(
                    "serving_prefix_pool_blocks_resident",
                    "KV blocks currently indexed by the prefix-pool trie"),
                "pool_ratio": reg.histogram(
                    "serving_prefix_pool_hit_ratio",
                    "Per-batch fraction of full prefix blocks served "
                    "from the trie", buckets=RATIO_BUCKETS),
            }

    def _note_occupancy(self) -> None:
        if self._m is None:
            return
        if self.allocator is not None:
            used = self.allocator.blocks_in_use
            self._m["kv_blocks"].set(used)
            self._m["kv_high"].set_max(used)
            if self.prefix_pool is not None:
                self._m["pool_resident"].set(
                    self.prefix_pool.blocks_resident)
        else:
            self._m["slots"].set(self.slots_in_use)

    # ------------------------------------------------------------------ jitted
    def _prefill(self, params, tokens, cache, extras, block_table=None,
                 copy_src=None, copy_dst=None):
        batch = {"tokens": tokens, **extras}
        if block_table is not None:
            batch["block_table"] = block_table
        logits, cache, _ = self.model.forward(params, batch, cache)
        if copy_src is not None:
            # CoW fan-out of the shared partial prefix block: fused into the
            # prefill step so the batch is decode-ready in one dispatch
            cache = cache_mod.copy_cache_blocks(cache, copy_src, copy_dst)
        return logits[:, -1], cache

    def _tail_prefill(self, params, tokens, start_pos, cache, extras,
                      block_table, *, kv_len):
        """Prefill only a prompt's tail against cached prefix blocks.

        ``decode=True`` forces the cache-attending branches at S > 1 (the
        speculative-verify mechanism): the tail queries scatter their KV
        into the resident pool through ``block_table`` *before* attending,
        then each attends to every cached position ``<= its own`` — the hit
        chain's prefix KV plus the tail itself, masked causally by position.
        The gathered reference path reduces over the same ``kv_len = plen``
        positions in the same order as a full prefill, so tail logits are
        *bit-identical* to prefilling the whole prompt (pinned by
        ``tests/test_prefix_pool.py``); a zero-hit prompt runs its whole
        prompt through this path (start_pos 0) with the same guarantee."""
        B, S = tokens.shape[0], tokens.shape[1]
        pos = start_pos + jnp.arange(S, dtype=jnp.int32)[None, :]
        pos = jnp.broadcast_to(pos, (B, S))
        if self.model.cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
        b = {"tokens": tokens, "positions": pos, "block_table": block_table,
             **extras}
        logits, cache, _ = self.model.forward(params, b, cache,
                                              kv_len=kv_len, decode=True)
        return logits[:, -1], cache

    def _decode_step(self, params, tok, step_pos, cache, rng, temperature,
                     extras, block_table=None, *, kv_len=None, greedy=False):
        B = tok.shape[0]
        # positions are built inside the jit from the scalar step counter:
        # nothing per-step is re-tiled or re-staged on the host
        pos = jnp.full((B, 1), step_pos, jnp.int32)
        if self.model.cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
        b = {"tokens": tok, "positions": pos, **extras}
        if block_table is not None:
            b["block_table"] = block_table
        logits, cache, _ = self.model.forward(params, b, cache, kv_len=kv_len)
        logits = logits[:, 0].astype(jnp.float32)          # (B, V) or (B, K, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        if greedy:          # temperature == 0 convention (static branch)
            sample = jnp.argmax(logits, axis=-1)
        else:
            sample = jax.random.categorical(rng, logits / temperature,
                                            axis=-1)
        chosen_logp = jnp.take_along_axis(logp, sample[..., None],
                                          axis=-1)[..., 0]
        return sample, chosen_logp, cache

    def _spec_verify(self, params, toks, base_pos, cache, rng, temperature,
                     extras, block_table=None, *, kv_len=None, greedy=False):
        """One speculative verify forward: score the last committed token +
        n drafts (S = n + 1 queries per row) against the cache, then
        accept/reject. ``base_pos`` is each row's position of ``toks[:, 0]``
        — rows progress raggedly, so it is per-sequence and traced.
        ``decode=True`` forces the cache-attending branches at S > 1; the
        scatter of these S positions happens before attention, so every
        query sees exactly its own prefix (stale rejected-draft entries from
        the previous step are overwritten or masked by position)."""
        B, n_q = toks.shape
        pos = base_pos[:, None] + jnp.arange(n_q, dtype=jnp.int32)[None, :]
        if self.model.cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (B, n_q, 3))
        b = {"tokens": toks, "positions": pos, **extras}
        if block_table is not None:
            b["block_table"] = block_table
        logits, cache, _ = self.model.forward(params, b, cache,
                                              kv_len=kv_len, decode=True)
        accept_len, out_tokens, out_lps = verify_tokens(
            logits.astype(jnp.float32), toks[:, 1:], rng, temperature,
            greedy)
        return accept_len, out_tokens, out_lps, cache

    # ---------------------------------------------------------------- plumbing
    @property
    def paged(self) -> bool:
        return self.allocator is not None

    @property
    def slots_free(self) -> Optional[int]:
        """Remaining KV slot budget (None = unbounded; dense mode only)."""
        if self.max_slots is None:
            return None
        return self.max_slots - self.slots_in_use

    @property
    def blocks_free(self) -> Optional[int]:
        return self.allocator.blocks_free if self.allocator else None

    @property
    def blocks_in_use(self) -> Optional[int]:
        return self.allocator.blocks_in_use if self.allocator else None

    @property
    def pool_blocks_resident(self) -> Optional[int]:
        """Physical pool blocks resident right now. Per-batch pools are
        whole arrays until retirement, so this can exceed ``blocks_in_use``
        after early releases (the budget frees before the memory does). With
        the prefix pool the physical cache is ONE resident array of
        ``kv_blocks`` blocks shared by every batch — no transient overcommit
        remains (the ROADMAP's cross-batch physical block sharing)."""
        if self.allocator is None:
            return None
        if self.prefix_pool is not None:
            return self.allocator.n_blocks if self._pool_cache is not None \
                else 0
        return sum(h.paged.n_pool_blocks for h in self._live.values()
                   if h.paged is not None)

    @property
    def capacity_free(self) -> Optional[int]:
        """Admission budget remaining, in this backend's currency: KV blocks
        (paged) or sequence slots (dense); None = unbounded. Idle prefix-pool
        blocks count as free — `_start_batch_pooled` evicts them on demand —
        so a cache full of reclaimable prefixes never starves admission."""
        if self.allocator is not None:
            free = self.allocator.blocks_free
            if self.prefix_pool is not None:
                free += self.prefix_pool.evictable_blocks
            return free
        return self.slots_free

    @property
    def capacity_total(self) -> Optional[int]:
        if self.allocator is not None:
            return self.allocator.n_blocks
        return self.max_slots

    def _spec_slack(self) -> int:
        """Extra token slots per sequence a speculative backend allocates.

        A verify step writes KV for all its queries before masking decides
        acceptance — a row that already finished (``committed == max_new``)
        still rides the static-shape forward with base position
        ``plen + max_new - 1``, writing up to ``plen + max_new - 1 + n``.
        Slack of ``spec_n + 1`` slots past the non-speculative last written
        position (``plen + max_new - 2``) covers the worst case; priced into
        `request_blocks` so admission stays exact."""
        return self.spec_n + 1 if self.spec_policy is not None else 0

    def request_blocks(self, plen: int, max_new: int, n_samples: int,
                       prompt: Optional[np.ndarray] = None) -> int:
        """Block cost of a request at shared-prefix price: the full prefix
        blocks once, plus per-sample privates (the CoW copy of a partial
        prefix block and the decode blocks). Mirrors `build_paged_layout`
        exactly — written positions end at ``plen + max_new - 2``, plus the
        speculative slack horizon when a draft policy is attached.

        With the resident prefix pool and the prompt tokens given, cost is
        *marginal* against `capacity_free` (= free + evictable blocks): a
        hit block already pinned by a live batch (refcount >= 2) is free —
        the batch only allocates the post-dedup tail — while an *idle* hit
        still charges one unit, because admitting the request pins it and
        removes it from the evictable headroom `capacity_free` counted.
        (Pricing idle hits free double-counts them against that headroom:
        admission could pass while the execution-time eviction loop finds
        the hits it needs to reclaim pinned under itself.) Under
        ``pool_evict="off"`` there is no evictable headroom to consume, so
        every hit is free and the price is the pure tail. The lookup is
        LRU-neutral (``touch=False``)."""
        bs = self.allocator.block_size
        horizon = max_new + self._spec_slack()
        n_logical = max(-(-(plen + horizon - 1) // bs), 1)
        full_prefix = plen // bs
        shared = full_prefix
        if prompt is not None and self.prefix_pool is not None:
            chain = self.prefix_pool.lookup(prompt, self._max_hit(plen),
                                            touch=False)
            if self.prefix_pool.evict_policy == "off":
                free_hits = len(chain)
            else:
                free_hits = sum(1 for g in chain
                                if self.allocator.refcount(g) >= 2)
            shared = full_prefix - free_hits
        return shared + n_samples * (n_logical - full_prefix)

    def request_cost(self, plen: int, max_new: int, n_samples: int,
                     prompt: Optional[np.ndarray] = None) -> int:
        """Admission cost in ``capacity_free`` units (blocks or slots)."""
        if self.allocator is not None:
            return self.request_blocks(plen, max_new, n_samples,
                                       prompt=prompt)
        return n_samples

    def marginal_request_cost(self, prompt: np.ndarray, max_new: int,
                              n_samples: int) -> int:
        """Post-dedup admission price of one request (the scheduler's
        per-batch budget check): identical to `request_cost` without a pool,
        cheaper by the already-pinned trie prefix blocks with one (see
        `request_blocks` for why idle hits still charge under LRU)."""
        return self.request_cost(len(prompt), max_new, n_samples,
                                 prompt=np.asarray(prompt))

    def _max_hit(self, plen: int) -> int:
        """Cap on trie-reusable full prefix blocks: at least one prompt
        token must remain in the tail — the tail forward produces the
        position ``plen - 1`` logits the first sample comes from, and a
        fully cached prompt would otherwise re-scatter into shared blocks."""
        return (plen - 1) // self.allocator.block_size

    @property
    def kv_token_bytes(self) -> int:
        """KV bytes one token position costs across the stack (for mapping
        slot/block budgets to real memory, and the prefill-savings
        telemetry). Follows the actual cache element dtype: int8 KV stores
        one byte per element, so at a fixed byte budget the block budget
        roughly doubles."""
        if self.kv_format == "int8":
            el = 1
        else:
            el = 2 if self.model.dtype == jnp.bfloat16 else 4
        return cache_mod.kv_bytes_per_token(self.model.cfg, el)

    def note_placement(self, placement) -> None:
        self.last_placement = placement
        self.placements.append(placement)

    def note_spec(self, n: int) -> None:
        """Set the draft depth for the NEXT ``start_batch`` (the router's
        per-batch choice; 0 runs the batch without drafting). Depths above
        ``spec_n`` raise — the slack allocation is sized for ``spec_n``."""
        if self.spec_policy is None:
            raise RuntimeError("note_spec on a backend with no draft policy")
        n = int(n)
        if not 0 <= n <= self.spec_n:
            raise ValueError(f"spec depth {n} outside [0, {self.spec_n}] "
                             "(slack allocation is sized for spec_n)")
        self._next_spec_n = n

    def _consume_spec_n(self) -> int:
        """Draft depth the next batch runs at: the noted per-batch depth if
        the router set one, else the configured maximum."""
        if self.spec_policy is None:
            return 0
        n = self._next_spec_n if self._next_spec_n is not None else self.spec_n
        self._next_spec_n = None
        return n

    @property
    def _multi_codebook(self) -> bool:
        return self.model.cfg.n_codebooks > 1

    # ---------------------------------------------------------------- step API
    def start_batch(self, prompts: Sequence[np.ndarray],
                    n_samples: Union[int, Sequence[int]], max_new: int,
                    temperature: float, rng: jax.Array,
                    extras: Optional[Dict] = None) -> InFlightBatch:
        """Prefill equal-length prompts and sample the first token.

        ``n_samples`` may be a single count or one per prompt (mixed-tier
        batches can carry different coverage floors). ``extras`` values are
        per-prompt rows, tiled to the sequence count here — once; decode
        steps reuse the tiled arrays.

        Paged mode prefills one row per *prompt* and fans the result out to
        the repeats through shared prefix blocks (+ a tiled first-token
        sample, bit-identical to prefilling every repeat).
        """
        extras = extras or {}
        mc = self._multi_codebook
        repeats = ([int(n_samples)] * len(prompts)
                   if isinstance(n_samples, int) else
                   [int(n) for n in n_samples])
        if not prompts or any(k < 1 for k in repeats):
            # a 0-sample request would allocate prefix blocks that no
            # sequence references (and so could never release)
            raise ValueError("start_batch needs >= 1 prompt and >= 1 "
                             f"sample per prompt (got repeats={repeats})")
        plen = len(prompts[0])
        if any(len(p) != plen for p in prompts):
            raise ValueError("start_batch requires equal-length prompts "
                             "(one static-shape bucket)")
        uniform = len(set(repeats)) == 1
        rep: Union[int, np.ndarray] = \
            repeats[0] if uniform else np.asarray(repeats)
        base = np.stack(list(prompts))                      # (R, L[,K])
        B = int(sum(repeats))

        tracer = self.obs.tracer
        t0 = time.perf_counter() if tracer.enabled else 0.0
        n_spec = self._consume_spec_n()
        if self.prefix_pool is not None:
            h = self._start_batch_pooled(prompts, repeats, rep, base, B,
                                         plen, max_new, temperature, rng,
                                         extras, mc)
            # only the post-dedup tails were prefilled
            prefilled = (len(prompts) * plen
                         - h.pool_hit_blocks * self.allocator.block_size)
        elif self.allocator is not None:
            h = self._start_batch_paged(prompts, repeats, rep, base, B, plen,
                                        max_new, temperature, rng, extras, mc)
            prefilled = len(prompts) * plen     # one row per unique prompt
        else:
            h = self._start_batch_dense(prompts, repeats, rep, base, B, plen,
                                        max_new, temperature, rng, extras, mc)
            prefilled = B * plen
        if h.pending_prefill is not None:
            # chunked: nothing forwarded yet — each chunk step meters its
            # own tokens and spans; the draft depth arms at finish
            h.pending_prefill.n_spec = n_spec
            n_spec = 0
            prefilled = 0
        if n_spec > 0:
            first = np.asarray(h.tok).ravel()
            lp0 = np.asarray(h.out_lps[0]).ravel()
            hists: List[np.ndarray] = []
            for prompt, k in zip(prompts, repeats):
                p = np.asarray(prompt, np.int64).ravel()
                for _ in range(k):
                    i = len(hists)
                    hists.append(np.concatenate([p, first[i:i + 1]]))
            h.spec = SpecState(
                policy=self.spec_policy, n=n_spec,
                committed=np.ones(B, np.int64), histories=hists,
                toks=[[int(t)] for t in first],
                lps=[[float(x)] for x in lp0])
        self._live[id(h)] = h
        if tracer.enabled:
            # wall clock: real dispatch time of prefill + first sample,
            # batch id supplied by the scheduler via tracer.batch_context
            tracer.emit("prefill", t0, time.perf_counter(), clock="wall",
                        prefill_tokens=prefilled, n_sequences=B, plen=plen)
        if self._m is not None:
            self._m["tokens_in"].inc(prefilled)
            if h.pending_prefill is None:       # else metered at finish
                self._m["tokens_out"].inc(B)    # first token per sequence
            if self.prefix_pool is not None and h.paged is not None:
                lookupable = len(prompts) * (plen // self.allocator.block_size)
                misses = lookupable - h.pool_hit_blocks
                self._m["pool_hits"].inc(h.pool_hit_blocks)
                self._m["pool_misses"].inc(misses)
                if h.pool_evictions:
                    self._m["pool_evictions"].inc(h.pool_evictions)
                if lookupable:
                    self._m["pool_ratio"].observe(
                        h.pool_hit_blocks / lookupable)
            self._note_occupancy()
        return h

    def _start_batch_dense(self, prompts, repeats, rep, base, B, plen,
                           max_new, temperature, rng, extras,
                           mc) -> InFlightBatch:
        tokens = np.repeat(base, rep, axis=0)               # (B, L[,K])
        if self.max_slots is not None and \
                self.slots_in_use + B > self.max_slots:
            raise RuntimeError(
                f"KV slot budget exceeded: {self.slots_in_use}+{B} > "
                f"{self.max_slots} (scheduler must check slots_free)")
        tiled_extras = {k: jnp.repeat(jnp.asarray(v), rep, axis=0)
                        for k, v in extras.items()}

        cache = self.model.init_cache(B, plen + max_new + self._spec_slack())
        last_logits, cache = self._prefill_jit(
            self.params, jnp.asarray(tokens), cache, tiled_extras)

        # first sampled token comes from the prefill logits
        rng, sub = jax.random.split(rng)
        lf = last_logits.astype(jnp.float32)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        if temperature > 0:
            tok = jax.random.categorical(sub, lf / temperature, axis=-1)
        else:
            tok = jnp.argmax(lf, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]

        self.slots_in_use += B
        return InFlightBatch(
            prompts=list(prompts), repeats=repeats, plen=plen,
            max_new=max_new, temperature=temperature, rng=rng,
            extras=tiled_extras, cache=cache, tok=tok, step=1,
            out_toks=[np.asarray(tok)],
            out_lps=[np.asarray(lp if not mc else lp.mean(-1))])

    def _start_batch_paged(self, prompts, repeats, rep, base, B, plen,
                           max_new, temperature, rng, extras,
                           mc) -> InFlightBatch:
        R = len(prompts)
        need = sum(self.request_blocks(plen, max_new, k) for k in repeats)
        if need > self.allocator.blocks_free:
            raise RuntimeError(
                f"KV block budget exceeded: need {need} > "
                f"{self.allocator.blocks_free} free (scheduler must check "
                "blocks_free)")
        layout = build_paged_layout(self.allocator, plen,
                                    max_new + self._spec_slack(), repeats)
        try:
            cache = self.model.init_paged_cache(
                layout.n_pool_blocks, layout.block_size,
                kv_dtype=jnp.int8 if self.kv_format == "int8" else None)
            # prefill rows are the unique prompts (extras per-prompt as-is);
            # decode rows are the tiled sequences — both tiled exactly once
            prefill_extras = {k: jnp.asarray(v) for k, v in extras.items()}
            decode_extras = {k: jnp.repeat(jnp.asarray(v), rep, axis=0)
                             for k, v in extras.items()}
            if self.prefill_chunk is not None:
                # chunked: the fresh paged cache masks every position, so
                # slice-at-a-time tail prefills are safe; CoW fan-out and
                # first-token sampling run at finish
                jobs: Deque[Tuple[List[int], int, int]] = deque()
                s = 0
                while s < plen:
                    e = min(s + self.prefill_chunk, plen)
                    jobs.append((list(range(R)), s, e))
                    s = e
                return InFlightBatch(
                    prompts=list(prompts), repeats=repeats, plen=plen,
                    max_new=max_new, temperature=temperature, rng=rng,
                    extras=decode_extras, cache=cache, tok=None, step=0,
                    paged=layout,
                    block_table=jnp.asarray(layout.decode_table),
                    prefill_bytes_saved=float((B - R) * plen
                                              * self.kv_token_bytes),
                    pending_prefill=PendingPrefill(
                        jobs=jobs, base=base, extras=prefill_extras,
                        last_rows=[None] * R, rep=rep, mc=mc,
                        full_prefix=0))
            has_cow = layout.copy_src.size > 0
            last_logits, cache = self._prefill_jit(
                self.params, jnp.asarray(base), cache, prefill_extras,
                jnp.asarray(layout.prefill_table),
                jnp.asarray(layout.copy_src) if has_cow else None,
                jnp.asarray(layout.copy_dst) if has_cow else None)
        except BaseException:
            # no handle exists yet to release() — return every reference the
            # layout took, or a failed prefill permanently shrinks the budget
            for gids in layout.seq_gids:
                for g in gids:
                    self.allocator.free(g)
            raise

        # fan the unique-prompt logits out to the repeats, then sample with
        # the same key/shape as the dense path — bit-identical first token
        rng, sub = jax.random.split(rng)
        lf = jnp.repeat(last_logits.astype(jnp.float32), rep, axis=0)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        if temperature > 0:
            tok = jax.random.categorical(sub, lf / temperature, axis=-1)
        else:
            tok = jnp.argmax(lf, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]

        return InFlightBatch(
            prompts=list(prompts), repeats=repeats, plen=plen,
            max_new=max_new, temperature=temperature, rng=rng,
            extras=decode_extras, cache=cache, tok=tok, step=1,
            out_toks=[np.asarray(tok)],
            out_lps=[np.asarray(lp if not mc else lp.mean(-1))],
            paged=layout, block_table=jnp.asarray(layout.decode_table),
            prefill_bytes_saved=float((B - R) * plen * self.kv_token_bytes))

    def _ensure_pool_cache(self):
        """The single resident physical cache (lazy: sized to the whole
        ``kv_blocks`` budget, so it is only materialized once serving
        actually starts). Block tables index it with global allocator ids;
        it outlives every batch."""
        if self._pool_cache is None:
            self._pool_cache = self.model.init_paged_cache(
                self.allocator.n_blocks, self.allocator.block_size,
                kv_dtype=jnp.int8 if self.kv_format == "int8" else None)
        return self._pool_cache

    def _start_batch_pooled(self, prompts, repeats, rep, base, B, plen,
                            max_new, temperature, rng, extras,
                            mc) -> InFlightBatch:
        """Paged start with the resident prefix pool: resolve each prompt's
        longest cached block chain (pinning it with per-sequence refs),
        evict idle LRU blocks to fit the post-dedup tails, then prefill
        *only the tails* — grouped by hit depth so every forward keeps a
        static shape — and finally index the freshly filled full-prefix
        chains for the batches that follow."""
        pool = self.prefix_pool
        alloc = self.allocator
        bs = alloc.block_size
        R = len(prompts)
        full_prefix = plen // bs
        # 1. acquire hit chains first: refs pin them, so the eviction pass
        #    below can never reclaim a block this batch just resolved
        hit_chains = [pool.acquire(p, self._max_hit(plen), holders=k)
                      for p, k in zip(prompts, repeats)]
        horizon = max_new + self._spec_slack()
        n_logical = max(-(-(plen + horizon - 1) // bs), 1)
        need = sum(full_prefix - len(ch) + k * (n_logical - full_prefix)
                   for ch, k in zip(hit_chains, repeats))
        # 2. make room for the tails (LRU over idle trie leaves only)
        evicted = pool.ensure_free(need)
        if need > alloc.blocks_free:
            for ch, k in zip(hit_chains, repeats):
                for g in ch:
                    for _ in range(k):
                        alloc.free(g)
            raise RuntimeError(
                f"KV block budget exceeded: need {need} tail blocks > "
                f"{alloc.blocks_free} free after {evicted} eviction(s) "
                "(scheduler must check capacity_free)")
        # 3. static block schedule over GLOBAL ids (allocation cannot fail
        #    past the check above)
        layout = build_paged_layout(alloc, plen, horizon, repeats,
                                    hit_chains=hit_chains)
        cache = self._ensure_pool_cache()
        try:
            # invalidate the pos slots of every block allocated this batch:
            # the resident cache outlives batches, and a block back from the
            # free list still carries its previous occupant's positions — a
            # stale slot in a partially filled tail block would become
            # visible the moment decode advances past it
            hit_gids = {int(g) for ch in hit_chains for g in ch}
            fresh = sorted({int(g) for gids in layout.seq_gids
                            for g in gids} - hit_gids)
            if fresh:
                cache = self._reset_blocks_jit(
                    cache, jnp.asarray(fresh, jnp.int32))
            prefill_extras = {k: jnp.asarray(v) for k, v in extras.items()}
            decode_extras = {k: jnp.repeat(jnp.asarray(v), rep, axis=0)
                             for k, v in extras.items()}
            # 4. tail-only prefill, one static-shape forward per hit depth
            groups: Dict[int, List[int]] = {}
            for i, c in enumerate(layout.hit_counts):
                groups.setdefault(c, []).append(i)
            if self.prefill_chunk is not None:
                # chunked: enqueue the tail slices instead of forwarding —
                # decode_step drains one per call; CoW fan-out, trie
                # indexing and first-token sampling run at finish
                jobs: Deque[Tuple[List[int], int, int]] = deque()
                for c, idxs in sorted(groups.items()):
                    s = c * bs
                    while s < plen:
                        e = min(s + self.prefill_chunk, plen)
                        jobs.append((idxs, s, e))
                        s = e
                self._pool_cache = cache
                tail_tokens = sum(plen - c * bs
                                  for c in layout.hit_counts)
                return InFlightBatch(
                    prompts=list(prompts), repeats=repeats, plen=plen,
                    max_new=max_new, temperature=temperature, rng=rng,
                    extras=decode_extras, cache=None, tok=None, step=0,
                    paged=layout,
                    block_table=jnp.asarray(layout.decode_table),
                    prefill_bytes_saved=float((B * plen - tail_tokens)
                                              * self.kv_token_bytes),
                    pool_hit_blocks=sum(layout.hit_counts),
                    pool_evictions=evicted,
                    pending_prefill=PendingPrefill(
                        jobs=jobs, base=base, extras=prefill_extras,
                        last_rows=[None] * R, rep=rep, mc=mc,
                        full_prefix=full_prefix))
            last_rows: List[Any] = [None] * R
            for c, idxs in sorted(groups.items()):
                gl, cache = self._tail_prefill_jit(
                    self.params, jnp.asarray(base[idxs][:, c * bs:]),
                    jnp.asarray(c * bs, jnp.int32), cache,
                    {k: v[jnp.asarray(idxs)]
                     for k, v in prefill_extras.items()},
                    jnp.asarray(layout.prefill_table[idxs]), kv_len=plen)
                for j, i in enumerate(idxs):
                    last_rows[i] = gl[j]
            last_logits = jnp.stack(last_rows, axis=0)
            if layout.copy_src.size > 0:
                # CoW fan-out of the shared partial prefix block (partials
                # are never pool-shared — same schedule as per-batch paging)
                cache = self._copy_blocks_jit(cache,
                                              jnp.asarray(layout.copy_src),
                                              jnp.asarray(layout.copy_dst))
        except BaseException:
            # every reference the batch took (hits included — their holder
            # refs unwind to the trie ref) must return, or a failed prefill
            # permanently shrinks the budget
            for gids in layout.seq_gids:
                for g in gids:
                    alloc.free(g)
            raise
        self._pool_cache = cache
        # 5. index the freshly filled full-prefix chains (post-success: the
        #    trie must never point at unfilled blocks). A same-prefix
        #    sibling within this batch keeps the first writer's blocks.
        for i, p in enumerate(prompts):
            pool.insert(p, [int(g) for g in
                            layout.prefill_table[i][:full_prefix]])
        hit_blocks = sum(layout.hit_counts)

        # fan the unique-prompt logits out to the repeats, then sample with
        # the same key/shape as the dense path — bit-identical first token
        rng, sub = jax.random.split(rng)
        lf = jnp.repeat(last_logits.astype(jnp.float32), rep, axis=0)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        if temperature > 0:
            tok = jax.random.categorical(sub, lf / temperature, axis=-1)
        else:
            tok = jnp.argmax(lf, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]

        tail_tokens = sum(plen - c * bs for c in layout.hit_counts)
        return InFlightBatch(
            prompts=list(prompts), repeats=repeats, plen=plen,
            max_new=max_new, temperature=temperature, rng=rng,
            extras=decode_extras, cache=None, tok=tok, step=1,
            out_toks=[np.asarray(tok)],
            out_lps=[np.asarray(lp if not mc else lp.mean(-1))],
            paged=layout, block_table=jnp.asarray(layout.decode_table),
            prefill_bytes_saved=float((B * plen - tail_tokens)
                                      * self.kv_token_bytes),
            pool_hit_blocks=hit_blocks, pool_evictions=evicted)

    def _prefill_chunk_step(self, h: InFlightBatch) -> None:
        """Run ONE pending prefill slice (the chunked-prefill unit a
        ``decode_step`` call spends instead of a token)."""
        pp = h.pending_prefill
        idxs, s, e = pp.jobs.popleft()
        tracer = self.obs.tracer
        t0 = time.perf_counter() if tracer.enabled else 0.0
        pooled = h.cache is None
        cache = self._pool_cache if pooled else h.cache
        gl, cache = self._tail_prefill_jit(
            self.params, jnp.asarray(pp.base[idxs][:, s:e]),
            jnp.asarray(s, jnp.int32), cache,
            {k: v[jnp.asarray(idxs)] for k, v in pp.extras.items()},
            jnp.asarray(h.paged.prefill_table[idxs]), kv_len=h.plen)
        if pooled:
            self._pool_cache = cache
        else:
            h.cache = cache
        if e == h.plen:
            for j, i in enumerate(idxs):
                pp.last_rows[i] = gl[j]
        if tracer.enabled:
            tracer.emit("prefill", t0, time.perf_counter(), clock="wall",
                        prefill_tokens=len(idxs) * (e - s),
                        n_sequences=h.n_sequences, plen=h.plen,
                        chunk=[s, e])
        if self._m is not None:
            self._m["tokens_in"].inc(len(idxs) * (e - s))
        if not pp.jobs:
            self._finish_chunked_prefill(h)

    def _finish_chunked_prefill(self, h: InFlightBatch) -> None:
        """Last chunk landed: CoW-fan-out the shared partial block, index
        the now-filled full-prefix chains (pooled mode), sample the first
        token with the exact split sequence of the one-shot path (bit
        parity), and arm the draft state if a depth was noted."""
        pp = h.pending_prefill
        layout = h.paged
        pooled = h.cache is None
        cache = self._pool_cache if pooled else h.cache
        if layout.copy_src.size > 0:
            cache = self._copy_blocks_jit(cache,
                                          jnp.asarray(layout.copy_src),
                                          jnp.asarray(layout.copy_dst))
        if pooled:
            self._pool_cache = cache
            for i, p in enumerate(h.prompts):
                self.prefix_pool.insert(
                    p, [int(g) for g in
                        layout.prefill_table[i][:pp.full_prefix]])
        else:
            h.cache = cache
        last_logits = jnp.stack(pp.last_rows, axis=0)
        h.rng, sub = jax.random.split(h.rng)
        lf = jnp.repeat(last_logits.astype(jnp.float32), pp.rep, axis=0)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        if h.temperature > 0:
            tok = jax.random.categorical(sub, lf / h.temperature, axis=-1)
        else:
            tok = jnp.argmax(lf, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]
        h.tok = tok
        h.step = 1
        h.out_toks = [np.asarray(tok)]
        h.out_lps = [np.asarray(lp if not pp.mc else lp.mean(-1))]
        if pp.n_spec > 0:
            first = np.asarray(h.tok).ravel()
            lp0 = np.asarray(h.out_lps[0]).ravel()
            hists: List[np.ndarray] = []
            for prompt, k in zip(h.prompts, h.repeats):
                p = np.asarray(prompt, np.int64).ravel()
                for _ in range(k):
                    i = len(hists)
                    hists.append(np.concatenate([p, first[i:i + 1]]))
            h.spec = SpecState(
                policy=self.spec_policy, n=pp.n_spec,
                committed=np.ones(h.n_sequences, np.int64),
                histories=hists,
                toks=[[int(t)] for t in first],
                lps=[[float(x)] for x in lp0])
        if self._m is not None:
            self._m["tokens_out"].inc(h.n_sequences)
        h.pending_prefill = None

    def park_batch(self, h: InFlightBatch,
                   histories: Sequence[np.ndarray]) -> int:
        """Preemption handoff: index the victim's *filled* full blocks in
        the resident prefix pool before releasing the batch, so resuming it
        is a trie hit that prefills only the post-preemption tail.

        ``histories[i]`` is sequence row *i*'s token history (prompt +
        committed tokens). After ``step`` sampled tokens the KV holds
        written positions through ``plen + step - 2`` (the newest token is
        sampled, not yet scattered), so exactly ``(len(history) - 1) //
        block_size`` leading blocks of the row's table are full and
        correct — on a speculative batch that bound also keeps any stale
        rejected-draft writes (which live past the committed frontier) out
        of the trie. Returns parked blocks; degrades to a plain release
        when there is no resident pool to park into, or mid-chunked-prefill
        (no block is guaranteed filled)."""
        pool = self.prefix_pool
        if pool is None or h.paged is None or h.pending_prefill is not None:
            self.release(h)
            return 0
        if len(histories) != h.n_sequences:
            raise ValueError(
                f"park_batch needs one history per sequence row "
                f"({h.n_sequences}), got {len(histories)}")
        bs = self.allocator.block_size
        parked = 0
        for i, hist in enumerate(histories):
            if i in h.freed_seqs:
                continue
            hist = np.asarray(hist)
            filled = (len(hist) - 1) // bs
            if filled <= 0:
                continue
            row = [int(g) for g in h.paged.decode_table[i][:filled]]
            pool.insert(hist, row)
            parked += filled
        self.release(h)
        return parked

    def decode_step(self, h: InFlightBatch) -> bool:
        """Advance one token (or one draft/verify round on a speculative
        batch); returns True while the batch still has decode steps left
        (so ``while backend.decode_step(h): pass`` drains it)."""
        if h.pending_prefill is not None:
            self._prefill_chunk_step(h)
            return True
        if h.spec is not None:
            return self._spec_decode_step(h)
        if h.done:
            return False
        tracer = self.obs.tracer
        t0 = time.perf_counter() if tracer.enabled else 0.0
        mc = self._multi_codebook
        h.rng, sub = jax.random.split(h.rng)
        step_pos = jnp.asarray(h.plen + h.step - 1, jnp.int32)
        tok_in = h.tok[:, None] if not mc else h.tok[:, None, :]
        pooled = h.cache is None           # resident pool, shared by batches
        cache = self._pool_cache if pooled else h.cache
        h.tok, lp, cache = self._decode_jit(
            self.params, tok_in, step_pos, cache, sub, h.temperature,
            h.extras, h.block_table,
            kv_len=h.paged.kv_len if h.paged is not None else None,
            greedy=h.temperature == 0.0)
        if pooled:
            self._pool_cache = cache
        else:
            h.cache = cache
        h.out_toks.append(np.asarray(h.tok))
        h.out_lps.append(np.asarray(lp if not mc else lp.mean(-1)))
        h.step += 1
        if tracer.enabled:
            tracer.emit("decode", t0, time.perf_counter(), clock="wall",
                        step=h.step, n_sequences=h.n_sequences)
        if self._m is not None:
            self._m["tokens_out"].inc(h.n_sequences - len(h.freed_seqs))
        return not h.done

    def _spec_decode_step(self, h: InFlightBatch) -> bool:
        """One draft -> verify -> commit round of a speculative batch.

        Rows progress raggedly: each commits ``accept_len + 1`` tokens,
        clamped to its remaining horizon room; a finished row stays in the
        static-shape verify (its writes land in the slack slots, see
        `_spec_slack`) but commits nothing. Early-released rows
        (``freed_seqs``) keep committing like the non-speculative path keeps
        sampling them — their tokens just stop counting toward metrics — so
        a release landing between verify steps touches the allocator exactly
        once per block, never the in-flight verify state."""
        if h.done:
            return False
        sp = h.spec
        tracer = self.obs.tracer
        t_step = time.perf_counter() if tracer.enabled else 0.0
        B = h.n_sequences
        n = sp.n
        drafts = np.asarray(sp.policy.propose(sp.histories, n), np.int32)
        if drafts.shape != (B, n):
            raise ValueError(f"draft policy {sp.policy.name!r} returned "
                             f"shape {drafts.shape}, expected {(B, n)}")
        t_draft = time.perf_counter() if tracer.enabled else 0.0
        if tracer.enabled:
            tracer.emit("draft", t_step, t_draft, clock="wall",
                        policy=sp.policy.name, n=n, n_sequences=B)
        h.rng, sub = jax.random.split(h.rng)
        last = np.asarray([row[-1] for row in sp.toks], np.int32)
        toks_in = np.concatenate([last[:, None], drafts], axis=1)
        base_pos = np.asarray(h.plen + sp.committed - 1, np.int32)
        pooled = h.cache is None           # resident pool, shared by batches
        cache = self._pool_cache if pooled else h.cache
        accept_len, out_tokens, out_lps, cache = self._spec_verify_jit(
            self.params, jnp.asarray(toks_in), jnp.asarray(base_pos),
            cache, sub, h.temperature, h.extras, h.block_table,
            kv_len=h.paged.kv_len if h.paged is not None else None,
            greedy=h.temperature == 0.0)
        if pooled:
            self._pool_cache = cache
        else:
            h.cache = cache
        accept_len = np.asarray(accept_len)
        out_tokens = np.asarray(out_tokens)
        out_lps = np.asarray(out_lps)

        emitted = 0             # metric-visible tokens (non-released rows)
        committed_this = 0      # tokens committed by rows still decoding
        accepted_this = 0
        active = 0
        for b in range(B):
            room = h.max_new - int(sp.committed[b])
            if room <= 0:
                continue        # finished row: verify output discarded
            active += 1
            a = int(accept_len[b])
            accepted_this += a
            sp.proposed += n
            sp.accepted += a
            e = min(a + 1, room)
            new = out_tokens[b, :e]
            sp.toks[b].extend(int(t) for t in new)
            sp.lps[b].extend(float(x) for x in out_lps[b, :e])
            sp.histories[b] = np.concatenate(
                [sp.histories[b], new.astype(np.int64)])
            sp.committed[b] += e
            committed_this += e
            if b not in h.freed_seqs:
                emitted += e
        sp.steps += 1
        h.step = int(sp.committed.min())
        if tracer.enabled:
            now = time.perf_counter()
            tracer.emit("verify", t_draft, now, clock="wall", n=n,
                        n_sequences=B, accepted=accepted_this)
            tracer.emit("decode", t_step, now, clock="wall", step=h.step,
                        n_sequences=B)
        if self._m is not None:
            self._m["tokens_out"].inc(emitted)
            if active and n > 0:
                self._m["spec_proposed"].inc(active * n)
                self._m["spec_accepted"].inc(accepted_this)
                self._m["spec_accept"].observe(accepted_this / (active * n))
                self._m["spec_tps"].set(committed_this / active)
        return not h.done

    def release(self, h: InFlightBatch) -> None:
        """Return a batch's remaining KV budget (blocks or slots). Raises on
        an unknown or already-released handle — a double release must fail
        loudly instead of silently driving the budget negative."""
        if self._live.pop(id(h), None) is None:
            raise RuntimeError("release of unknown or already-released "
                               "batch handle")
        if h.paged is not None:
            for i, gids in enumerate(h.paged.seq_gids):
                if i in h.freed_seqs:
                    continue
                for g in gids:
                    self.allocator.free(g)
        else:
            self.slots_in_use -= h.n_sequences - len(h.freed_seqs)
        h.freed_seqs = set(range(h.n_sequences))
        self._note_occupancy()

    def release_sequences(self, h: InFlightBatch,
                          seq_indices: Sequence[int]) -> int:
        """Early-release finished sequences' KV budget (CSVET early stop:
        once one sample of a prompt verifies, the remaining repeats cannot
        change pass@k). The batch keeps decoding with its static shapes, but
        the released rows' blocks/slots are free for new admissions *now*
        instead of at batch retirement. Returns blocks (or slots) actually
        returned to the budget; shared prefix blocks only come back with
        their last holder.

        Note this frees *budget*, not bytes: the batch's physical pool is
        one array, resident until retirement (`pool_blocks_resident`), so
        admissions riding on early-released budget transiently overcommit
        by at most the released count — see `BlockAllocator`."""
        if id(h) not in self._live:
            raise RuntimeError("release_sequences on unknown or "
                               "already-released batch handle")
        bad = [i for i in seq_indices if not 0 <= i < h.n_sequences]
        if bad:
            raise ValueError(f"sequence indices {bad} out of range for a "
                             f"{h.n_sequences}-sequence batch")
        freed = 0
        for i in seq_indices:
            if i in h.freed_seqs:
                continue
            h.freed_seqs.add(i)
            if h.paged is not None:
                freed += sum(self.allocator.free(g)
                             for g in h.paged.seq_gids[i])
            else:
                self.slots_in_use -= 1
                freed += 1
        self._note_occupancy()
        return freed

    def finalize(self, h: InFlightBatch) -> List[GenerationResult]:
        """Stack per-step samples into per-request results and release the
        batch's KV budget."""
        mc = self._multi_codebook
        if h.spec is not None:
            # ragged per-sequence lists -> (B, max_new); the commit clamp
            # means done implies every row holds exactly max_new tokens
            toks = np.asarray([row[:h.max_new] for row in h.spec.toks],
                              np.int32)
            lps = np.asarray([row[:h.max_new] for row in h.spec.lps],
                             np.float32)
        else:
            toks = np.stack(h.out_toks, axis=1)             # (B, T[,K])
            lps = np.stack(h.out_lps, axis=1)               # (B, T)
        results = []
        offset = 0
        for prompt, ns in zip(h.prompts, h.repeats):
            sl = slice(offset, offset + ns)
            offset += ns
            samples = [toks[i] for i in range(sl.start, sl.stop)]
            if self.eos_token is not None and not mc:
                samples = [self._truncate(s) for s in samples]
            results.append(GenerationResult(
                prompt=prompt,
                samples=samples,
                logprobs=[float(lps[i].mean())
                          for i in range(sl.start, sl.stop)],
                prefill_tokens=h.plen,
                decode_tokens=int(np.prod(toks.shape[1:2])) * ns,
            ))
        self.release(h)
        return results

    def _truncate(self, sample: np.ndarray) -> np.ndarray:
        hits = np.nonzero(sample == self.eos_token)[0]
        return sample[: hits[0]] if hits.size else sample
