"""Execution backend: the *how* of serving, as a stepwise batch API.

Extracted from the original monolithic ``ServingEngine.generate`` so a
scheduler can interleave work across batches instead of blocking on one
call. The backend owns the jitted prefill/decode functions, the KV-cache
slot budget, and static-shape bucketing; policy (admission, batch
formation, routing) lives in `repro.serving.scheduler`.

The step API is deliberately small:

* ``start_batch`` — prefill a group of equal-length prompts (each tiled by
  its per-request sample count) and sample the first token; returns an
  `InFlightBatch` holding the KV cache and the rng stream.
* ``decode_step`` — advance an in-flight batch by one autoregressive token.
* ``finalize`` — stack the sampled tokens into per-request
  `GenerationResult`s and release the batch's KV slots.

Running ``start_batch`` + ``decode_step`` until done + ``finalize`` is
bit-identical to the pre-refactor monolith (same rng split sequence, same
jitted functions) — `ServingEngine.generate` is now exactly that loop, and
the parity test in ``tests/test_serving_scheduler.py`` pins it.

Batches are formed within a *bucket*: prompts of one length (the static
shape the jit specializes on) with one max-new-tokens horizon and one
temperature. ``bucket_key`` is the canonical key; the scheduler never mixes
buckets inside a batch.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class GenerationResult:
    prompt: np.ndarray
    samples: List[np.ndarray]          # n_samples completions (token arrays)
    logprobs: List[float]              # mean per-token logprob per sample
    prefill_tokens: int = 0
    decode_tokens: int = 0


@dataclass
class InFlightBatch:
    """One prefilled batch mid-decode: the unit the scheduler interleaves."""
    prompts: List[np.ndarray]
    repeats: List[int]                 # samples per prompt (KV slots held)
    plen: int
    max_new: int
    temperature: float
    rng: jax.Array                     # stream state: split once per token
    extras: Dict[str, jax.Array]       # already tiled to sequence count
    cache: Any
    tok: jax.Array                     # last sampled token (B,) or (B, K)
    step: int                          # tokens sampled so far (>= 1)
    out_toks: List[np.ndarray] = field(default_factory=list)
    out_lps: List[np.ndarray] = field(default_factory=list)

    @property
    def n_sequences(self) -> int:
        return sum(self.repeats)

    @property
    def done(self) -> bool:
        return self.step >= self.max_new


def bucket_key(prompt: np.ndarray, max_new: int,
               temperature: float) -> Tuple[int, int, float]:
    """Static-shape bucket: batches may only group requests that share the
    prompt length (the jit's shape key), decode horizon and temperature."""
    return (len(prompt), max_new, float(temperature))


class ExecutionBackend:
    """Owns model execution state: jitted step functions, KV slot budget,
    placement history. ``max_slots`` bounds the number of concurrently
    resident sequences (prompt x samples rows); ``None`` means unbounded
    (the original engine's behaviour)."""

    def __init__(self, model: Model, params, eos_token: Optional[int] = None,
                 max_slots: Optional[int] = None):
        self.model = model
        self.params = params
        self.eos_token = eos_token
        self.max_slots = max_slots
        self.slots_in_use = 0
        # placement hook state (the orchestrator's simulated stage->device
        # plan for whatever is being executed): the scheduler notes the
        # routed operating point per batch; the legacy engine notes its
        # placement_provider's answer per generate call. Bounded history —
        # a long-lived server must not grow linearly with request count.
        self.last_placement = None
        self.placements: Deque = deque(maxlen=256)
        self._prefill_jit = jax.jit(self._prefill)
        self._decode_jit = jax.jit(self._decode_step)

    # ------------------------------------------------------------------ jitted
    def _prefill(self, params, tokens, cache, extras):
        batch = {"tokens": tokens, **extras}
        logits, cache, _ = self.model.forward(params, batch, cache)
        return logits[:, -1], cache

    def _decode_step(self, params, tok, pos, cache, rng, temperature, extras):
        b = {"tokens": tok, "positions": pos, **extras}
        logits, cache, _ = self.model.forward(params, b, cache)
        logits = logits[:, 0].astype(jnp.float32)          # (B, V) or (B, K, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        sample = jax.random.categorical(rng, logits / temperature, axis=-1)
        chosen_logp = jnp.take_along_axis(logp, sample[..., None],
                                          axis=-1)[..., 0]
        return sample, chosen_logp, cache

    # ---------------------------------------------------------------- plumbing
    @property
    def slots_free(self) -> Optional[int]:
        """Remaining KV slot budget (None = unbounded)."""
        if self.max_slots is None:
            return None
        return self.max_slots - self.slots_in_use

    def note_placement(self, placement) -> None:
        self.last_placement = placement
        self.placements.append(placement)

    @property
    def _multi_codebook(self) -> bool:
        return self.model.cfg.n_codebooks > 1

    # ---------------------------------------------------------------- step API
    def start_batch(self, prompts: Sequence[np.ndarray],
                    n_samples: Union[int, Sequence[int]], max_new: int,
                    temperature: float, rng: jax.Array,
                    extras: Optional[Dict] = None) -> InFlightBatch:
        """Prefill equal-length prompts and sample the first token.

        ``n_samples`` may be a single count or one per prompt (mixed-tier
        batches can carry different coverage floors). ``extras`` values are
        per-prompt rows, tiled to the sequence count here.
        """
        extras = extras or {}
        mc = self._multi_codebook
        repeats = ([int(n_samples)] * len(prompts)
                   if isinstance(n_samples, int) else
                   [int(n) for n in n_samples])
        plen = len(prompts[0])
        if any(len(p) != plen for p in prompts):
            raise ValueError("start_batch requires equal-length prompts "
                             "(one static-shape bucket)")
        uniform = len(set(repeats)) == 1
        rep: Union[int, np.ndarray] = \
            repeats[0] if uniform else np.asarray(repeats)
        base = np.stack(list(prompts))                      # (R, L[,K])
        tokens = np.repeat(base, rep, axis=0)               # (B, L[,K])
        B = tokens.shape[0]
        if self.max_slots is not None and \
                self.slots_in_use + B > self.max_slots:
            raise RuntimeError(
                f"KV slot budget exceeded: {self.slots_in_use}+{B} > "
                f"{self.max_slots} (scheduler must check slots_free)")
        tiled_extras = {k: jnp.repeat(jnp.asarray(v), rep, axis=0)
                        for k, v in extras.items()}

        cache = self.model.init_cache(B, plen + max_new)
        last_logits, cache = self._prefill_jit(
            self.params, jnp.asarray(tokens), cache, tiled_extras)

        # first sampled token comes from the prefill logits
        rng, sub = jax.random.split(rng)
        lf = last_logits.astype(jnp.float32)
        logp0 = jax.nn.log_softmax(lf, axis=-1)
        tok = jax.random.categorical(sub, lf / temperature, axis=-1)
        lp = jnp.take_along_axis(logp0, tok[..., None], axis=-1)[..., 0]

        self.slots_in_use += B
        return InFlightBatch(
            prompts=list(prompts), repeats=repeats, plen=plen,
            max_new=max_new, temperature=temperature, rng=rng,
            extras=tiled_extras, cache=cache, tok=tok, step=1,
            out_toks=[np.asarray(tok)],
            out_lps=[np.asarray(lp if not mc else lp.mean(-1))])

    def decode_step(self, h: InFlightBatch) -> bool:
        """Advance one token; returns True while the batch still has decode
        steps left (so ``while backend.decode_step(h): pass`` drains it)."""
        if h.done:
            return False
        mc = self._multi_codebook
        B = h.n_sequences
        h.rng, sub = jax.random.split(h.rng)
        pos = jnp.full((B, 1), h.plen + h.step - 1, jnp.int32)
        if self.model.cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
        tok_in = h.tok[:, None] if not mc else h.tok[:, None, :]
        h.tok, lp, h.cache = self._decode_jit(
            self.params, tok_in, pos, h.cache, sub, h.temperature, h.extras)
        h.out_toks.append(np.asarray(h.tok))
        h.out_lps.append(np.asarray(lp if not mc else lp.mean(-1)))
        h.step += 1
        return not h.done

    def finalize(self, h: InFlightBatch) -> List[GenerationResult]:
        """Stack per-step samples into per-request results and release the
        batch's KV slots."""
        mc = self._multi_codebook
        toks = np.stack(h.out_toks, axis=1)                 # (B, T[,K])
        lps = np.stack(h.out_lps, axis=1)                   # (B, T)
        results = []
        offset = 0
        for prompt, ns in zip(h.prompts, h.repeats):
            sl = slice(offset, offset + ns)
            offset += ns
            samples = [toks[i] for i in range(sl.start, sl.stop)]
            if self.eos_token is not None and not mc:
                samples = [self._truncate(s) for s in samples]
            results.append(GenerationResult(
                prompt=prompt,
                samples=samples,
                logprobs=[float(lps[i].mean())
                          for i in range(sl.start, sl.stop)],
                prefill_tokens=h.plen,
                decode_tokens=int(np.prod(toks.shape[1:2])) * ns,
            ))
        self.slots_in_use -= h.n_sequences
        return results

    def _truncate(self, sample: np.ndarray) -> np.ndarray:
        hits = np.nonzero(sample == self.eos_token)[0]
        return sample[: hits[0]] if hits.size else sample
