from repro.serving.backend import (ExecutionBackend, GenerationResult,
                                   InFlightBatch, bucket_key)
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (AdmissionResult, BatchRecord,
                                     CompletedRequest,
                                     ContinuousBatchingScheduler,
                                     RequestQueue, SchedulerConfig,
                                     ServeRequest)

__all__ = ["ServingEngine", "GenerationResult", "ExecutionBackend",
           "InFlightBatch", "bucket_key", "ContinuousBatchingScheduler",
           "RequestQueue", "SchedulerConfig", "ServeRequest",
           "AdmissionResult", "BatchRecord", "CompletedRequest"]
