from repro.serving.backend import (BlockAllocator, ExecutionBackend,
                                   GenerationResult, InFlightBatch,
                                   PagedBatchLayout, PendingPrefill,
                                   bucket_key, build_paged_layout)
from repro.serving.chaos import ChaosDriver, FaultAction, FaultPlan
from repro.serving.engine import ServingEngine
from repro.serving.prefix_pool import PrefixPool
from repro.serving.scheduler import (AdmissionResult, BatchRecord,
                                     CompletedRequest,
                                     ContinuousBatchingScheduler,
                                     RequestQueue, ResumeState,
                                     SchedulerConfig, ServeRequest,
                                     tier_priority)

__all__ = ["ServingEngine", "GenerationResult", "ExecutionBackend",
           "InFlightBatch", "bucket_key", "ContinuousBatchingScheduler",
           "RequestQueue", "SchedulerConfig", "ServeRequest",
           "AdmissionResult", "BatchRecord", "CompletedRequest",
           "BlockAllocator", "PagedBatchLayout", "build_paged_layout",
           "PrefixPool", "PendingPrefill", "ResumeState", "tier_priority",
           "ChaosDriver", "FaultAction", "FaultPlan"]
