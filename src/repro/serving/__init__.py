from repro.serving.engine import GenerationResult, ServingEngine

__all__ = ["ServingEngine", "GenerationResult"]
