"""End-to-end driver (deliverable b): train a small model on a verifiable
task, then serve batched requests with repeated sampling, the quality-
verification cascade, QEIL orchestration and the safety monitor in the loop.

This is the full QEIL story on real hardware (this container's CPU), with
the edge-platform profiles driving the placement/energy decisions. Serving
goes through the scheduler-centric stack (PR 4): requests enter tier-aware
admission and the continuous-batching scheduler routes each formed batch to
a shared operating point off the PGSAM archive.

Run: PYTHONPATH=src python examples/serve_heterogeneous.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Constraints, SafetyMonitor, Workload, run_pass_at_k)
from repro.core.devices import EDGE_PLATFORM
from repro.data import ArithGenerator, DataConfig, data_iterator
from repro.models import ArchConfig, Model
from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                         RoutedServingEngine, default_tiers)
from repro.serving import ServingEngine
from repro.training import AdamWConfig, train

# --- 1. train a ~1M-param model on the verifiable arithmetic task
cfg = ArchConfig(name="arith-serve", arch_type="dense", n_layers=2,
                 d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                 vocab_size=16)
model = Model(cfg, dtype=jnp.float32)
dc = DataConfig(vocab_size=16, seq_len=24, batch_size=32, kind="arith")
print("training...")
params, info = train(model, AdamWConfig(lr=3e-3, warmup_steps=10,
                                        total_steps=150),
                     data_iterator(dc), 150, log_every=50)
print("  final loss:", round(info["final_loss"], 3))

# --- 2. QEIL routing surface for the serving workload: PGSAM archive + SLA
# tiers; the scheduler will route every formed batch over this frontier
w = Workload(batch=1, prompt_tokens=4, decode_tokens=2, samples=8)
orch = PGSAMOrchestrator(EDGE_PLATFORM,
                         Constraints(latency_budget_factor=None),
                         config=PGSAMConfig(seed=0, iters_max=800,
                                            incremental=True))
placed = [a for a in orch.pareto_frontier(cfg, w) if a.mapping]
base_lat = min(a.latency_s for a in placed) / 0.9
router = ParetoRouter(orch, cfg, w, tiers=default_tiers(base_lat))
plan = router.route("standard").assignment
print(f"\nrouting surface: {len(placed)} operating points; standard tier "
      f"-> {plan.device_names()}  energy={plan.energy_j * 1e3:.2f} mJ")

# --- 3. safety monitor vets requests
safety = SafetyMonitor(EDGE_PLATFORM, max_seq_len=64, vocab_size=16)
gen = ArithGenerator(dc)
rng = np.random.default_rng(0)
tasks = []
rejected = 0
attacks = [np.zeros(1000, np.int32),                  # oversized
           np.array([3, -1, 5], np.int32)]            # malformed
for attack in attacks:
    if not safety.validator.validate(attack, time.time() % 1e6).ok:
        rejected += 1
for _ in range(16):
    prompt, answer = gen.make_prompt(rng)
    if safety.validator.validate(prompt, time.time() % 1e6).ok:
        tasks.append((prompt, lambda s, a=answer: gen.verify(s, a)))
print(f"safety: {rejected}/2 attacks blocked, {len(tasks)} legit requests in")

# --- 4. repeated sampling + verification cascade, served through the
# scheduler: the shim turns the pass@k driver's one generate call into
# admission -> batching -> backend (one batch per prompt-length bucket,
# placed at the standard tier's shared operating point)
engine = ServingEngine(model, params, max_new_tokens=2, temperature=1.0)
routed = RoutedServingEngine(engine, router, default_tier="standard")
res = run_pass_at_k(routed, tasks, n_samples=8, budgets=(1, 2, 4, 8))
for rec in routed.scheduler.records:
    print(f"scheduler batch {rec.batch_id}: {rec.n_requests} req "
          f"{rec.tier_mix} -> point {rec.point_index} "
          f"E={rec.energy_j * 1e3:.2f} mJ T={rec.latency_s * 1e3:.2f} ms")
print("\npass@k coverage:", {k: round(v, 3)
                             for k, v in res.coverage_by_k.items()})
print(f"verification cascade: {res.cascade.exact_checked}/"
      f"{res.cascade.candidates} exact checks "
      f"({res.cascade.verification_savings:.0%} saved by the cheap screen)")
print(f"tokens: {res.prefill_tokens} prefill / {res.decode_tokens} decode")
