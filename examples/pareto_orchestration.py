"""Pareto-optimal multi-objective orchestration (the v2 title's headline):
sweep sample budgets and latency SLAs, compute the non-dominated
(energy, latency, coverage) frontier, and show where the paper's operating
points sit relative to what the roofline actually admits.

Run: PYTHONPATH=src python examples/pareto_orchestration.py
"""
from repro.core import (ParetoOrchestrator, Workload, decompose,
                        homogeneous_assignment, hypervolume_2d, plan_costs)
from repro.core.devices import EDGE_GPU_NVIDIA, EDGE_PLATFORM
from repro.configs.paper_models import GPT2_125M

w = Workload(batch=100, prompt_tokens=128, decode_tokens=256, samples=20)

po = ParetoOrchestrator(EDGE_PLATFORM)
front = po.frontier(GPT2_125M, w, sample_budgets=(5, 10, 20, 40),
                    n_latency_points=6)

stages = decompose(GPT2_125M, w)
gpu = plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                 workload=w)
print(f"homogeneous GPU reference: {gpu.energy_j:.1f} J, "
      f"{gpu.makespan_s * 1e3:.0f} ms, S=20\n")

print(f"{'S':>4} {'energy J':>10} {'latency ms':>11} {'coverage':>9} "
      f"{'devices'}")
for c in sorted(front, key=lambda c: c["energy_j"]):
    a = c["assignment"]
    print(f"{c['samples']:>4} {c['energy_j']:>10.1f} "
          f"{c['latency_s'] * 1e3:>11.0f} {c['coverage']:>9.3f} "
          f"{','.join(d.split('-')[0] for d in a.device_names())}")

pts = [(c["energy_j"], c["latency_s"]) for c in front]
hv = hypervolume_2d(pts, ref=(gpu.energy_j * 2, gpu.makespan_s * 2))
print(f"\nfrontier size: {len(front)}  "
      f"2-D hypervolume vs 2x-GPU reference: {hv:.2f}")

# --- v2: the same-workload frontier from a single PGSAM anneal (no sweep) ---
from repro.core import Constraints
from repro.qeil2 import PGSAMConfig, PGSAMOrchestrator

orch = PGSAMOrchestrator(EDGE_PLATFORM,
                         Constraints(latency_budget_factor=None),
                         config=PGSAMConfig(seed=0))
archive = orch.pareto_frontier(GPT2_125M, w)
pg_pts = [(a.energy_j, a.latency_s) for a in archive if a.mapping]
# compare at fixed S=20: the sweep's other points change the workload itself
g20 = [(c["energy_j"], c["latency_s"]) for c in front if c["samples"] == 20]
ref = (gpu.energy_j * 2, gpu.makespan_s * 2)
pg_hv, g_hv = hypervolume_2d(pg_pts, ref), hypervolume_2d(g20, ref)
print(f"PGSAM archive size: {len(pg_pts)}  hypervolume: {pg_hv:.2f} vs "
      f"greedy S=20 sweep {g_hv:.2f} "
      f"({'beats' if pg_hv >= g_hv else 'trails'} it, from one anneal)")
print("note: no single frontier point reaches the paper's claimed "
      "(-47.7% energy AND -22.5% latency AND +10.5pp coverage) "
      "simultaneously — see EXPERIMENTS.md §Perf for the analysis.")
