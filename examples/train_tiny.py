"""Train a ~25M-parameter model for a few hundred steps on the synthetic
Markov corpus, with checkpointing — exercising the full training substrate
(optimizer, LR schedule, data pipeline, checkpoint save/restore).

Run: PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.data import DataConfig, data_iterator
from repro.models import ArchConfig, Model
from repro.training import (AdamWConfig, latest_checkpoint,
                            restore_checkpoint, save_checkpoint, train)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
args = ap.parse_args()

cfg = ArchConfig(name="tiny-lm", arch_type="dense", n_layers=args.layers,
                 d_model=args.d_model, n_heads=8, n_kv_heads=4,
                 d_ff=args.d_model * 4, vocab_size=512)
model = Model(cfg, dtype=jnp.float32)
print(f"model: {model.param_count() / 1e6:.1f} M params")

dc = DataConfig(vocab_size=512, seq_len=128, batch_size=16, kind="markov")
ckpt_dir = tempfile.mkdtemp(prefix="qeil_ckpt_")

params, info = train(
    model, AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    data_iterator(dc), args.steps, log_every=25,
    checkpoint_fn=lambda step, p, o: save_checkpoint(ckpt_dir, step, p, o),
    checkpoint_every=max(args.steps // 2, 1))

for h in info["history"]:
    print(f"  step {h['step']:4.0f}  loss {h['loss']:.4f}  "
          f"lr {h['lr']:.2e}  {h['wall_s']:.0f}s")

# restore round-trip
ck = latest_checkpoint(ckpt_dir)
step, restored, _ = restore_checkpoint(ck, model.param_specs())
import numpy as np
a = jax.tree.leaves(params)[0]
b = jax.tree.leaves(restored)[0]
assert np.allclose(np.asarray(a), np.asarray(b))
print(f"\ncheckpoint round-trip OK at step {step} ({ck})")
