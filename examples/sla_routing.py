"""SLA-tiered routing over the live Pareto frontier (PR 2 runtime).

Three request classes — interactive (latency-capped), standard (balanced,
quality-floored), economy (pure energy) — routed over the PGSAM archive on
the paper's 4-device edge platform. One anneal builds the archive; every
route is a cache hit that scalarizes the tier's caps/weights over it.

Run: PYTHONPATH=src python examples/sla_routing.py
"""
from repro.core import Constraints, Workload
from repro.core.devices import EDGE_PLATFORM
from repro.configs.paper_models import GPT2_125M
from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                         SLATier)

w = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
orch = PGSAMOrchestrator(EDGE_PLATFORM,
                         Constraints(latency_budget_factor=None),
                         config=PGSAMConfig(seed=0, incremental=True),
                         energy_model="v2")

archive = [a for a in orch.pareto_frontier(GPT2_125M, w) if a.mapping]
lat_min = min(a.latency_s for a in archive)
print(f"archive: {len(archive)} operating points, latency span "
      f"{lat_min * 1e3:.0f}..{max(a.latency_s for a in archive) * 1e3:.0f} ms,"
      f" energy span {min(a.energy_j for a in archive):.1f}.."
      f"{max(a.energy_j for a in archive):.1f} J")

tiers = [
    SLATier("interactive", latency_p99_s=1.3 * lat_min,
            energy_weight=0.0, latency_weight=1.0),
    SLATier("standard", latency_p99_s=3.0 * lat_min, min_quality=0.70,
            energy_weight=0.5, latency_weight=0.5),
    SLATier("economy", energy_weight=1.0, latency_weight=0.0),
]
router = ParetoRouter(orch, GPT2_125M, w, tiers=tiers)

print(f"\n{'tier':<12} {'pt':>3} {'energy J':>9} {'latency ms':>11} "
      f"{'avg W':>6} {'caps':>5}  devices")
for name in ("interactive", "standard", "economy"):
    d = router.route(name)
    devs = ",".join(n.split("-")[0] for n in d.assignment.device_names())
    print(f"{name:<12} {d.point_index:>3} {d.energy_j:>9.2f} "
          f"{d.latency_s * 1e3:>11.1f} {d.avg_power_w:>6.1f} "
          f"{str(d.meets_caps):>5}  {devs}")
    for note in d.notes:
        print(f"{'':<12} note: {note}")

distinct = {router.route(t.name).point_index for t in tiers}
print(f"\n{len(tiers)} tiers -> {len(distinct)} distinct operating points "
      f"(the frontier is a routing surface, not a single plan)")

# --- batch-aware routing (PR 4): the continuous-batching scheduler routes a
# mixed-tier batch to ONE shared operating point. Caps merge to the tightest
# member tier, every archive point is re-costed under the batch workload
# (decode re-streams weights once per token regardless of batch size, so
# batching amortizes), and the batch energy is attributed back per tier.
print("\nbatch-aware routing (shared operating point per mixed-tier batch):")
for members in (["interactive"], ["interactive", "standard", "economy"],
                ["standard"] * 2 + ["economy"] * 6):
    d = router.route_batch(members)
    per_req = d.energy_j / d.n_requests
    attrib = {t: round(e, 2) for t, e in sorted(d.per_tier_energy_j.items())}
    print(f"  {len(members)} req {d.tier.name:<30} -> point {d.point_index:2d}"
          f" T={d.latency_s * 1e3:7.1f} ms E/req={per_req:6.2f} J"
          f" caps={d.meets_caps}  attribution {attrib}")

one = router.recost(router.route("economy").assignment,
                    router.batch_workload(1))
eight = router.recost(router.route("economy").assignment,
                      router.batch_workload(8))
print(f"\namortization at the economy point: batch of 8 costs "
      f"{eight.energy_j / (8 * one.energy_j):.0%} of 8x a batch of 1 "
      f"(weight re-streaming is batch-invariant)")
