"""QEIL quickstart: the paper's pipeline in ~60 lines.

1. fit the coverage scaling formalism from sampled outcomes,
2. decompose an inference workload into stages,
3. orchestrate across the heterogeneous edge platform,
4. compare against homogeneous baselines with IPW/ECE/PPP.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Constraints, GreedyOrchestrator, Workload,
                        decompose, empirical_coverage, fit_power_law,
                        homogeneous_assignment, plan_costs,
                        simulate_outcomes)
from repro.core.devices import EDGE_GPU_NVIDIA, EDGE_PLATFORM
from repro.configs.paper_models import GPT2_125M

# --- 1. Formalism 1: fit coverage scaling C(S) = 1 - exp(-alpha S^beta)
outcomes = simulate_outcomes(n_tasks=1000, n_samples=20, target_cov=0.70)
ks = [1, 2, 5, 10, 20]
cov = empirical_coverage(outcomes, ks)
fit = fit_power_law(ks, [cov[k] for k in ks])
print(f"coverage scaling: alpha={fit.alpha:.4f} beta={fit.beta:.2f} "
      f"(paper: ~0.70), R2={fit.r2:.3f}")
print("  pass@k:", {k: round(v, 3) for k, v in cov.items()})

# --- 2. decompose a 20-sample workload into stages
w = Workload(batch=100, prompt_tokens=128, decode_tokens=256, samples=20)
stages = decompose(GPT2_125M, w)
pre = [s for s in stages if s.phase == "prefill"][0]
dec = [s for s in stages if s.phase == "decode"][0]
print(f"\nstage intensities (FLOP/byte): prefill {pre.intensity:.0f} "
      f"(compute-bound), decode {dec.intensity:.1f} (memory-bound)")

# --- 3. orchestrate
orch = GreedyOrchestrator(EDGE_PLATFORM,
                          Constraints(latency_budget_factor=1.0))
plan = orch.assign(GPT2_125M, w)
print(f"\nQEIL plan: devices={plan.device_names()}")
print(f"  energy {plan.energy_j:.1f} J, latency {plan.latency_s * 1e3:.1f} ms")

# --- 4. compare with homogeneous GPU
gpu = plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                 workload=w)
print(f"homogeneous GPU: energy {gpu.energy_j:.1f} J, "
      f"latency {gpu.makespan_s * 1e3:.1f} ms")
print(f"==> heterogeneous delta: "
      f"{(plan.energy_j / gpu.energy_j - 1) * 100:+.1f}% energy, "
      f"{(plan.latency_s / gpu.makespan_s - 1) * 100:+.1f}% latency")
